"""Sharded SPMD learner group: data-parallel ``learn_on_batch`` on a mesh.

The paper's thesis is that the dataflow layer and the numerical concerns
compose independently (§3, Fig 5): ``TrainOneStep`` / ``LearnerThread`` call
``learn_on_batch`` and never care *how* the update executes.  This module is
the numerical half of that contract scaled out: it lowers a worker's learn
step onto a ``jax.Mesh`` so the same dataflow plan drives one device or a
data-parallel learner group — the execution mapping changes, the graph does
not (MSRL's "fragment to multiple processes" move, SRL's learner group).

``ShardedLearnerGroup`` wraps an existing rollout/learner worker (the owner
of policy, params, optimizer, RNG) and replaces its ``learn_on_batch`` with
a jit-compiled SPMD step:

  * **batch sharding at the transport boundary** — host numpy columns are
    ``device_put`` directly with a ``NamedSharding`` over the mesh's
    ``data`` axis (resolved through the existing ``AxisRules`` table), so
    each device receives only its slice; no full-batch staging on device 0.
  * **gradient microbatch accumulation** — the per-device shard is split
    into ``microbatch`` slices walked by ``lax.scan``, accumulating the
    mean gradient before a single optimizer apply: global batch sizes
    beyond per-device memory cost activations of one microbatch only.
  * **donated buffers** — optimizer state is donated into the step, so its
    update is in-place on device.  Param donation is opt-in
    (``donate_params=True``): on the thread backend ``sync_weights`` shares
    the canonical param arrays with rollout workers *by reference*, and
    donating them would delete the buffers out from under the workers'
    jitted rollouts (a real crash, caught end-to-end on IMPALA).  Enable it
    only when weights cross every worker boundary by value (process
    backends).

Loss parity: with equal global batch, mean-reduced losses and gradients are
identical (to float tolerance) between 1 device, N devices, and any
microbatch factor — asserted at 1e-4 by ``tests/test_learner_group.py``
against a 4-device simulated mesh (``XLA_FLAGS=--xla_force_host_platform_
device_count=4``).
"""

from __future__ import annotations

import logging
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import AxisRules, make_data_mesh

PyTree = Any

__all__ = ["ShardedLearnerGroup"]

logger = logging.getLogger(__name__)

# Logical-axis rules for the learner group's mesh: only the batch dim is
# sharded (pure data parallelism); params/opt state stay replicated.
LEARNER_RULES = {"batch": "data"}


class ShardedLearnerGroup:
    """Data-parallel SPMD learn step over ``num_learners`` devices.

    ``worker`` must expose the learner half of the worker protocol —
    ``policy``, ``params``, ``target_params``, ``opt_state``, ``optimizer``,
    ``_key``, and the pure ``_loss_for(params, target_params, batch, key)``
    (``RolloutWorker`` does).  The group keeps the worker canonical: after
    every step the worker's params/opt state are the updated (replicated)
    values, so ``get_weights``/``sync_weights`` see fresh weights.
    """

    def __init__(
        self,
        worker: Any,
        num_learners: int = 0,
        microbatch: int = 0,
        donate_params: bool = False,
    ):
        devices = jax.devices()
        requested = num_learners if num_learners > 0 else 1
        if requested > len(devices):
            logger.warning(
                "learner group: %d learners requested but only %d devices "
                "visible; clamping (set XLA_FLAGS="
                "--xla_force_host_platform_device_count=%d to simulate)",
                requested, len(devices), requested,
            )
        self.num_learners = min(requested, len(devices))
        self.microbatch = max(microbatch, 1)
        self.donate_params = donate_params
        self.worker = worker
        # Trace-structured losses (v-trace) reshape rows back into
        # contiguous length-T traces: trimming and microbatch slicing must
        # then happen in whole-trace units or the reshape fails (or worse,
        # regroups rows across trace boundaries silently).
        policy = getattr(worker, "policy", None)
        self.trace_len = (
            max(int(getattr(policy, "rollout_len", 0)), 1)
            if getattr(policy, "loss_kind", None) == "vtrace"
            else 1
        )
        self.mesh = make_data_mesh(self.num_learners)
        self.rules = AxisRules(LEARNER_RULES, self.mesh)
        self._batch_sharding = NamedSharding(
            self.mesh, self.rules.resolve(("batch",))
        )
        self._replicated = NamedSharding(self.mesh, P())
        self._step = None
        self.num_steps = 0
        self.num_rows_trimmed = 0
        # Replicate the worker's state onto the mesh once; afterwards the
        # donated step keeps it resident.
        for attr in ("params", "target_params", "opt_state"):
            setattr(
                self.worker,
                attr,
                jax.device_put(getattr(self.worker, attr), self._replicated),
            )

    # ------------------------------------------------------------ SPMD step
    def _build_step(self):
        optimizer = self.worker.optimizer
        loss_for = self.worker._loss_for
        k = self.microbatch

        def step(params, target_params, opt_state, batch, key):
            if k > 1:
                def microstep(carry, mb):
                    grad_acc, loss_acc, key = carry
                    key, sub = jax.random.split(key)
                    (loss, aux), grads = jax.value_and_grad(
                        loss_for, has_aux=True
                    )(params, target_params, mb, sub)
                    grad_acc = jax.tree_util.tree_map(jnp.add, grad_acc, grads)
                    return (grad_acc, loss_acc + loss, key), aux

                zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
                (grads, loss, _), aux = jax.lax.scan(
                    microstep, (zeros, jnp.asarray(0.0), key), batch
                )
                grads = jax.tree_util.tree_map(lambda g: g / k, grads)
                loss = loss / k
                # aux leaves keep their stacked [k, ...] leading axis; the
                # host side means scalars and flattens per-row columns.
            else:
                (loss, aux), grads = jax.value_and_grad(loss_for, has_aux=True)(
                    params, target_params, batch, key
                )
            params, opt_state = optimizer.apply(params, grads, opt_state)
            return params, opt_state, loss, aux

        return jax.jit(
            step,
            # opt_state (2) updates in place on the mesh; params (0) only
            # when donation is safe (see class docstring), and
            # target_params persist across steps and are never donated.
            donate_argnums=(0, 2) if self.donate_params else (2,),
            out_shardings=(self._replicated, self._replicated, None, None),
        )

    # --------------------------------------------------- transport boundary
    def shard_batch(self, batch: Any) -> Tuple[Dict[str, jax.Array], int]:
        """Host columns -> mesh-sharded device columns.

        The global row count must tile evenly: each of the ``microbatch``
        slices must split across ``num_learners`` devices, and for
        trace-structured losses every slice must hold whole length-T traces
        (batch-major rows keep traces contiguous, so tail-trimming in
        T-multiples preserves them).  Surplus rows are trimmed (counted in
        ``num_rows_trimmed``) rather than padded — padding would silently
        bias mean-reduced losses.  With ``microbatch=k`` columns land as
        [k, rows/k, ...], microbatch axis replicated, row axis sharded over
        ``data``.
        """
        # rows-per-microbatch must divide by trace_len (loss reshape) and
        # the total by num_learners (even device shards): k * lcm(n, T).
        import math

        tile = self.microbatch * math.lcm(self.num_learners, self.trace_len)
        count = batch.count if hasattr(batch, "count") else len(next(iter(batch.values())))
        usable = (count // tile) * tile
        if usable == 0:
            raise ValueError(
                f"batch of {count} rows cannot tile {self.num_learners} "
                f"learners x {self.microbatch} microbatches"
            )
        self.num_rows_trimmed += count - usable
        k = self.microbatch
        if k > 1:
            sharding = NamedSharding(self.mesh, P(None, "data"))
        else:
            sharding = self._batch_sharding
        out = {}
        for name, col in batch.items():
            # Host-only metadata never reaches the mesh: batch_indices feed
            # replay priority updates, eps_id is int64 fragment labeling
            # (canonicalizing it to int32 would overflow the lane strides).
            if name in ("batch_indices", "eps_id"):
                continue
            col = np.asarray(col)[:usable]
            if k > 1:
                col = col.reshape((k, usable // k) + col.shape[1:])
            out[name] = jax.device_put(col, sharding)
        return out, usable

    # -------------------------------------------------------------- learning
    def learn_on_batch(self, batch: Any, policy_id: Optional[str] = None) -> Dict[str, Any]:
        if self._step is None:
            self._step = self._build_step()
        device_batch, usable = self.shard_batch(batch)
        count = batch.count if hasattr(batch, "count") else usable
        w = self.worker
        w._key, key = jax.random.split(w._key)
        w.params, w.opt_state, loss, aux = self._step(
            w.params, w.target_params, w.opt_state, device_batch, key
        )
        self.num_steps += 1
        # Replay the worker's own per-update side effects (SAC polyak
        # target tracking — skipping it would train against a frozen
        # target forever, silently), then keep the touched state on-mesh.
        if hasattr(w, "_post_update"):
            w._post_update()
            w.target_params = jax.device_put(w.target_params, self._replicated)
        info: Dict[str, Any] = {"loss": float(loss)}
        for name, v in aux.items():
            if name == "td_error":
                # Per-row priorities: flatten the microbatch axis back out.
                td = np.asarray(v).reshape(-1)
                if td.size < count:
                    # Trimmed rows got no update; consumers zip td_error
                    # with the *full* batch (UpdateReplayPriorities against
                    # batch_indices), so pad with the mean magnitude — a
                    # neutral priority, not an artificial zero or max.
                    fill = float(np.mean(np.abs(td))) if td.size else 0.0
                    td = np.concatenate([td, np.full(count - td.size, fill, td.dtype)])
                info["td_error"] = td
            else:
                info[name] = float(jnp.mean(v))
        info["num_learners"] = self.num_learners
        info["microbatch"] = self.microbatch
        return info

    # ----------------------------------------------------- worker protocol
    def get_weights(self) -> PyTree:
        return self.worker.get_weights()

    def set_weights(self, weights: PyTree) -> None:
        self.worker.set_weights(weights)
        self.worker.params = jax.device_put(self.worker.params, self._replicated)

    def __repr__(self) -> str:  # pragma: no cover
        return (
            f"ShardedLearnerGroup(devices={self.num_learners}, "
            f"microbatch={self.microbatch}, steps={self.num_steps})"
        )
