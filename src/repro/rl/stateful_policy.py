"""Stateful (recurrent) serving policies: per-lane state lives server-side.

The serving tier's *stateful-policy protocol* is two methods on top of the
usual ``init_params``:

  * ``init_lane_state(n) -> pytree`` — fresh recurrent state for ``n`` lanes
    (leading axis ``n`` on every leaf, so the server can gather/scatter
    per-lane rows with ``tree_map``).
  * ``compute_actions_stateful(params, obs[B,D], keys[B,2], state) ->
    (actions, logp, values, new_state)`` — one decode step over a batch of
    lanes, carrying the state exactly like env state in a rollout actor
    (DESIGN.md §4: model-state-as-actor-state).

``InferenceActor`` detects the protocol (``hasattr(policy,
"init_lane_state")``), keys the state by the caller's global lane id, and
``InferenceRouter`` then routes those lanes *sticky*: a lane's state exists
on exactly one replica, so its requests must keep landing there.

``SSMStatePolicy`` below is the concrete exemplar: a Mamba block
(``models/ssm.py``) as the actor-critic trunk, whose selective-scan state
``{"h": [B, d_in, d_state], "conv": [B, d_conv-1, d_in]}`` is the per-lane
server-side state.  A KV-cache transformer policy
(``kernels/decode_attention.py``) slots into the same protocol — the cache
is just a bigger pytree with the same leading lane axis.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig, SSMConfig
from repro.models.ssm import init_mamba_state, mamba_decode, mamba_init
from repro.rl.policy import mlp_apply, mlp_init

PyTree = Any

__all__ = ["SSMStatePolicy"]


def _serve_ssm_config(d_model: int, d_state: int) -> ModelConfig:
    return ModelConfig(
        name="serve-ssm",
        arch_type="ssm",
        num_layers=1,
        d_model=d_model,
        num_heads=1,
        num_kv_heads=1,
        d_ff=d_model,
        vocab_size=1,
        block_pattern=(LayerSpec(kind="mamba", mlp="none"),),
        ssm=SSMConfig(kind="mamba", d_state=d_state, d_conv=2, expand=1),
        dtype="float32",
    )


class SSMStatePolicy:
    """Discrete actor-critic over a single Mamba block, decoded one env step
    at a time with O(1) per-lane state.

    Each ``compute_actions_stateful`` call is one token of an unbounded
    decode: the observation embeds to a d_model token, the Mamba block
    advances ``(h, conv)`` for every lane in the batch, and policy/value
    heads read the block output.  The recurrent state is returned to the
    caller (the serving actor), never kept here — the policy object stays
    stateless and picklable.
    """

    def __init__(
        self,
        obs_dim: int,
        num_actions: int,
        d_model: int = 32,
        d_state: int = 4,
    ):
        self.obs_dim = obs_dim
        self.num_actions = num_actions
        self.cfg = _serve_ssm_config(d_model, d_state)

    def init_params(self, key: jax.Array) -> PyTree:
        k1, k2, k3, k4 = jax.random.split(key, 4)
        d = self.cfg.d_model
        return {
            "embed": jax.random.normal(k1, (self.obs_dim, d), jnp.float32)
            * (1.0 / jnp.sqrt(self.obs_dim)),
            "trunk": mamba_init(k2, self.cfg),
            "pi": mlp_init(k3, (d, self.num_actions)),
            "vf": mlp_init(k4, (d, 1), scale_last=1.0),
        }

    # ------------------------------------------------ stateful-policy protocol
    def init_lane_state(self, n: int) -> PyTree:
        """Fresh decode state for ``n`` lanes (leading axis n on each leaf)."""
        return init_mamba_state(self.cfg, n)

    def compute_actions_stateful(
        self, params: PyTree, obs: jax.Array, keys: jax.Array, state: PyTree
    ) -> Tuple[jax.Array, jax.Array, jax.Array, PyTree]:
        """One decode step for a batch of lanes with per-lane RNG keys."""
        x = (obs @ params["embed"])[:, None, :]  # [B, 1, d_model]
        out, new_state = mamba_decode(params["trunk"], x, state, self.cfg)
        h = jnp.tanh(out[:, 0])
        logits = mlp_apply(params["pi"], h)
        action = jax.vmap(jax.random.categorical)(keys, logits)
        logp_all = jax.nn.log_softmax(logits)
        logp = jnp.take_along_axis(logp_all, action[:, None], axis=-1)[:, 0]
        value = mlp_apply(params["vf"], h)[:, 0]
        return action, logp, value, new_state

    # ------------------------------------------------------- value queries
    def value(self, params: PyTree, obs: jax.Array) -> jax.Array:
        """State-free value estimate (bootstrap queries): decode one step
        from a fresh state without advancing anything."""
        x = (obs @ params["embed"])[:, None, :]
        out, _ = mamba_decode(
            params["trunk"], x, init_mamba_state(self.cfg, obs.shape[0]), self.cfg
        )
        return mlp_apply(params["vf"], jnp.tanh(out[:, 0]))[:, 0]
