"""RolloutWorker: the actor target used by every dataflow plan.

Owns: a vectorized JAX env, a policy, params (+ target params for off-policy
algos), optimizer state, and RNG.  The entire T-step × B-env rollout compiles
to a single ``lax.scan`` XLA program; ``learn_on_batch`` is likewise one jitted
update.  The dataflow layer composes these via the worker protocol
(sample / get_weights / set_weights / compute_gradients / apply_gradients /
learn_on_batch / update_target).
"""

from __future__ import annotations

import functools
import threading
from collections import deque
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import fused_gae as gae
from repro.optim import Optimizer, adam
from repro.rl.env import Env
from repro.rl.policy import ActorCriticPolicy, DQNPolicy, SACPolicy
from repro.rl.sample_batch import MultiAgentBatch, SampleBatch

PyTree = Any

__all__ = ["RolloutWorker", "MultiAgentRolloutWorker"]


def _to_numpy_batch(cols: Dict[str, jax.Array]) -> SampleBatch:
    """[T, B, ...] device arrays -> batch-major flattened numpy SampleBatch.

    Batch-major flattening keeps each env's length-T trace contiguous, which
    the v-trace loss relies on to reshape back to time-major.
    """
    out = {}
    for k, v in cols.items():
        v = np.asarray(v)
        v = v.swapaxes(0, 1)  # [B, T, ...]
        out[k] = v.reshape((-1,) + v.shape[2:])
    return SampleBatch(out)


class RolloutWorker:
    def __init__(
        self,
        env: Env,
        policy: Any,
        algo: str = "pg",  # pg | ppo | vtrace | dqn | sac
        num_envs: int = 4,
        rollout_len: int = 64,
        optimizer: Optional[Optimizer] = None,
        gamma: float = 0.99,
        lam: float = 0.95,
        epsilon: float = 0.1,
        target_polyak: float = 0.0,  # 0 -> hard target copy
        seed: int = 0,
        worker_index: int = 0,
    ):
        self.env = env
        self.policy = policy
        self.algo = algo
        self.num_envs = num_envs
        self.rollout_len = rollout_len
        self.gamma = gamma
        self.lam = lam
        self.epsilon = epsilon
        self.target_polyak = target_polyak
        self.worker_index = worker_index

        self._key = jax.random.PRNGKey(seed * 10007 + worker_index)
        self._key, pk, ek = jax.random.split(self._key, 3)
        self.params = policy.init_params(pk)
        self.target_params = jax.tree_util.tree_map(jnp.array, self.params)
        self.optimizer = optimizer or adam(3e-4)
        self.opt_state = self.optimizer.init(self.params)

        env_keys = jax.random.split(ek, num_envs)
        self.env_state, self.obs = jax.vmap(env.reset)(env_keys)
        self._ep_returns = jnp.zeros((num_envs,), jnp.float32)
        self._completed: deque = deque(maxlen=100)

        self._rollout_jit = jax.jit(self._rollout)
        self._learn_jit = jax.jit(self._learn)
        self._grad_jit = jax.jit(self._grads)
        self._apply_jit = jax.jit(self._apply)

    # --------------------------------------------------------------- rollout
    def _act(self, params: PyTree, obs: jax.Array, key: jax.Array):
        if self.algo == "dqn":
            return self.policy.act(params, obs, key, jnp.asarray(self.epsilon))
        return self.policy.act(params, obs, key)

    def _rollout(self, params: PyTree, env_state: Any, obs: jax.Array, ep_ret: jax.Array, key: jax.Array):
        def step_fn(carry, key_t):
            env_state, obs, ep_ret = carry
            k_act, k_env = jax.random.split(key_t)
            action, logp, value, _ = self._act(params, obs, k_act)
            env_keys = jax.random.split(k_env, self.num_envs)
            env_state, next_obs, reward, done = jax.vmap(self.env.step)(
                env_state, action, env_keys
            )
            new_ret = ep_ret + reward
            completed = jnp.where(done, new_ret, 0.0)
            ep_ret = jnp.where(done, 0.0, new_ret)
            out = {
                "obs": obs,
                "actions": action,
                "rewards": reward,
                "dones": done.astype(jnp.float32),
                "logp": logp,
                "values": value,
                "next_obs": next_obs,
                "completed": completed,
            }
            return (env_state, next_obs, ep_ret), out

        keys = jax.random.split(key, self.rollout_len)
        (env_state, obs, ep_ret), cols = jax.lax.scan(step_fn, (env_state, obs, ep_ret), keys)

        if self.algo in ("pg", "ppo"):
            _, _, last_value, _ = self._act(params, obs, keys[-1])
            adv, ret = gae(
                cols["rewards"], cols["values"], cols["dones"], last_value, self.gamma, self.lam
            )
            cols["advantages"] = adv
            cols["returns"] = ret
        return env_state, obs, ep_ret, cols

    def sample(self) -> SampleBatch:
        self._key, k = jax.random.split(self._key)
        self.env_state, self.obs, self._ep_returns, cols = self._rollout_jit(
            self.params, self.env_state, self.obs, self._ep_returns, k
        )
        completed = np.asarray(cols.pop("completed"))
        for r in completed[completed != 0.0]:
            self._completed.append(float(r))
        if self.algo in ("dqn", "sac"):
            for k_ in ("logp", "values"):
                cols.pop(k_, None)
        return _to_numpy_batch(cols)

    def sample_with_count(self) -> Tuple[SampleBatch, int]:
        b = self.sample()
        return b, b.count

    # ----------------------------------------------------------------- learn
    # NOTE: target_params must be an explicit argument (never closed over) or
    # jit would bake the trace-time snapshot in as a constant.
    def _loss_for(self, params: PyTree, target_params: PyTree, batch: Dict[str, jax.Array], key: jax.Array):
        if self.algo == "dqn":
            return self.policy.loss(params, target_params, batch)
        if self.algo == "sac":
            return self.policy.loss(params, target_params, batch, key)
        return self.policy.loss(params, batch)

    def _grads(self, params: PyTree, target_params: PyTree, batch: Dict[str, jax.Array], key: jax.Array):
        (loss, aux), grads = jax.value_and_grad(self._loss_for, has_aux=True)(
            params, target_params, batch, key
        )
        return grads, loss, aux

    def _apply(self, params: PyTree, opt_state: PyTree, grads: PyTree):
        return self.optimizer.apply(params, grads, opt_state)

    def _learn(self, params: PyTree, target_params: PyTree, opt_state: PyTree, batch: Dict[str, jax.Array], key: jax.Array):
        (loss, aux), grads = jax.value_and_grad(self._loss_for, has_aux=True)(
            params, target_params, batch, key
        )
        params, opt_state = self.optimizer.apply(params, grads, opt_state)
        return params, opt_state, loss, aux

    @staticmethod
    def _device_batch(batch: SampleBatch) -> Dict[str, jax.Array]:
        return {k: jnp.asarray(v) for k, v in batch.items() if k != "batch_indices"}

    def learn_on_batch(self, batch: SampleBatch, policy_id: Optional[str] = None) -> Dict[str, Any]:
        self._key, k = jax.random.split(self._key)
        self.params, self.opt_state, loss, aux = self._learn_jit(
            self.params, self.target_params, self.opt_state, self._device_batch(batch), k
        )
        info = {"loss": float(loss)}
        for name, v in aux.items():
            if name == "td_error":
                info["td_error"] = np.asarray(v)
            else:
                info[name] = float(v)
        self._post_update()
        return info

    def _post_update(self) -> None:
        """Per-update side effects beyond the optimizer step (single hook so
        sharded learner groups replay the exact same behaviour): SAC tracks
        its target network by polyak averaging."""
        if self.algo == "sac" and self.target_polyak > 0:
            tau = self.target_polyak
            self.target_params = jax.tree_util.tree_map(
                lambda t, p: (1 - tau) * t + tau * p, self.target_params, self.params
            )

    def compute_gradients(self, batch: SampleBatch) -> Tuple[PyTree, Dict[str, Any]]:
        self._key, k = jax.random.split(self._key)
        grads, loss, aux = self._grad_jit(
            self.params, self.target_params, self._device_batch(batch), k
        )
        info = {"loss": float(loss), "batch_count": batch.count}
        return grads, info

    def apply_gradients(self, grads: PyTree) -> None:
        self.params, self.opt_state = self._apply_jit(self.params, self.opt_state, grads)

    # ------------------------------------------------------------- messaging
    def get_weights(self) -> PyTree:
        return self.params

    def set_weights(self, weights: PyTree) -> None:
        self.params = weights

    def update_target(self) -> None:
        self.target_params = jax.tree_util.tree_map(jnp.array, self.params)

    def episode_stats(self) -> Dict[str, float]:
        if not self._completed:
            return {"episode_reward_mean": float("nan"), "episodes": 0}
        return {
            "episode_reward_mean": float(np.mean(self._completed)),
            "episodes": len(self._completed),
        }

    # --------------------------------------------------------------- MAML
    def inner_adapt(self, batch: SampleBatch) -> None:
        """One inner-loop PG step on worker-local params (first-order MAML)."""
        self.learn_on_batch(batch)

    def reset_inner(self) -> None:
        # Meta-params were just broadcast via set_weights; nothing else to do
        # because inner adaptation mutated self.params in place.
        pass


class MultiAgentRolloutWorker:
    """Multi-policy rollouts for the PPO+DQN composition (paper §5.3).

    Each agent index is mapped to a policy id; per-policy experiences are
    returned as a MultiAgentBatch.  Policies may use different algorithms
    (PPO and DQN here), which is exactly the composition the paper enables.
    """

    def __init__(
        self,
        env: Any,  # MultiAgentCartPole
        policy_specs: Dict[str, Dict[str, Any]],
        agent_to_policy: Dict[int, str],
        rollout_len: int = 32,
        gamma: float = 0.99,
        lam: float = 0.95,
        epsilon: float = 0.1,
        seed: int = 0,
        worker_index: int = 0,
    ):
        self.env = env
        self.rollout_len = rollout_len
        self.gamma = gamma
        self.lam = lam
        self.epsilon = epsilon
        self.agent_to_policy = dict(agent_to_policy)
        self._key = jax.random.PRNGKey(seed * 7919 + worker_index)

        self.policies: Dict[str, Any] = {}
        self.params: Dict[str, PyTree] = {}
        self.target_params: Dict[str, PyTree] = {}
        self.optimizers: Dict[str, Optimizer] = {}
        self.opt_states: Dict[str, PyTree] = {}
        self.algos: Dict[str, str] = {}
        for pid, spec in policy_specs.items():
            self._key, k = jax.random.split(self._key)
            self.policies[pid] = spec["policy"]
            self.algos[pid] = spec.get("algo", "ppo")
            self.params[pid] = spec["policy"].init_params(k)
            self.target_params[pid] = jax.tree_util.tree_map(jnp.array, self.params[pid])
            self.optimizers[pid] = spec.get("optimizer") or adam(3e-4)
            self.opt_states[pid] = self.optimizers[pid].init(self.params[pid])

        self._key, ek = jax.random.split(self._key)
        self.env_state, self.obs = env.reset(ek)
        self._ep_returns = jnp.zeros((env.num_agents,), jnp.float32)
        self._completed: deque = deque(maxlen=100)
        self._rollout_jit = jax.jit(self._rollout)
        self._learn_jits: Dict[str, Callable] = {
            pid: jax.jit(functools.partial(self._learn, pid)) for pid in self.policies
        }

    # Agents grouped by policy for vectorized acting.
    def _agents_of(self, pid: str):
        return np.array([a for a, p in self.agent_to_policy.items() if p == pid])

    def _rollout(self, params: Dict[str, PyTree], env_state, obs, ep_ret, key):
        A = self.env.num_agents

        def step_fn(carry, key_t):
            env_state, obs, ep_ret = carry
            k_act, k_env = jax.random.split(key_t)
            actions = jnp.zeros((A,), jnp.int32)
            logps = jnp.zeros((A,), jnp.float32)
            values = jnp.zeros((A,), jnp.float32)
            for pid, pol in self.policies.items():
                idx = self._agents_of(pid)
                o = obs[idx]
                if self.algos[pid] == "dqn":
                    a, lp, v, _ = pol.act(params[pid], o, k_act, jnp.asarray(self.epsilon))
                else:
                    a, lp, v, _ = pol.act(params[pid], o, k_act)
                actions = actions.at[idx].set(a.astype(jnp.int32))
                logps = logps.at[idx].set(lp)
                values = values.at[idx].set(v)
            env_state, next_obs, reward, done = self.env.step(env_state, actions, k_env)
            new_ret = ep_ret + reward
            completed = jnp.where(done, new_ret, 0.0)
            ep_ret = jnp.where(done, 0.0, new_ret)
            out = {
                "obs": obs,
                "actions": actions,
                "rewards": reward,
                "dones": done.astype(jnp.float32),
                "logp": logps,
                "values": values,
                "next_obs": next_obs,
                "completed": completed,
            }
            return (env_state, next_obs, ep_ret), out

        keys = jax.random.split(key, self.rollout_len)
        (env_state, obs, ep_ret), cols = jax.lax.scan(step_fn, (env_state, obs, ep_ret), keys)
        adv, ret = gae(
            cols["rewards"], cols["values"], cols["dones"],
            jnp.zeros_like(ep_ret), self.gamma, self.lam,
        )
        cols["advantages"] = adv
        cols["returns"] = ret
        return env_state, obs, ep_ret, cols

    def sample(self) -> MultiAgentBatch:
        self._key, k = jax.random.split(self._key)
        self.env_state, self.obs, self._ep_returns, cols = self._rollout_jit(
            self.params, self.env_state, self.obs, self._ep_returns, k
        )
        completed = np.asarray(cols.pop("completed"))
        for r in completed[completed != 0.0]:
            self._completed.append(float(r))
        # Split per policy: columns are [T, A, ...].
        batches = {}
        for pid in self.policies:
            idx = self._agents_of(pid)
            sub = {k_: np.asarray(v)[:, idx] for k_, v in cols.items()}
            if self.algos[pid] == "dqn":
                sub.pop("logp", None)
                sub.pop("values", None)
                sub.pop("advantages", None)
                sub.pop("returns", None)
            batches[pid] = _to_numpy_batch(sub)
        return MultiAgentBatch(batches)

    def _learn(self, pid: str, params, target_params, opt_state, batch, key):
        pol = self.policies[pid]
        if self.algos[pid] == "dqn":
            loss_fn = lambda p: pol.loss(p, target_params, batch)
        else:
            loss_fn = lambda p: pol.loss(p, batch)
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state = self.optimizers[pid].apply(params, grads, opt_state)
        return params, opt_state, loss, aux

    def learn_on_batch(self, batch: SampleBatch, policy_id: str = "ppo_policy") -> Dict[str, Any]:
        dev = {k: jnp.asarray(v) for k, v in batch.items() if k != "batch_indices"}
        self._key, k = jax.random.split(self._key)
        self.params[policy_id], self.opt_states[policy_id], loss, aux = self._learn_jits[
            policy_id
        ](self.params[policy_id], self.target_params[policy_id], self.opt_states[policy_id], dev, k)
        info: Dict[str, Any] = {"loss": float(loss)}
        if "td_error" in aux:
            info["td_error"] = np.asarray(aux["td_error"])
        return info

    def update_target(self) -> None:
        for pid in self.policies:
            if self.algos[pid] == "dqn":
                self.target_params[pid] = jax.tree_util.tree_map(
                    jnp.array, self.params[pid]
                )

    def get_weights(self) -> Dict[str, PyTree]:
        return dict(self.params)

    def set_weights(self, weights: Dict[str, PyTree]) -> None:
        self.params.update(weights)

    def episode_stats(self) -> Dict[str, float]:
        if not self._completed:
            return {"episode_reward_mean": float("nan"), "episodes": 0}
        return {
            "episode_reward_mean": float(np.mean(self._completed)),
            "episodes": len(self._completed),
        }
