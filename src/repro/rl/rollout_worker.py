"""RolloutWorker: the actor target used by every dataflow plan.

Owns: a vectorized JAX env, a policy, params (+ target params for off-policy
algos), optimizer state, and RNG.  The entire T-step × B-env rollout compiles
to a single ``lax.scan`` XLA program; ``learn_on_batch`` is likewise one jitted
update.  The dataflow layer composes these via the worker protocol
(sample / get_weights / set_weights / compute_gradients / apply_gradients /
learn_on_batch / update_target).
"""

from __future__ import annotations

import functools
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import fused_gae as gae
from repro.optim import Optimizer, adam
from repro.rl.env import Env, VectorEnv, VectorEnvState
from repro.rl.sample_batch import MultiAgentBatch, SampleBatch

PyTree = Any

__all__ = [
    "RolloutWorker",
    "MultiAgentRolloutWorker",
    "VectorizedRolloutWorker",
    "PerEnvRolloutWorker",
    "assemble_fragments",
]

# Episode-id layout: eps_id = (worker_index * MAX_LANES + lane) * EPS_STRIDE
# + per-lane episode counter.  int64 gives ~2^43 worker-lanes' headroom.
MAX_LANES = 4096
EPS_STRIDE = 1 << 20


def _to_numpy_batch(cols: Dict[str, jax.Array]) -> SampleBatch:
    """[T, B, ...] device arrays -> batch-major flattened numpy SampleBatch.

    Batch-major flattening keeps each env's length-T trace contiguous, which
    the v-trace loss relies on to reshape back to time-major.
    """
    out = {}
    for k, v in cols.items():
        v = np.asarray(v)
        v = v.swapaxes(0, 1)  # [B, T, ...]
        out[k] = v.reshape((-1,) + v.shape[2:])
    return SampleBatch(out)


def assemble_fragments(cols: Dict[str, Any], lane_base: np.ndarray) -> SampleBatch:
    """[T, B, ...] rollout columns -> one batch-major SampleBatch whose rows
    carry globally unique int64 ``eps_id`` episode-fragment labels.

    The ``eps_count`` column (each step's per-lane episode index, int32) is
    consumed and replaced by ``eps_id = lane_base[lane] * EPS_STRIDE +
    eps_count``; ``lane_base`` must be globally unique per (worker, lane)
    (see ``MAX_LANES``).  Row order is batch-major, so every lane's length-T
    trace stays contiguous and, within a lane, episode fragments are
    contiguous runs — ``SampleBatch.split_by_episode()`` recovers exactly
    the per-episode fragments, and any slice/concat/shard that respects
    lane boundaries preserves fragment boundaries.
    """
    cols = dict(cols)
    eps_count = np.asarray(cols.pop("eps_count"))  # [T, B]
    batch = _to_numpy_batch(cols)
    lane_base = np.asarray(lane_base, np.int64)
    if lane_base.shape != (eps_count.shape[1],):
        raise ValueError(
            f"lane_base shape {lane_base.shape} != (num_lanes,)={eps_count.shape[1:2]}"
        )
    eps_id = lane_base[:, None] * EPS_STRIDE + eps_count.T.astype(np.int64)  # [B, T]
    batch["eps_id"] = eps_id.reshape(-1)
    return batch


class RolloutWorker:
    def __init__(
        self,
        env: Env,
        policy: Any,
        algo: str = "pg",  # pg | ppo | vtrace | dqn | sac
        num_envs: int = 4,
        rollout_len: int = 64,
        optimizer: Optional[Optimizer] = None,
        gamma: float = 0.99,
        lam: float = 0.95,
        epsilon: float = 0.1,
        target_polyak: float = 0.0,  # 0 -> hard target copy
        seed: int = 0,
        worker_index: int = 0,
    ):
        self.env = env
        self.policy = policy
        self.algo = algo
        self.num_envs = num_envs
        self.rollout_len = rollout_len
        self.gamma = gamma
        self.lam = lam
        self.epsilon = epsilon
        self.target_polyak = target_polyak
        self.worker_index = worker_index

        self._key = jax.random.PRNGKey(seed * 10007 + worker_index)
        self._key, pk, ek = jax.random.split(self._key, 3)
        self.params = policy.init_params(pk)
        self.target_params = jax.tree_util.tree_map(jnp.array, self.params)
        self.optimizer = optimizer or adam(3e-4)
        self.opt_state = self.optimizer.init(self.params)

        self._completed: deque = deque(maxlen=100)
        self._init_env_state(ek)

        self._learn_jit = jax.jit(self._learn)
        self._grad_jit = jax.jit(self._grads)
        self._apply_jit = jax.jit(self._apply)

    def _init_env_state(self, ek: jax.Array) -> None:
        """Build the worker's env-side state (subclass hook: the vectorized
        engine replaces the flat vmapped state with a ``VectorEnv``)."""
        env_keys = jax.random.split(ek, self.num_envs)
        self.env_state, self.obs = jax.vmap(self.env.reset)(env_keys)
        self._ep_returns = jnp.zeros((self.num_envs,), jnp.float32)
        self._rollout_jit = jax.jit(self._rollout)

    # --------------------------------------------------------------- rollout
    def _act(self, params: PyTree, obs: jax.Array, key: jax.Array):
        if self.algo == "dqn":
            return self.policy.act(params, obs, key, jnp.asarray(self.epsilon))
        return self.policy.act(params, obs, key)

    def _rollout(self, params: PyTree, env_state: Any, obs: jax.Array, ep_ret: jax.Array, key: jax.Array):
        def step_fn(carry, key_t):
            env_state, obs, ep_ret = carry
            k_act, k_env = jax.random.split(key_t)
            action, logp, value, _ = self._act(params, obs, k_act)
            env_keys = jax.random.split(k_env, self.num_envs)
            env_state, next_obs, reward, done = jax.vmap(self.env.step)(
                env_state, action, env_keys
            )
            new_ret = ep_ret + reward
            completed = jnp.where(done, new_ret, 0.0)
            ep_ret = jnp.where(done, 0.0, new_ret)
            out = {
                "obs": obs,
                "actions": action,
                "rewards": reward,
                "dones": done.astype(jnp.float32),
                "logp": logp,
                "values": value,
                "next_obs": next_obs,
                "completed": completed,
            }
            return (env_state, next_obs, ep_ret), out

        keys = jax.random.split(key, self.rollout_len)
        (env_state, obs, ep_ret), cols = jax.lax.scan(step_fn, (env_state, obs, ep_ret), keys)

        if self.algo in ("pg", "ppo"):
            _, _, last_value, _ = self._act(params, obs, keys[-1])
            adv, ret = gae(
                cols["rewards"], cols["values"], cols["dones"], last_value, self.gamma, self.lam
            )
            cols["advantages"] = adv
            cols["returns"] = ret
        return env_state, obs, ep_ret, cols

    def sample(self) -> SampleBatch:
        self._key, k = jax.random.split(self._key)
        self.env_state, self.obs, self._ep_returns, cols = self._rollout_jit(
            self.params, self.env_state, self.obs, self._ep_returns, k
        )
        completed = np.asarray(cols.pop("completed"))
        for r in completed[completed != 0.0]:
            self._completed.append(float(r))
        if self.algo in ("dqn", "sac"):
            for k_ in ("logp", "values"):
                cols.pop(k_, None)
        return _to_numpy_batch(cols)

    def sample_with_count(self) -> Tuple[SampleBatch, int]:
        b = self.sample()
        return b, b.count

    # ----------------------------------------------------------------- learn
    # NOTE: target_params must be an explicit argument (never closed over) or
    # jit would bake the trace-time snapshot in as a constant.
    def _loss_for(self, params: PyTree, target_params: PyTree, batch: Dict[str, jax.Array], key: jax.Array):
        if self.algo == "dqn":
            return self.policy.loss(params, target_params, batch)
        if self.algo == "sac":
            return self.policy.loss(params, target_params, batch, key)
        return self.policy.loss(params, batch)

    def _grads(self, params: PyTree, target_params: PyTree, batch: Dict[str, jax.Array], key: jax.Array):
        (loss, aux), grads = jax.value_and_grad(self._loss_for, has_aux=True)(
            params, target_params, batch, key
        )
        return grads, loss, aux

    def _apply(self, params: PyTree, opt_state: PyTree, grads: PyTree):
        return self.optimizer.apply(params, grads, opt_state)

    def _learn(self, params: PyTree, target_params: PyTree, opt_state: PyTree, batch: Dict[str, jax.Array], key: jax.Array):
        (loss, aux), grads = jax.value_and_grad(self._loss_for, has_aux=True)(
            params, target_params, batch, key
        )
        params, opt_state = self.optimizer.apply(params, grads, opt_state)
        return params, opt_state, loss, aux

    # Host-side metadata columns that never enter jitted losses (eps_id is
    # int64, which JAX would silently truncate without x64 mode).
    _HOST_COLUMNS = frozenset({"batch_indices", "eps_id"})

    @classmethod
    def _device_batch(cls, batch: SampleBatch) -> Dict[str, jax.Array]:
        return {
            k: jnp.asarray(v) for k, v in batch.items() if k not in cls._HOST_COLUMNS
        }

    def learn_on_batch(self, batch: SampleBatch, policy_id: Optional[str] = None) -> Dict[str, Any]:
        self._key, k = jax.random.split(self._key)
        self.params, self.opt_state, loss, aux = self._learn_jit(
            self.params, self.target_params, self.opt_state, self._device_batch(batch), k
        )
        info = {"loss": float(loss)}
        for name, v in aux.items():
            if name == "td_error":
                info["td_error"] = np.asarray(v)
            else:
                info[name] = float(v)
        self._post_update()
        return info

    def _post_update(self) -> None:
        """Per-update side effects beyond the optimizer step (single hook so
        sharded learner groups replay the exact same behaviour): SAC tracks
        its target network by polyak averaging."""
        if self.algo == "sac" and self.target_polyak > 0:
            tau = self.target_polyak
            self.target_params = jax.tree_util.tree_map(
                lambda t, p: (1 - tau) * t + tau * p, self.target_params, self.params
            )

    def compute_gradients(self, batch: SampleBatch) -> Tuple[PyTree, Dict[str, Any]]:
        self._key, k = jax.random.split(self._key)
        grads, loss, aux = self._grad_jit(
            self.params, self.target_params, self._device_batch(batch), k
        )
        info = {"loss": float(loss), "batch_count": batch.count}
        return grads, info

    def apply_gradients(self, grads: PyTree) -> None:
        self.params, self.opt_state = self._apply_jit(self.params, self.opt_state, grads)

    # ------------------------------------------------------------- messaging
    def get_weights(self) -> PyTree:
        return self.params

    def set_weights(self, weights: PyTree) -> None:
        self.params = weights

    def update_target(self) -> None:
        self.target_params = jax.tree_util.tree_map(jnp.array, self.params)

    def episode_stats(self) -> Dict[str, float]:
        if not self._completed:
            return {"episode_reward_mean": float("nan"), "episodes": 0}
        return {
            "episode_reward_mean": float(np.mean(self._completed)),
            "episodes": len(self._completed),
        }

    # ------------------------------------------------------------ durability
    def get_state(self) -> Dict[str, Any]:
        """Resumable rollout-side state (weights are checkpointed separately
        by ``Algorithm.save``): env auto-reset state, RNG, episode stats."""
        return {
            "key": np.asarray(self._key),
            "env_state": jax.tree_util.tree_map(np.asarray, self.env_state),
            "obs": np.asarray(self.obs),
            "ep_returns": np.asarray(self._ep_returns),
            "completed": list(self._completed),
        }

    def set_state(self, state: Dict[str, Any]) -> None:
        self._key = jnp.asarray(state["key"])
        self.env_state = jax.tree_util.tree_map(jnp.asarray, state["env_state"])
        self.obs = jnp.asarray(state["obs"])
        self._ep_returns = jnp.asarray(state["ep_returns"])
        self._completed = deque(state["completed"], maxlen=100)

    # --------------------------------------------------------------- MAML
    def inner_adapt(self, batch: SampleBatch) -> None:
        """One inner-loop PG step on worker-local params (first-order MAML)."""
        self.learn_on_batch(batch)

    def reset_inner(self) -> None:
        # Meta-params were just broadcast via set_weights; nothing else to do
        # because inner adaptation mutated self.params in place.
        pass


class VectorizedRolloutWorker(RolloutWorker):
    """Vectorized rollout engine: a ``VectorEnv`` stepped with one batched
    policy dispatch per step (``policy.compute_actions``, per-lane RNG).

    Differences from the base worker:

      * the whole T×N rollout is still one jitted ``lax.scan``, but env
        auto-reset, per-lane key chains, and episode accounting live in an
        explicit ``VectorEnvState`` — checkpointable (``get_state``) and
        reconfigurable at lowering time (``configure_vectorization``);
      * batches are assembled as per-episode *fragments*: every row carries
        a globally unique int64 ``eps_id``, plus ``terminateds``/
        ``truncateds`` split so consumers can tell env death from horizon
        cuts;
      * GAE routes through ``repro.kernels.ops.fused_gae`` with truncation-
        aware bootstrap: at a truncated step the successor value (from the
        TRUE pre-reset next obs) is folded into the reward, so advantage
        math is correct across artificial horizons;
      * optional decoupled inference (``inference='server'``): actions come
        from an ``InferenceActor`` via an ``InferenceClient`` (batched
        request per step, credit-bounded in flight).  If the server fails
        mid-rollout the in-flight fragment is dropped
        (``num_fragments_dropped``), the client's recovery path restarts
        the actor and re-syncs weights, and sampling resumes from the live
        env state;
      * optional cached decode (``decode='cache'``): a policy implementing
        the stateful-policy protocol (``init_lane_state`` /
        ``compute_actions_stateful``) carries per-lane model state — e.g.
        an LM's KV cache — through the rollout scan, so acting is one
        decode step per token instead of a full forward (the RLHF fast
        path; parity-gated in tests/bench_rlhf).
    """

    def __init__(
        self,
        env: Env,
        policy: Any,
        algo: str = "pg",
        num_envs: int = 8,
        rollout_len: int = 64,
        inference: str = "local",
        inference_client: Any = None,
        max_inference_retries: int = 3,
        decode: str = "forward",
        **kwargs: Any,
    ):
        if inference not in ("local", "server"):
            raise ValueError(f"unknown inference mode {inference!r}")
        if decode not in ("forward", "cache"):
            raise ValueError(f"unknown decode mode {decode!r}")
        if decode == "cache" and not hasattr(policy, "init_lane_state"):
            raise ValueError(
                "decode='cache' needs a stateful policy "
                "(init_lane_state/compute_actions_stateful)"
            )
        self.inference = inference
        self.inference_client = inference_client
        self.max_inference_retries = max_inference_retries
        self.num_fragments_dropped = 0
        self.decode = decode
        super().__init__(
            env, policy, algo=algo, num_envs=num_envs, rollout_len=rollout_len, **kwargs
        )

    # ------------------------------------------------------------ state init
    def _rebuild_plumbing(self) -> None:
        """(Re)derive everything that depends on ``self.num_envs``: the
        VectorEnv, lane-id bases, and the jitted entry points.  Called at
        init, on ``configure_vectorization(vector=...)`` resizes, and when
        ``set_state`` adopts a checkpoint taken at a different lane count."""
        if self.num_envs > MAX_LANES:
            raise ValueError(f"num_envs {self.num_envs} > MAX_LANES {MAX_LANES}")
        self.venv = VectorEnv(self.env, self.num_envs)
        self._lane_base = (
            self.worker_index * MAX_LANES + np.arange(self.num_envs, dtype=np.int64)
        )
        self._vrollout_jit = jax.jit(self._vrollout)
        self._postprocess_jit = jax.jit(self._postprocess_cols)
        self._vstep_jit = jax.jit(self.venv.step)
        self._act1_jit = jax.jit(self._act)

    def _init_env_state(self, ek: jax.Array) -> None:
        self._rebuild_plumbing()
        k_env, k_act = jax.random.split(ek)
        self.vstate = self.venv.reset(k_env)
        self.act_rng = jax.vmap(lambda i: jax.random.fold_in(k_act, i))(
            jnp.arange(self.num_envs)
        )
        self._reset_lane_state()

    def _reset_lane_state(self) -> None:
        """Fresh per-lane model state for the cached-decode path (an empty
        pytree when decode='forward', so the scan carry shape is uniform)."""
        self.lane_state = (
            self.policy.init_lane_state(self.num_envs) if self.decode == "cache" else {}
        )

    # -------------------------------------------------------------- lowering
    def configure_vectorization(
        self,
        vector: Optional[int] = None,
        inference: Optional[str] = None,
        client: Any = None,
        decode: Optional[str] = None,
    ) -> Dict[str, Any]:
        """Reconfigure lanes / inference mode / decode path (FlowSpec
        annotation lowering).

        Resizing rebuilds the ``VectorEnv`` with fresh per-lane key chains
        derived from the worker's RNG; switching to ``'server'`` without a
        client falls back to local inference (flagged in the ack), and
        ``decode='cache'`` on a policy without the stateful protocol falls
        back to ``'forward'`` likewise.
        """
        if vector is not None and int(vector) != self.num_envs:
            self.num_envs = int(vector)
            self._key, ek = jax.random.split(self._key)
            self._init_env_state(ek)
        if inference is not None:
            if inference not in ("local", "server"):
                raise ValueError(f"unknown inference mode {inference!r}")
            if client is not None:
                self.inference_client = client
            if inference == "server" and self.inference_client is None:
                inference = "local"
            self.inference = inference
        if decode is not None:
            if decode not in ("forward", "cache"):
                raise ValueError(f"unknown decode mode {decode!r}")
            if decode == "cache" and not hasattr(self.policy, "init_lane_state"):
                decode = "forward"
            if decode != self.decode:
                self.decode = decode
                self._reset_lane_state()
                self._vrollout_jit = jax.jit(self._vrollout)
        return {"vector": self.num_envs, "inference": self.inference, "decode": self.decode}

    # --------------------------------------------------------------- rollout
    def _compute_actions(self, params: PyTree, obs: jax.Array, keys: jax.Array):
        if self.algo == "dqn":
            return self.policy.compute_actions(
                params, obs, keys, jnp.asarray(self.epsilon)
            )
        return self.policy.compute_actions(params, obs, keys)

    def _vrollout(
        self, params: PyTree, vstate: VectorEnvState, act_rng: jax.Array, lane_state: PyTree
    ):
        stateful = self.decode == "cache"

        def step_fn(carry, _):
            vstate, act_rng, lstate = carry
            act_rng, k_act = VectorEnv._split_lanes(act_rng)
            obs = vstate.obs
            if stateful:
                action, logp, value, lstate = self.policy.compute_actions_stateful(
                    params, obs, k_act, lstate
                )
            else:
                action, logp, value, _ = self._compute_actions(params, obs, k_act)
            vstate, out = self.venv.step(vstate, action)
            cols = {
                "obs": obs,
                "actions": action,
                "rewards": out.reward,
                "dones": out.done.astype(jnp.float32),
                "terminateds": out.terminated.astype(jnp.float32),
                "truncateds": out.truncated.astype(jnp.float32),
                "logp": logp,
                "values": value,
                "next_obs": out.next_obs,
                "completed": out.completed_return,
                "eps_count": out.eps_count,
            }
            return (vstate, act_rng, lstate), cols

        (vstate, act_rng, lane_state), cols = jax.lax.scan(
            step_fn, (vstate, act_rng, lane_state), None, length=self.rollout_len
        )
        return vstate, act_rng, lane_state, cols

    def _postprocess_cols(self, params: PyTree, cols: Dict[str, jax.Array]):
        """Advantage columns over assembled [T, B] rollout columns.

        Shared verbatim by the vectorized and per-env paths (one jitted
        function object), so the two engines are bit-comparable downstream
        of acting.  Truncation bootstrap: the successor value (true
        pre-reset next obs) is folded into the reward at truncated steps,
        then the standard ``fused_gae`` runs with ``dones`` as the
        accumulation mask — identical math to explicit next-value GAE, but
        expressed through the existing kernel dispatch.
        """
        cols = dict(cols)
        if self.algo in ("pg", "ppo"):
            v_next = self.policy.value(params, cols["next_obs"])
            rewards_adj = cols["rewards"] + self.gamma * v_next * cols["truncateds"]
            adv, ret = gae(
                rewards_adj,
                cols["values"],
                cols["dones"],
                v_next[-1],
                self.gamma,
                self.lam,
            )
            cols["advantages"] = adv
            cols["returns"] = ret
        return cols

    def _record_completed(self, completed: np.ndarray) -> None:
        for r in completed.T.reshape(-1)[completed.T.reshape(-1) != 0.0]:
            self._completed.append(float(r))

    def _emit(self, cols: Dict[str, Any]) -> SampleBatch:
        """Post-scan host path shared by all inference modes."""
        cols = dict(self._postprocess_jit(self.params, cols))
        self._record_completed(np.asarray(cols.pop("completed")))
        if self.algo in ("dqn", "sac"):
            for k_ in ("logp", "values"):
                cols.pop(k_, None)
        return assemble_fragments(cols, self._lane_base)

    def sample(self) -> SampleBatch:
        if self.inference == "server":
            return self._sample_server()
        self.vstate, self.act_rng, self.lane_state, cols = self._vrollout_jit(
            self.params, self.vstate, self.act_rng, self.lane_state
        )
        return self._emit(cols)

    # ---------------------------------------------------- decoupled inference
    def _sample_server(self) -> SampleBatch:
        from repro.rl.inference import InferenceUnavailable

        attempts = 0
        while True:
            try:
                cols = self._server_rollout()
                return self._emit(cols)
            except InferenceUnavailable:
                # Drop ONLY the in-flight fragment: env state has advanced
                # to wherever acting stopped; collected step columns are
                # discarded, completed batches are untouched.
                self.num_fragments_dropped += 1
                attempts += 1
                if attempts > self.max_inference_retries:
                    raise
                self.inference_client.recover()

    def _server_rollout(self) -> Dict[str, np.ndarray]:
        # Routing clients (InferenceRouter) want the global lane ids so
        # stateful policies can be sticky-routed; plain clients/bare targets
        # keep the two-argument call (legacy fakes in the chaos suite).
        send_lanes = bool(getattr(self.inference_client, "wants_lanes", False))
        lanes = np.asarray(self._lane_base) if send_lanes else None
        steps: List[Dict[str, np.ndarray]] = []
        for _ in range(self.rollout_len):
            self.act_rng, k_act = VectorEnv._split_lanes(self.act_rng)
            obs = np.asarray(self.vstate.obs)
            if lanes is not None:
                action, logp, value = self.inference_client.compute_actions(
                    obs, np.asarray(k_act), lanes
                )
            else:
                action, logp, value = self.inference_client.compute_actions(
                    obs, np.asarray(k_act)
                )
            self.vstate, out = self._vstep_jit(self.vstate, jnp.asarray(action))
            steps.append(
                {
                    "obs": obs,
                    "actions": action,
                    "rewards": np.asarray(out.reward),
                    "dones": np.asarray(out.done, np.float32),
                    "terminateds": np.asarray(out.terminated, np.float32),
                    "truncateds": np.asarray(out.truncated, np.float32),
                    "logp": logp,
                    "values": value,
                    "next_obs": np.asarray(out.next_obs),
                    "completed": np.asarray(out.completed_return),
                    "eps_count": np.asarray(out.eps_count),
                }
            )
        return {k: np.stack([s[k] for s in steps]) for k in steps[0]}

    # ------------------------------------------------------------ durability
    def get_state(self) -> Dict[str, Any]:
        state = {
            "key": np.asarray(self._key),
            "vstate": VectorEnv.state_to_numpy(self.vstate),
            "act_rng": np.asarray(self.act_rng),
            "completed": list(self._completed),
            "num_fragments_dropped": self.num_fragments_dropped,
        }
        if self.decode == "cache":
            state["lane_state"] = jax.tree_util.tree_map(np.asarray, self.lane_state)
        return state

    def set_state(self, state: Dict[str, Any]) -> None:
        self._key = jnp.asarray(state["key"])
        self.vstate = VectorEnv.state_from_numpy(state["vstate"])
        self.act_rng = jnp.asarray(state["act_rng"])
        self._completed = deque(state["completed"], maxlen=100)
        self.num_fragments_dropped = int(state.get("num_fragments_dropped", 0))
        # Adopt the checkpoint's lane count: a state saved at vector=8
        # restored into a worker configured vector=4 must not leave stale
        # lane plumbing behind (the next sample would crash in assembly).
        lanes = int(self.act_rng.shape[0])
        if lanes != self.num_envs:
            self.num_envs = lanes
            self._rebuild_plumbing()
        if self.decode == "cache":
            ls = state.get("lane_state")
            # A checkpoint without lane state (taken under decode='forward')
            # restores to fresh caches; stale caches self-heal anyway — the
            # stateful policy re-prefills any lane whose cache position
            # disagrees with its observation.
            self.lane_state = (
                jax.tree_util.tree_map(jnp.asarray, ls)
                if ls is not None
                else self.policy.init_lane_state(self.num_envs)
            )

    def episode_stats(self) -> Dict[str, float]:
        stats = super().episode_stats()
        stats["fragments_dropped"] = float(self.num_fragments_dropped)
        return stats


class PerEnvRolloutWorker(VectorizedRolloutWorker):
    """The per-env reference loop: one policy dispatch *per env per step*.

    Identical key chains, env stepping, and fragment assembly as
    ``VectorizedRolloutWorker`` — only the inference dispatch differs (N
    single-obs calls instead of one batched call).  For elementwise envs/
    policies (``StubEnv`` + ``DummyPolicy``) the two engines are
    bit-identical; the determinism regression suite pins that down, and
    ``benchmarks/bench_rollout.py`` measures what the batching is worth.
    """

    def _rebuild_plumbing(self) -> None:
        super()._rebuild_plumbing()
        # Per-lane stepping uses an N=1 VectorEnv over lane slices: vmap
        # over one lane is elementwise-identical to lane i of the N-wide
        # step, so the env key chains match the vectorized engine exactly.
        self._venv1 = VectorEnv(self.env, 1)
        self._lane_step_jit = jax.jit(self._venv1.step)

    @staticmethod
    def _lane(tree: Any, i: int) -> Any:
        return jax.tree_util.tree_map(lambda x: x[i : i + 1], tree)

    def sample(self) -> SampleBatch:
        if self.inference == "server":
            return super().sample()
        B, T = self.num_envs, self.rollout_len
        lanes = [self._lane(self.vstate, i) for i in range(B)]
        act_rng = self.act_rng
        steps: List[Dict[str, np.ndarray]] = []
        for _ in range(T):
            act_rng, k_act = VectorEnv._split_lanes(act_rng)
            per_lane: List[Dict[str, np.ndarray]] = []
            for i in range(B):
                obs_i = lanes[i].obs[0]
                a, logp, value, _ = self._act1_jit(self.params, obs_i, k_act[i])
                lanes[i], out = self._lane_step_jit(lanes[i], a[None])
                per_lane.append(
                    {
                        "obs": np.asarray(obs_i),
                        "actions": np.asarray(a),
                        "rewards": np.asarray(out.reward[0]),
                        "dones": np.asarray(out.done[0], np.float32),
                        "terminateds": np.asarray(out.terminated[0], np.float32),
                        "truncateds": np.asarray(out.truncated[0], np.float32),
                        "logp": np.asarray(logp),
                        "values": np.asarray(value),
                        "next_obs": np.asarray(out.next_obs[0]),
                        "completed": np.asarray(out.completed_return[0]),
                        "eps_count": np.asarray(out.eps_count[0]),
                    }
                )
            steps.append(
                {k: np.stack([p[k] for p in per_lane]) for k in per_lane[0]}
            )
        self.act_rng = act_rng
        self.vstate = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *lanes
        )
        cols = {k: np.stack([s[k] for s in steps]) for k in steps[0]}
        return self._emit(cols)


class MultiAgentRolloutWorker:
    """Multi-policy rollouts for the PPO+DQN composition (paper §5.3).

    Each agent index is mapped to a policy id; per-policy experiences are
    returned as a MultiAgentBatch.  Policies may use different algorithms
    (PPO and DQN here), which is exactly the composition the paper enables.
    """

    def __init__(
        self,
        env: Any,  # MultiAgentCartPole
        policy_specs: Dict[str, Dict[str, Any]],
        agent_to_policy: Dict[int, str],
        rollout_len: int = 32,
        gamma: float = 0.99,
        lam: float = 0.95,
        epsilon: float = 0.1,
        seed: int = 0,
        worker_index: int = 0,
    ):
        self.env = env
        self.rollout_len = rollout_len
        self.gamma = gamma
        self.lam = lam
        self.epsilon = epsilon
        self.agent_to_policy = dict(agent_to_policy)
        self._key = jax.random.PRNGKey(seed * 7919 + worker_index)

        self.policies: Dict[str, Any] = {}
        self.params: Dict[str, PyTree] = {}
        self.target_params: Dict[str, PyTree] = {}
        self.optimizers: Dict[str, Optimizer] = {}
        self.opt_states: Dict[str, PyTree] = {}
        self.algos: Dict[str, str] = {}
        for pid, spec in policy_specs.items():
            self._key, k = jax.random.split(self._key)
            self.policies[pid] = spec["policy"]
            self.algos[pid] = spec.get("algo", "ppo")
            self.params[pid] = spec["policy"].init_params(k)
            self.target_params[pid] = jax.tree_util.tree_map(jnp.array, self.params[pid])
            self.optimizers[pid] = spec.get("optimizer") or adam(3e-4)
            self.opt_states[pid] = self.optimizers[pid].init(self.params[pid])

        self._key, ek = jax.random.split(self._key)
        self.env_state, self.obs = env.reset(ek)
        self._ep_returns = jnp.zeros((env.num_agents,), jnp.float32)
        self._completed: deque = deque(maxlen=100)
        self._rollout_jit = jax.jit(self._rollout)
        self._learn_jits: Dict[str, Callable] = {
            pid: jax.jit(functools.partial(self._learn, pid)) for pid in self.policies
        }

    # Agents grouped by policy for vectorized acting.
    def _agents_of(self, pid: str):
        return np.array([a for a, p in self.agent_to_policy.items() if p == pid])

    def _rollout(self, params: Dict[str, PyTree], env_state, obs, ep_ret, key):
        A = self.env.num_agents

        def step_fn(carry, key_t):
            env_state, obs, ep_ret = carry
            k_act, k_env = jax.random.split(key_t)
            actions = jnp.zeros((A,), jnp.int32)
            logps = jnp.zeros((A,), jnp.float32)
            values = jnp.zeros((A,), jnp.float32)
            for pid, pol in self.policies.items():
                idx = self._agents_of(pid)
                o = obs[idx]
                if self.algos[pid] == "dqn":
                    a, lp, v, _ = pol.act(params[pid], o, k_act, jnp.asarray(self.epsilon))
                else:
                    a, lp, v, _ = pol.act(params[pid], o, k_act)
                actions = actions.at[idx].set(a.astype(jnp.int32))
                logps = logps.at[idx].set(lp)
                values = values.at[idx].set(v)
            env_state, next_obs, reward, done = self.env.step(env_state, actions, k_env)
            new_ret = ep_ret + reward
            completed = jnp.where(done, new_ret, 0.0)
            ep_ret = jnp.where(done, 0.0, new_ret)
            out = {
                "obs": obs,
                "actions": actions,
                "rewards": reward,
                "dones": done.astype(jnp.float32),
                "logp": logps,
                "values": values,
                "next_obs": next_obs,
                "completed": completed,
            }
            return (env_state, next_obs, ep_ret), out

        keys = jax.random.split(key, self.rollout_len)
        (env_state, obs, ep_ret), cols = jax.lax.scan(step_fn, (env_state, obs, ep_ret), keys)
        adv, ret = gae(
            cols["rewards"], cols["values"], cols["dones"],
            jnp.zeros_like(ep_ret), self.gamma, self.lam,
        )
        cols["advantages"] = adv
        cols["returns"] = ret
        return env_state, obs, ep_ret, cols

    def sample(self) -> MultiAgentBatch:
        self._key, k = jax.random.split(self._key)
        self.env_state, self.obs, self._ep_returns, cols = self._rollout_jit(
            self.params, self.env_state, self.obs, self._ep_returns, k
        )
        completed = np.asarray(cols.pop("completed"))
        for r in completed[completed != 0.0]:
            self._completed.append(float(r))
        # Split per policy: columns are [T, A, ...].
        batches = {}
        for pid in self.policies:
            idx = self._agents_of(pid)
            sub = {k_: np.asarray(v)[:, idx] for k_, v in cols.items()}
            if self.algos[pid] == "dqn":
                sub.pop("logp", None)
                sub.pop("values", None)
                sub.pop("advantages", None)
                sub.pop("returns", None)
            batches[pid] = _to_numpy_batch(sub)
        return MultiAgentBatch(batches)

    def _learn(self, pid: str, params, target_params, opt_state, batch, key):
        pol = self.policies[pid]
        if self.algos[pid] == "dqn":
            loss_fn = lambda p: pol.loss(p, target_params, batch)
        else:
            loss_fn = lambda p: pol.loss(p, batch)
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state = self.optimizers[pid].apply(params, grads, opt_state)
        return params, opt_state, loss, aux

    def learn_on_batch(self, batch: SampleBatch, policy_id: str = "ppo_policy") -> Dict[str, Any]:
        dev = {k: jnp.asarray(v) for k, v in batch.items() if k != "batch_indices"}
        self._key, k = jax.random.split(self._key)
        self.params[policy_id], self.opt_states[policy_id], loss, aux = self._learn_jits[
            policy_id
        ](self.params[policy_id], self.target_params[policy_id], self.opt_states[policy_id], dev, k)
        info: Dict[str, Any] = {"loss": float(loss)}
        if "td_error" in aux:
            info["td_error"] = np.asarray(aux["td_error"])
        return info

    def update_target(self) -> None:
        for pid in self.policies:
            if self.algos[pid] == "dqn":
                self.target_params[pid] = jax.tree_util.tree_map(
                    jnp.array, self.params[pid]
                )

    def get_weights(self) -> Dict[str, PyTree]:
        return dict(self.params)

    def set_weights(self, weights: Dict[str, PyTree]) -> None:
        self.params.update(weights)

    def episode_stats(self) -> Dict[str, float]:
        if not self._completed:
            return {"episode_reward_mean": float("nan"), "episodes": 0}
        return {
            "episode_reward_mean": float(np.mean(self._completed)),
            "episodes": len(self._completed),
        }
