"""Decoupled batched inference for the vectorized rollout engine.

SRL (Mei et al., 2023) and HybridFlow (Sheng et al., 2024) both separate
environment simulation from policy inference: env loops stay cheap and
numerous, while action computation is batched onto dedicated inference
workers.  Here that split rides the existing executor runtime:

  * ``InferenceActor`` — a plain worker *target* owning a policy + params
    and serving ``compute_actions(obs, keys)`` for whole lane batches in
    one jitted dispatch.  Wrap it in a ``VirtualActor`` (thread or process
    backend) to serve multiple rollout shards; the actor mailbox serializes
    requests, so each call is one batched policy dispatch.
  * ``CreditGate`` — a counting semaphore shared by every client of one
    actor: at most ``credits`` requests in flight across all shards
    (the PR 3 credit-based backpressure idea applied to the request path).
    Stall counts/time are recorded for introspection.
  * ``InferenceClient`` — the rollout-worker-side handle.  On actor failure
    it raises ``InferenceUnavailable`` (the worker drops its in-flight
    fragment); ``recover()`` restarts the actor through the supervision
    path and re-syncs weights from the canonical provider before the next
    rollout begins.

Process-backed *rollout* workers cannot hold a client (actor handles do not
pickle across the RPC boundary), so server inference is lowered only onto
thread-backend rollout workers — ``compile()`` falls back to local
inference elsewhere and says so.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

__all__ = [
    "InferenceActor",
    "InferenceClient",
    "InferenceUnavailable",
    "CreditGate",
]


class InferenceUnavailable(RuntimeError):
    """The inference server failed mid-request; the caller's in-flight
    rollout fragment must be dropped and the client recovered."""


class CreditGate:
    """Counting semaphore bounding in-flight inference requests.

    One gate is shared by every client of an inference actor, so the bound
    is global across rollout shards.  ``stalls``/``stall_time_s`` mirror the
    data plane's ``num_credit_stalls`` instrumentation.
    """

    def __init__(self, credits: int):
        if credits < 1:
            raise ValueError(f"credits must be >= 1 (got {credits})")
        self.credits = credits
        self._sem = threading.Semaphore(credits)
        self._lock = threading.Lock()
        self.stalls = 0
        self.stall_time_s = 0.0

    def acquire(self) -> None:
        if self._sem.acquire(blocking=False):
            return
        t0 = time.perf_counter()
        self._sem.acquire()
        with self._lock:
            self.stalls += 1
            self.stall_time_s += time.perf_counter() - t0

    def release(self) -> None:
        self._sem.release()


class InferenceActor:
    """Worker target serving batched action requests for one policy.

    Built from a policy *factory* so it is rebuildable by supervision (and
    picklable for process backends when the factory is module-level).  The
    jitted ``compute_actions`` path is exactly the vectorized worker's:
    per-lane keys, single dispatch for all lanes.
    """

    def __init__(
        self,
        policy_factory: Callable[[], Any],
        algo: str = "pg",
        epsilon: float = 0.1,
        seed: int = 0,
    ):
        import jax
        import jax.numpy as jnp

        self.policy = policy_factory()
        self.algo = algo
        self.epsilon = epsilon
        self.params = self.policy.init_params(jax.random.PRNGKey(seed))
        self.num_requests = 0
        self.num_lane_steps = 0
        self._jnp = jnp
        self._jit = jax.jit(self._dispatch)

    def _dispatch(self, params: Any, obs: Any, keys: Any):
        if self.algo == "dqn":
            return self.policy.compute_actions(
                params, obs, keys, self._jnp.asarray(self.epsilon)
            )
        return self.policy.compute_actions(params, obs, keys)

    def compute_actions(
        self, obs: np.ndarray, keys: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """[N, obs_dim] obs + [N, 2] lane keys -> (actions, logp, values)."""
        self.num_requests += 1
        self.num_lane_steps += int(obs.shape[0])
        action, logp, value, _ = self._jit(self.params, obs, keys)
        return np.asarray(action), np.asarray(logp), np.asarray(value)

    def compute_values(self, obs: np.ndarray) -> np.ndarray:
        """Value-only dispatch (GAE bootstrap queries)."""
        return np.asarray(self.policy.value(self.params, self._jnp.asarray(obs)))

    # ------------------------------------------------------------ messaging
    def set_weights(self, weights: Any) -> None:
        self.params = weights

    def get_weights(self) -> Any:
        return self.params

    def stats(self) -> Dict[str, int]:
        return {
            "num_requests": self.num_requests,
            "num_lane_steps": self.num_lane_steps,
        }


class InferenceClient:
    """Rollout-shard handle to a (possibly remote) ``InferenceActor``.

    ``actor`` is either a ``VirtualActor`` wrapping an ``InferenceActor``
    (``.call``/``.sync`` duck-typed) or a bare ``InferenceActor`` (direct
    in-process calls — useful in tests).  ``credits`` bounds requests in
    flight across every client sharing the gate.

    Failure contract: any actor-side failure surfaces as
    ``InferenceUnavailable``.  The *worker* decides what to drop (its
    in-flight fragment); ``recover()`` then heals the server — restart via
    the supervision path, plus a weight re-sync from ``weights_provider``
    (the canonical policy owner, normally the plan's local worker) so the
    restarted actor never serves stale or freshly-reinitialized weights.
    """

    def __init__(
        self,
        actor: Any,
        credits: Optional[CreditGate] = None,
        weights_provider: Optional[Callable[[], Any]] = None,
    ):
        self.actor = actor
        self.credits = credits
        self.weights_provider = weights_provider
        self.num_failures = 0
        self.num_recoveries = 0

    def _invoke(self, method: str, *args: Any) -> Any:
        actor = self.actor
        if hasattr(actor, "call"):  # VirtualActor
            try:
                return actor.call(method, *args).result()
            except Exception as exc:
                self.num_failures += 1
                raise InferenceUnavailable(
                    f"inference actor {getattr(actor, 'name', actor)!r} failed "
                    f"in {method}(): {exc!r}"
                ) from exc
        try:  # bare target (in-process)
            return getattr(actor, method)(*args)
        except Exception as exc:
            self.num_failures += 1
            raise InferenceUnavailable(f"inference target failed: {exc!r}") from exc

    def compute_actions(
        self, obs: np.ndarray, keys: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        if self.credits is not None:
            self.credits.acquire()
        try:
            return self._invoke("compute_actions", obs, keys)
        finally:
            if self.credits is not None:
                self.credits.release()

    def compute_values(self, obs: np.ndarray) -> np.ndarray:
        return self._invoke("compute_values", obs)

    def sync_weights(self, weights: Any = None) -> None:
        if weights is None and self.weights_provider is not None:
            weights = self.weights_provider()
        if weights is not None:
            self._invoke("set_weights", weights)

    def recover(self) -> None:
        """Heal the server: restart a dead VirtualActor (supervision path),
        then push canonical weights so the fresh target is in sync."""
        actor = self.actor
        if hasattr(actor, "restart") and not getattr(actor, "alive", True):
            actor.restart()
            self.num_recoveries += 1
        self.sync_weights()

    def stop(self) -> None:
        if hasattr(self.actor, "stop"):
            self.actor.stop()
