"""Decoupled batched inference: the serving tier of the rollout engine.

SRL (Mei et al., 2023) and HybridFlow (Sheng et al., 2024) both separate
environment simulation from policy inference: env loops stay cheap and
numerous, while action computation is batched onto dedicated inference
workers.  Here that split rides the existing executor runtime, grown into a
multi-replica serving tier (ISSUE 9):

  * ``AdmissionQueue`` — Orca-style continuous batching: requests are
    admitted/evicted per *dispatch step* (FIFO, up to ``max_occupancy``)
    instead of per fixed batch, with occupancy and admission-latency
    accounting.
  * ``InferenceActor`` — a worker *target* owning a policy + params.  Its
    native surface is ``submit``/``poll``: submissions from *different*
    clients interleaving through the actor mailbox are co-batched into one
    jitted dispatch per serve step.  ``compute_actions`` (submit + drain)
    keeps the original blocking call.  Policies exposing ``init_lane_state``
    / ``compute_actions_stateful`` (KV cache, SSM state — see
    ``repro.rl.stateful_policy``) keep their per-lane recurrent state
    server-side, keyed by global lane id.
  * ``CreditGate`` — a counting semaphore shared by every client of one
    serving tier: at most ``credits`` requests in flight across all shards
    (the PR 3 credit-based backpressure idea applied to the request path).
  * ``InferenceClient`` — the single-replica rollout-worker handle.  On
    actor failure it raises ``InferenceUnavailable`` (the worker drops its
    in-flight fragment); ``recover()`` restarts the actor through the
    supervision path and re-syncs weights before the next rollout begins.
  * ``InferenceRouter`` — N replicas behind the client API: least-loaded
    dispatch for stateless policies, **sticky lane->replica routing** for
    stateful ones (a lane's server-side state lives on exactly one
    replica), per-replica health + weight-version tracking (a replica that
    missed a ``sync_weights`` broadcast is refused until re-synced), and a
    ``restart``/``drop_shard`` recovery path that re-pins orphaned lanes
    with a state reset.

Process-backed *rollout* workers cannot hold a client (actor handles do not
pickle across the RPC boundary), so server inference is lowered only onto
thread-backend rollout workers — ``compile()`` falls back to local
inference elsewhere and says so.
"""

from __future__ import annotations

import logging
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.metrics import LatencyStat

__all__ = [
    "AdmissionQueue",
    "InferenceActor",
    "InferenceClient",
    "InferenceRouter",
    "InferenceUnavailable",
    "CreditGate",
]

logger = logging.getLogger(__name__)


class InferenceUnavailable(RuntimeError):
    """The inference server failed mid-request; the caller's in-flight
    rollout fragment must be dropped and the client recovered."""


class CreditGate:
    """Counting semaphore bounding in-flight inference requests.

    One gate is shared by every client of an inference tier, so the bound
    is global across rollout shards.  ``stalls``/``stall_time_s`` mirror the
    data plane's ``num_credit_stalls`` instrumentation.
    """

    def __init__(self, credits: int):
        if credits < 1:
            raise ValueError(f"credits must be >= 1 (got {credits})")
        self.credits = credits
        self._sem = threading.Semaphore(credits)
        self._lock = threading.Lock()
        self.stalls = 0
        self.stall_time_s = 0.0

    def acquire(self) -> None:
        if self._sem.acquire(blocking=False):
            return
        t0 = time.perf_counter()
        self._sem.acquire()
        with self._lock:
            self.stalls += 1
            self.stall_time_s += time.perf_counter() - t0

    def release(self) -> None:
        self._sem.release()


# --------------------------------------------------------------------------
# Continuous batching
# --------------------------------------------------------------------------
class AdmissionQueue:
    """Admission control for continuous batching (Orca-style).

    Requests move ``pending -> active -> (completed | evicted)``; every
    transition happens at a *dispatch step* boundary (``admit``), never per
    fixed batch: a step admits pending requests FIFO up to
    ``max_occupancy`` free slots, serves the whole active set, and the
    server completes (or evicts) them individually.  Invariants the
    property suite pins down:

      * conservation — every submitted id is in exactly one of
        pending/active/completed/evicted at all times;
      * FIFO fairness — ids are admitted in submission order (no pending
        request is overtaken by a later submission);
      * bounded occupancy — ``len(active) <= max_occupancy`` always.

    ``max_occupancy=None`` means unbounded: a whole lane batch admits in
    one step, which keeps single-client serving bit-identical to a fixed
    whole-batch dispatch.
    """

    def __init__(self, max_occupancy: Optional[int] = None):
        if max_occupancy is not None and max_occupancy < 1:
            raise ValueError(f"max_occupancy must be >= 1 (got {max_occupancy})")
        self.max_occupancy = max_occupancy
        self._lock = threading.Lock()
        self._pending: deque = deque()  # (req_id, t_submit)
        self._active: Dict[Any, float] = {}  # req_id -> t_submit
        self.num_submitted = 0
        self.num_admitted = 0
        self.num_completed = 0
        self.num_evicted = 0
        self.occupancy_peak = 0
        self._occ_sum = 0.0
        self._steps = 0
        self.admission_wait = LatencyStat()

    @property
    def occupancy(self) -> int:
        return len(self._active)

    def submit(self, req_id: Any) -> None:
        with self._lock:
            if req_id in self._active or any(r == req_id for r, _ in self._pending):
                raise ValueError(f"request {req_id!r} already queued")
            self._pending.append((req_id, time.perf_counter()))
            self.num_submitted += 1

    def admit(self) -> List[Any]:
        """One dispatch step's admission: pending -> active, FIFO, up to the
        configured occupancy.  Returns the ids admitted *this step* (the
        server batches them together with anything still active)."""
        with self._lock:
            now = time.perf_counter()
            free = (
                len(self._pending)
                if self.max_occupancy is None
                else self.max_occupancy - len(self._active)
            )
            admitted: List[Any] = []
            while self._pending and len(admitted) < max(0, free):
                rid, t0 = self._pending.popleft()
                self._active[rid] = t0
                self.admission_wait.push(now - t0)
                admitted.append(rid)
            self.num_admitted += len(admitted)
            occ = len(self._active)
            self.occupancy_peak = max(self.occupancy_peak, occ)
            self._occ_sum += occ
            self._steps += 1
            return admitted

    def complete(self, ids: Sequence[Any]) -> None:
        with self._lock:
            for rid in ids:
                if rid not in self._active:
                    raise ValueError(f"request {rid!r} is not active")
                del self._active[rid]
                self.num_completed += 1

    def evict(self, ids: Sequence[Any]) -> int:
        """Drop requests (cancel/failure path) from active *or* pending."""
        with self._lock:
            dropped = 0
            for rid in ids:
                if rid in self._active:
                    del self._active[rid]
                    dropped += 1
                else:
                    n = len(self._pending)
                    self._pending = deque(
                        (r, t) for r, t in self._pending if r != rid
                    )
                    dropped += n - len(self._pending)
            self.num_evicted += dropped
            return dropped

    def stats(self) -> Dict[str, float]:
        with self._lock:
            wait = self.admission_wait.summary()
            return {
                "max_occupancy": -1.0 if self.max_occupancy is None else float(self.max_occupancy),
                "occupancy": float(len(self._active)),
                "occupancy_peak": float(self.occupancy_peak),
                "occupancy_mean": self._occ_sum / self._steps if self._steps else 0.0,
                "num_steps": float(self._steps),
                "num_submitted": float(self.num_submitted),
                "num_admitted": float(self.num_admitted),
                "num_completed": float(self.num_completed),
                "num_evicted": float(self.num_evicted),
                "admission_wait_mean_s": wait["mean"],
                "admission_wait_p50_s": wait["p50"],
                "admission_wait_p99_s": wait["p99"],
            }


# --------------------------------------------------------------------------
# The serving replica
# --------------------------------------------------------------------------
class InferenceActor:
    """Worker target serving batched action requests for one policy.

    Built from a policy *factory* so it is rebuildable by supervision (and
    picklable for process backends when the factory is module-level).

    The native serving surface is asynchronous: ``submit`` enqueues one
    request per lane row into the admission queue, ``poll`` drives at most
    one serve step when the caller's requests are not done yet.  A serve
    step co-batches *every* admitted request — whichever client submitted
    it — into one jitted dispatch, which is what makes interleaved
    submissions from multiple rollout shards continuous-batched rather
    than serialized per caller.  ``compute_actions`` is submit + drain.

    Stateful policies (``init_lane_state``/``compute_actions_stateful``)
    keep per-lane recurrent state here, keyed by the caller's global lane
    id; ``reset_lanes`` drops it (router re-pin path).
    """

    def __init__(
        self,
        policy_factory: Callable[[], Any],
        algo: str = "pg",
        epsilon: float = 0.1,
        seed: int = 0,
        max_batch: Optional[int] = None,
    ):
        import jax
        import jax.numpy as jnp

        self.policy = policy_factory()
        self.algo = algo
        self.epsilon = epsilon
        self.params = self.policy.init_params(jax.random.PRNGKey(seed))
        self.stateful = hasattr(self.policy, "init_lane_state")
        self.num_requests = 0
        self.num_lane_steps = 0
        self.num_dispatches = 0
        self.queue = AdmissionQueue(max_batch)
        self._req_seq = 0
        self._requests: Dict[int, Tuple[np.ndarray, np.ndarray, Optional[int]]] = {}
        self._results: Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
        self._lane_state: Dict[int, Any] = {}
        self._jnp = jnp
        self._tree = jax.tree_util
        self._jit = jax.jit(self._dispatch)
        if self.stateful:
            self._jit_stateful = jax.jit(self._dispatch_stateful)

    def _dispatch(self, params: Any, obs: Any, keys: Any):
        if self.algo == "dqn":
            return self.policy.compute_actions(
                params, obs, keys, self._jnp.asarray(self.epsilon)
            )
        return self.policy.compute_actions(params, obs, keys)

    def _dispatch_stateful(self, params: Any, obs: Any, keys: Any, state: Any):
        return self.policy.compute_actions_stateful(params, obs, keys, state)

    # ------------------------------------------------------- async serving
    def submit(
        self,
        obs: np.ndarray,
        keys: np.ndarray,
        lanes: Optional[np.ndarray] = None,
    ) -> List[int]:
        """Enqueue one request per lane row; returns the request ids."""
        obs, keys = np.asarray(obs), np.asarray(keys)
        if self.stateful and lanes is None:
            raise ValueError(
                "stateful policy serving needs lanes= (per-row global lane "
                "ids keying the server-side recurrent state)"
            )
        self.num_requests += 1
        self.num_lane_steps += int(obs.shape[0])
        ids: List[int] = []
        for i in range(obs.shape[0]):
            rid = self._req_seq
            self._req_seq += 1
            lane = None if lanes is None else int(np.asarray(lanes)[i])
            self._requests[rid] = (obs[i], keys[i], lane)
            self.queue.submit(rid)
            ids.append(rid)
        return ids

    def serve_step(self) -> int:
        """Admit + dispatch one continuous-batching step; returns the number
        of requests served (0 when nothing is pending).

        The dispatch batch is padded up to the next power of two (row 0
        repeated; padded results discarded): continuous batching and sticky
        sub-batch splits produce arbitrary batch sizes, and without shape
        bucketing every new size would pay an XLA recompile mid-serve.
        Policies dispatch per-row (vmapped), so padding never changes the
        real rows' results."""
        ids = self.queue.admit()
        if not ids:
            return 0
        n = len(ids)
        pad = (1 << max(0, n - 1).bit_length()) - n
        rows = [self._requests[rid] for rid in ids]
        obs = np.stack([r[0] for r in rows])
        keys = np.stack([r[1] for r in rows])
        if pad:
            obs = np.concatenate([obs, np.repeat(obs[:1], pad, axis=0)])
            keys = np.concatenate([keys, np.repeat(keys[:1], pad, axis=0)])
        if self.stateful:
            init = None
            states = []
            for r in rows:
                s = self._lane_state.get(r[2])
                if s is None:
                    if init is None:
                        init = self.policy.init_lane_state(1)
                    s = init
                states.append(s)
            if pad:
                states.append(self.policy.init_lane_state(pad))
            batch_state = self._tree.tree_map(
                lambda *xs: self._jnp.concatenate(xs, axis=0), *states
            )
            action, logp, value, new_state = self._jit_stateful(
                self.params, obs, keys, batch_state
            )
            for j, r in enumerate(rows):
                self._lane_state[r[2]] = self._tree.tree_map(
                    lambda x, j=j: x[j : j + 1], new_state
                )
        else:
            action, logp, value, _ = self._jit(self.params, obs, keys)
        action, logp, value = np.asarray(action), np.asarray(logp), np.asarray(value)
        for j, rid in enumerate(ids):
            self._results[rid] = (action[j], logp[j], value[j])
            del self._requests[rid]
        self.queue.complete(ids)
        self.num_dispatches += 1
        return n

    def poll(
        self, ids: Sequence[int]
    ) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Collect results for ``ids``; drives at most one serve step when
        they are not all done yet (returning None — the caller loops)."""
        if not all(rid in self._results for rid in ids):
            self.serve_step()
            if not all(rid in self._results for rid in ids):
                return None
        rows = [self._results.pop(rid) for rid in ids]
        return (
            np.stack([r[0] for r in rows]),
            np.stack([r[1] for r in rows]),
            np.stack([r[2] for r in rows]),
        )

    def discard(self, ids: Sequence[int]) -> int:
        """Cancel requests (failure cleanup): evict queued ones, drop any
        results already computed."""
        dropped = self.queue.evict([rid for rid in ids if rid in self._requests])
        for rid in ids:
            self._requests.pop(rid, None)
            if self._results.pop(rid, None) is not None:
                dropped += 1
        return dropped

    # ---------------------------------------------------- blocking serving
    def compute_actions(
        self,
        obs: np.ndarray,
        keys: np.ndarray,
        lanes: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """[N, obs_dim] obs + [N, 2] lane keys -> (actions, logp, values).

        Blocking submit + drain.  With the default unbounded admission this
        is a single whole-batch jitted dispatch — bit-identical to fixed
        batching; with ``max_batch`` set the batch is served in FIFO
        chunks."""
        ids = self.submit(obs, keys, lanes)
        while True:
            out = self.poll(ids)
            if out is not None:
                return out

    def compute_values(self, obs: np.ndarray) -> np.ndarray:
        """Value-only dispatch (GAE bootstrap queries)."""
        return np.asarray(self.policy.value(self.params, self._jnp.asarray(obs)))

    # --------------------------------------------------------- lane state
    def reset_lanes(self, lanes: Sequence[int]) -> int:
        """Drop server-side recurrent state for ``lanes`` (re-pin path)."""
        n = 0
        for lane in lanes:
            if self._lane_state.pop(int(lane), None) is not None:
                n += 1
        return n

    # ------------------------------------------------------------ messaging
    def set_weights(self, weights: Any) -> None:
        self.params = weights

    def get_weights(self) -> Any:
        return self.params

    def stats(self) -> Dict[str, Any]:
        return {
            "num_requests": self.num_requests,
            "num_lane_steps": self.num_lane_steps,
            "num_dispatches": self.num_dispatches,
            "stateful": self.stateful,
            "num_lane_states": len(self._lane_state),
            "queue": self.queue.stats(),
        }


class InferenceClient:
    """Rollout-shard handle to a (possibly remote) ``InferenceActor``.

    ``actor`` is either a ``VirtualActor`` wrapping an ``InferenceActor``
    (``.call``/``.sync`` duck-typed) or a bare ``InferenceActor`` (direct
    in-process calls — useful in tests).  ``credits`` bounds requests in
    flight across every client sharing the gate.

    Failure contract: any actor-side failure surfaces as
    ``InferenceUnavailable``.  The *worker* decides what to drop (its
    in-flight fragment); ``recover()`` then heals the server — restart via
    the supervision path, plus a weight re-sync from ``weights_provider``
    (the canonical policy owner, normally the plan's local worker) so the
    restarted actor never serves stale or freshly-reinitialized weights.
    """

    wants_lanes = False  # single replica: no routing key needed

    def __init__(
        self,
        actor: Any,
        credits: Optional[CreditGate] = None,
        weights_provider: Optional[Callable[[], Any]] = None,
    ):
        self.actor = actor
        self.credits = credits
        self.weights_provider = weights_provider
        self.num_failures = 0
        self.num_recoveries = 0

    def _invoke(self, method: str, *args: Any) -> Any:
        actor = self.actor
        if hasattr(actor, "call"):  # VirtualActor
            try:
                return actor.call(method, *args).result()
            except Exception as exc:
                self.num_failures += 1
                raise InferenceUnavailable(
                    f"inference actor {getattr(actor, 'name', actor)!r} failed "
                    f"in {method}(): {exc!r}"
                ) from exc
        try:  # bare target (in-process)
            return getattr(actor, method)(*args)
        except Exception as exc:
            self.num_failures += 1
            raise InferenceUnavailable(f"inference target failed: {exc!r}") from exc

    def compute_actions(
        self,
        obs: np.ndarray,
        keys: np.ndarray,
        lanes: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        if self.credits is not None:
            self.credits.acquire()
        try:
            if lanes is not None:
                return self._invoke("compute_actions", obs, keys, lanes)
            return self._invoke("compute_actions", obs, keys)
        finally:
            if self.credits is not None:
                self.credits.release()

    def compute_values(self, obs: np.ndarray) -> np.ndarray:
        return self._invoke("compute_values", obs)

    def sync_weights(self, weights: Any = None) -> None:
        if weights is None and self.weights_provider is not None:
            weights = self.weights_provider()
        if weights is not None:
            self._invoke("set_weights", weights)

    def recover(self) -> None:
        """Heal the server: restart a dead VirtualActor (supervision path),
        then push canonical weights so the fresh target is in sync."""
        actor = self.actor
        if hasattr(actor, "restart") and not getattr(actor, "alive", True):
            actor.restart()
            self.num_recoveries += 1
        self.sync_weights()

    def stop(self) -> None:
        if hasattr(self.actor, "stop"):
            self.actor.stop()


# --------------------------------------------------------------------------
# Multi-replica routing
# --------------------------------------------------------------------------
class _Replica:
    """Router-side record for one serving replica."""

    __slots__ = ("actor", "inflight", "weight_version", "failures")

    def __init__(self, actor: Any):
        self.actor = actor
        self.inflight = 0
        self.weight_version = 0
        self.failures = 0

    @property
    def name(self) -> str:
        return getattr(self.actor, "name", type(self.actor).__name__)

    @property
    def alive(self) -> bool:
        return getattr(self.actor, "alive", True)

    def is_virtual(self) -> bool:
        return hasattr(self.actor, "call")


class _Immediate:
    """Future-shaped wrapper for bare-target results."""

    def __init__(self, value: Any):
        self._value = value

    def result(self) -> Any:
        return self._value


class InferenceRouter:
    """N ``InferenceActor`` replicas behind the ``InferenceClient`` API.

    Dispatch policy:

      * stateless replicas — **least-loaded**: the whole request batch goes
        to the eligible replica with the fewest in-flight requests (whole-
        batch dispatch keeps single-client serving bit-identical to one
        local inference).
      * stateful replicas — **sticky lane->replica routing**: each global
        lane id is pinned to one replica (its KV/SSM state lives there);
        a request batch is partitioned by pin and the sub-batches are
        dispatched concurrently through the replicas' submit/poll surface.

    A replica is *eligible* when it is alive AND its acked weight version
    matches the router's: a replica that was down during a ``sync_weights``
    broadcast — even one restarted out-of-band afterwards — is refused
    until ``recover()`` re-syncs it, so stale weights never serve.

    Failure contract matches ``InferenceClient``: a replica failing
    mid-request raises ``InferenceUnavailable`` (in-flight rows counted in
    ``num_inflight_dropped``; the caller drops its fragment).  ``recover()``
    then heals per ``failure_policy``: ``'restart'`` rebuilds dead replicas
    through supervision and re-syncs weights; ``'drop_shard'`` removes them
    from the set.  Either way, lanes pinned to a lost replica are unpinned
    (their server-side state is gone) and re-pin onto survivors with a
    fresh state — counted in ``num_lane_repins``/``num_lane_state_resets``.
    """

    wants_lanes = True  # sticky routing needs the caller's global lane ids

    def __init__(
        self,
        replicas: Sequence[Any],
        credits: Optional[CreditGate] = None,
        weights_provider: Optional[Callable[[], Any]] = None,
        sticky: Optional[bool] = None,
        failure_policy: str = "restart",
        name: str = "inference-router",
    ):
        if not replicas:
            raise ValueError("InferenceRouter needs at least one replica")
        if failure_policy not in ("restart", "drop_shard"):
            raise ValueError(
                f"failure_policy must be 'restart'|'drop_shard' (got {failure_policy!r})"
            )
        self.name = name
        self.credits = credits
        self.weights_provider = weights_provider
        self.failure_policy = failure_policy
        self.weight_version = 0
        self._replicas: List[_Replica] = [_Replica(a) for a in replicas]
        self._pins: Dict[int, _Replica] = {}
        self._lock = threading.Lock()
        self._recover_lock = threading.Lock()
        self._sticky = sticky
        self.num_requests = 0
        self.num_lane_requests = 0
        self.num_failures = 0  # kept name-compatible with InferenceClient
        self.num_recoveries = 0
        self.num_replica_failures = 0
        self.num_replica_restarts = 0
        self.num_replicas_dropped = 0
        self.num_inflight_dropped = 0
        self.num_lane_repins = 0
        self.num_lane_state_resets = 0

    # ---------------------------------------------------------- inspection
    @property
    def sticky(self) -> bool:
        if self._sticky is None:
            self._sticky = self._probe_stateful()
        return self._sticky

    def _probe_stateful(self) -> bool:
        rep = self._replicas[0]
        if not rep.is_virtual():
            return bool(getattr(rep.actor, "stateful", False))
        try:
            return bool(rep.actor.sync("stats").get("stateful", False))
        except Exception:  # dead/opaque replica: assume stateless
            return False

    def _eligible(self) -> List[_Replica]:
        return [
            r
            for r in self._replicas
            if r.alive and r.weight_version == self.weight_version
        ]

    @property
    def replicas(self) -> List[Any]:
        return [r.actor for r in self._replicas]

    # ------------------------------------------------------------- serving
    def compute_actions(
        self,
        obs: np.ndarray,
        keys: np.ndarray,
        lanes: Optional[np.ndarray] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        if self.credits is not None:
            self.credits.acquire()
        try:
            return self._route(obs, keys, lanes)
        finally:
            if self.credits is not None:
                self.credits.release()

    def _route(
        self, obs: np.ndarray, keys: np.ndarray, lanes: Optional[np.ndarray]
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        obs, keys = np.asarray(obs), np.asarray(keys)
        n = int(obs.shape[0])
        with self._lock:
            self.num_requests += 1
            self.num_lane_requests += n
        eligible = self._eligible()
        if not eligible:
            self.num_failures += 1
            raise InferenceUnavailable(
                f"router {self.name!r}: no eligible replicas "
                f"({len(self._replicas)} known, weight_version={self.weight_version})"
            )
        if self.sticky and lanes is not None:
            groups = self._sticky_groups(np.asarray(lanes), eligible)
        else:
            rep = min(eligible, key=lambda r: r.inflight)
            groups = [(rep, np.arange(n))]
        return self._dispatch_groups(groups, obs, keys, lanes)

    def _sticky_groups(
        self, lanes: np.ndarray, eligible: List[_Replica]
    ) -> List[Tuple[_Replica, np.ndarray]]:
        """Partition rows by pinned replica, pinning new lanes least-loaded.

        All of a request's *new* lanes pin together to one least-loaded
        replica (session affinity): pinning per-lane would shred every
        request into tiny sub-batches across all replicas, destroying the
        batching that makes the tier fast — affinity keeps whole requests
        dispatching as one batch while different clients' lane sets still
        balance across replicas.

        A lane pinned to a replica that is no longer eligible fails the
        request (the pin is only moved by ``recover()``, which also resets
        the lane's server-side state): silently re-pinning here would serve
        from a replica that never saw the lane's recurrent state.
        """
        by_rep: Dict[int, List[int]] = {}
        reps: Dict[int, _Replica] = {}
        with self._lock:
            load = {id(r): r.inflight for r in eligible}
            new_rep: Optional[_Replica] = None
            for i, lane in enumerate(int(x) for x in lanes):
                rep = self._pins.get(lane)
                if rep is None:
                    if new_rep is None:
                        new_rep = min(eligible, key=lambda r: (load[id(r)], r.name))
                    rep = new_rep
                    self._pins[lane] = rep
                elif rep not in self._replicas or not (
                    rep.alive and rep.weight_version == self.weight_version
                ):
                    self.num_failures += 1
                    self.num_replica_failures += 1
                    raise InferenceUnavailable(
                        f"router {self.name!r}: lane {lane} is pinned to "
                        f"ineligible replica {rep.name!r}; recover() to re-pin"
                    )
                load[id(rep)] = load.get(id(rep), 0) + 1
                by_rep.setdefault(id(rep), []).append(i)
                reps[id(rep)] = rep
        return [(reps[k], np.asarray(idx)) for k, idx in by_rep.items()]

    def _dispatch_groups(
        self,
        groups: List[Tuple[_Replica, np.ndarray]],
        obs: np.ndarray,
        keys: np.ndarray,
        lanes: Optional[np.ndarray],
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Dispatch per-replica sub-batches; submit-all-then-poll so groups
        run concurrently across replicas, then reassemble rows in order."""
        pending: List[Tuple[_Replica, np.ndarray, Any]] = []
        failed: Optional[Tuple[_Replica, int, Exception]] = None
        for rep, idx in groups:
            sub_lanes = None if lanes is None else np.asarray(lanes)[idx]
            with self._lock:
                rep.inflight += len(idx)
            try:
                if rep.is_virtual():
                    ids_f = rep.actor.call("submit", obs[idx], keys[idx], sub_lanes)
                else:
                    ids_f = _Immediate(rep.actor.submit(obs[idx], keys[idx], sub_lanes))
            except Exception as exc:
                with self._lock:
                    rep.inflight -= len(idx)
                failed = (rep, len(idx), exc)
                break
            pending.append((rep, idx, ids_f))

        out: List[Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]] = []
        for rep, idx, ids_f in pending:
            if failed is not None:
                self._discard_group(rep, idx, ids_f)
                continue
            try:
                ids = ids_f.result()
                while True:
                    if rep.is_virtual():
                        res = rep.actor.call("poll", ids).result()
                    else:
                        res = rep.actor.poll(ids)
                    if res is not None:
                        break
                out.append(res)
            except Exception as exc:
                failed = (rep, len(idx), exc)
            finally:
                with self._lock:
                    rep.inflight -= len(idx)
        if failed is not None:
            rep, nrows, exc = failed
            with self._lock:
                rep.failures += 1
                self.num_failures += 1
                self.num_replica_failures += 1
                self.num_inflight_dropped += nrows
            raise InferenceUnavailable(
                f"router {self.name!r}: replica {rep.name!r} failed "
                f"mid-request ({nrows} lane rows in flight): {exc!r}"
            ) from exc

        n = sum(len(idx) for _, idx, _ in pending)
        first = out[0]
        actions = np.empty((n,) + first[0].shape[1:], dtype=first[0].dtype)
        logps = np.empty((n,) + first[1].shape[1:], dtype=first[1].dtype)
        values = np.empty((n,) + first[2].shape[1:], dtype=first[2].dtype)
        for (rep, idx, _), (a, lp, v) in zip(pending, out):
            actions[idx], logps[idx], values[idx] = a, lp, v
        return actions, logps, values

    def _discard_group(self, rep: _Replica, idx: np.ndarray, ids_f: Any) -> None:
        """Best-effort cancel of a group submitted before another failed."""
        try:
            ids = ids_f.result()
            if rep.is_virtual():
                rep.actor.call("discard", ids)
            else:
                rep.actor.discard(ids)
        except Exception:  # pragma: no cover - cleanup is best-effort
            pass
        finally:
            with self._lock:
                rep.inflight -= len(idx)
                self.num_inflight_dropped += len(idx)

    def compute_values(self, obs: np.ndarray, lanes: Optional[np.ndarray] = None) -> Any:
        eligible = self._eligible()
        if not eligible:
            raise InferenceUnavailable(f"router {self.name!r}: no eligible replicas")
        rep = min(eligible, key=lambda r: r.inflight)
        try:
            if rep.is_virtual():
                return rep.actor.call("compute_values", obs).result()
            return rep.actor.compute_values(obs)
        except Exception as exc:
            with self._lock:
                rep.failures += 1
                self.num_failures += 1
                self.num_replica_failures += 1
            raise InferenceUnavailable(
                f"router {self.name!r}: replica {rep.name!r} failed in "
                f"compute_values(): {exc!r}"
            ) from exc

    # ------------------------------------------------------ weight tracking
    def sync_weights(self, weights: Any = None) -> None:
        """Broadcast weights to all live replicas, bumping the router's
        weight version.  A replica that misses the broadcast keeps its old
        version and becomes ineligible until ``recover()`` re-syncs it."""
        if weights is None and self.weights_provider is not None:
            weights = self.weights_provider()
        if weights is None:
            return
        with self._lock:
            self.weight_version += 1
            version = self.weight_version
        for rep in list(self._replicas):
            if not rep.alive:
                continue  # stays on its old version: refused until recover()
            try:
                if rep.is_virtual():
                    rep.actor.call("set_weights", weights).result()
                else:
                    rep.actor.set_weights(weights)
                rep.weight_version = version
            except Exception as exc:
                logger.warning(
                    "router %s: weight broadcast v%d to replica %s failed: %s",
                    self.name, version, rep.name, repr(exc),
                )

    def _push_weights(self, rep: _Replica) -> bool:
        weights = (
            self.weights_provider() if self.weights_provider is not None else None
        )
        if weights is None:
            # No canonical provider (tests driving the router directly):
            # nothing to re-sync, accept the replica at the current version.
            rep.weight_version = self.weight_version
            return True
        try:
            if rep.is_virtual():
                rep.actor.call("set_weights", weights).result()
            else:
                rep.actor.set_weights(weights)
            rep.weight_version = self.weight_version
            return True
        except Exception as exc:
            logger.warning(
                "router %s: weight re-sync to replica %s failed: %s",
                self.name, rep.name, repr(exc),
            )
            return False

    # ------------------------------------------------------------- healing
    def recover(self) -> None:
        """Heal the replica set: per ``failure_policy``, dead replicas are
        restarted through supervision (then weight re-synced) or dropped;
        stale-but-alive replicas are re-synced.  Lanes pinned to lost
        replicas are unpinned so they re-pin with fresh server-side state.
        Serialized: concurrent callers (racing rollout shards) observe the
        first caller's completed repair as a no-op."""
        with self._recover_lock:
            for rep in list(self._replicas):
                if rep.alive and rep.weight_version == self.weight_version:
                    continue
                if not rep.alive:
                    if self.failure_policy == "drop_shard" or not hasattr(
                        rep.actor, "restart"
                    ):
                        self._drop_replica(rep)
                        continue
                    try:
                        rep.actor.restart()
                    except Exception as exc:
                        logger.warning(
                            "router %s: restart of replica %s failed: %s",
                            self.name, rep.name, repr(exc),
                        )
                    if not rep.alive:
                        self._drop_replica(rep)  # restart budget exhausted
                        continue
                    with self._lock:
                        self.num_replica_restarts += 1
                    # The rebuilt target lost all per-lane state: unpin its
                    # lanes so they re-init wherever they pin next.
                    self._unpin_replica(rep)
                if not self._push_weights(rep):
                    if not rep.alive:
                        self._drop_replica(rep)
            with self._lock:
                self.num_recoveries += 1

    def _drop_replica(self, rep: _Replica) -> None:
        with self._lock:
            if rep not in self._replicas:
                return
            self._replicas.remove(rep)
            self.num_replicas_dropped += 1
        self._unpin_replica(rep)
        try:
            if hasattr(rep.actor, "stop"):
                rep.actor.stop()
        except Exception:  # pragma: no cover - teardown is best-effort
            pass

    def _unpin_replica(self, rep: _Replica) -> None:
        with self._lock:
            lanes = [lane for lane, r in self._pins.items() if r is rep]
            for lane in lanes:
                del self._pins[lane]
            self.num_lane_repins += len(lanes)
            self.num_lane_state_resets += len(lanes)

    # ----------------------------------------------------------- lifecycle
    def stop(self) -> None:
        for rep in list(self._replicas):
            try:
                if hasattr(rep.actor, "stop"):
                    rep.actor.stop()
            except Exception:  # pragma: no cover - teardown is best-effort
                pass

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            out: Dict[str, Any] = {
                "num_requests": self.num_requests,
                "num_lane_requests": self.num_lane_requests,
                "num_failures": self.num_failures,
                "num_recoveries": self.num_recoveries,
                "num_replica_failures": self.num_replica_failures,
                "num_replica_restarts": self.num_replica_restarts,
                "num_replicas_dropped": self.num_replicas_dropped,
                "num_inflight_dropped": self.num_inflight_dropped,
                "num_lane_repins": self.num_lane_repins,
                "num_lane_state_resets": self.num_lane_state_resets,
                "num_pinned_lanes": len(self._pins),
                "weight_version": self.weight_version,
                "sticky": self._sticky,
            }
        replicas = []
        for rep in list(self._replicas):
            row: Dict[str, Any] = {
                "name": rep.name,
                "alive": rep.alive,
                "weight_version": rep.weight_version,
                "inflight": rep.inflight,
                "failures": rep.failures,
            }
            try:
                row["stats"] = (
                    rep.actor.sync("stats") if rep.is_virtual() else rep.actor.stats()
                )
            except Exception:  # dead replica: health fields only
                pass
            replicas.append(row)
        out["replicas"] = replicas
        out["num_eligible"] = len(self._eligible())
        return out

    # ------------------------------------------------------------- metrics
    def metrics_probe(self, key: str) -> Callable[[Any], None]:
        """A ``MetricsContext`` probe publishing this router's serving
        metrics under ``inference/<key>/...`` — run at every ``save()`` so
        occupancy, admission latency, and credit stalls land in ``train()``
        results and the ``Algorithm.explain()`` join."""

        def probe(ctx: Any) -> None:
            pre = f"inference/{key}/"
            with self._lock:
                ctx.counters[pre + "num_requests"] = self.num_requests
                ctx.counters[pre + "num_replica_failures"] = self.num_replica_failures
                ctx.counters[pre + "num_replicas_dropped"] = self.num_replicas_dropped
                ctx.counters[pre + "num_inflight_dropped"] = self.num_inflight_dropped
                ctx.counters[pre + "num_lane_repins"] = self.num_lane_repins
                replicas = list(self._replicas)
            ctx.gauges[pre + "replicas"] = float(len(replicas))
            ctx.gauges[pre + "replicas_eligible"] = float(len(self._eligible()))
            ctx.gauges[pre + "weight_version"] = float(self.weight_version)
            if self.credits is not None:
                ctx.counters[pre + "credit_stalls"] = self.credits.stalls
                ctx.gauges[pre + "credit_stall_time_s"] = self.credits.stall_time_s
            occ_mean: List[float] = []
            occ_peak: List[float] = []
            wait_p50: List[float] = []
            wait_p99: List[float] = []
            for rep in replicas:
                try:
                    st = (
                        rep.actor.sync("stats")
                        if rep.is_virtual()
                        else rep.actor.stats()
                    )
                except Exception:
                    continue  # dead replica: skip its queue stats
                q = st.get("queue") or {}
                occ_mean.append(float(q.get("occupancy_mean", 0.0)))
                occ_peak.append(float(q.get("occupancy_peak", 0.0)))
                wait_p50.append(float(q.get("admission_wait_p50_s", 0.0)))
                wait_p99.append(float(q.get("admission_wait_p99_s", 0.0)))
            if occ_mean:
                ctx.gauges[pre + "occupancy_mean"] = sum(occ_mean) / len(occ_mean)
                ctx.gauges[pre + "occupancy_peak"] = max(occ_peak)
                ctx.gauges[pre + "admission_wait_p50_s"] = max(wait_p50)
                ctx.gauges[pre + "admission_wait_p99_s"] = max(wait_p99)

        return probe
