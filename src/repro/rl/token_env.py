"""TokenEnv: autoregressive generation as an RL environment.

The HybridFlow-shaped RLHF workload (ROADMAP item 2) cast onto the standard
``Env`` protocol so the whole flow runtime — vector engine, credit
backpressure, inference serving, sharded learners — applies unchanged:

  * **reset** samples a prompt: ``prompt_len`` tokens drawn from the vocab
    (ragged per lane within ``[min_prompt, max_prompt]``).
  * **one action = one token.**  The action appends to the sequence; the
    episode is the generation.
  * **termination** — EOS or the decode horizon.  Two modes:
      - ``sync=False``: classic semantics — EOS terminates, the horizon
        truncates.  Lanes desynchronize as they reset at different times.
      - ``sync=True`` (default): EOS is *absorbing* — the lane keeps
        stepping (appending PAD) until every lane hits the shared horizon,
        so all lanes of a vectorized rollout reset on the same step.  This
        is what lets the KV-cache decode rollout run prefill exactly once
        per episode under ``lax.cond`` instead of re-prefilling whenever
        any single lane resets (see ``LMTokenPolicy``).
  * **reward** is programmatic and granted at episode end:
    ``reward_fn(tokens, prompt_len, length) -> float`` over the final
    sequence (a verifier score, a length penalty, a stub target — anything
    jax-traceable).

The observation is the whole generation state, so any policy — including a
stateless one — can act from it: ``[ctx]`` token window (right-padded),
then ``length`` and ``t`` as trailing scalars, all float32.  Helpers
``split_obs``/``make_obs`` define that layout in one place.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.rl.env import Env

__all__ = ["TokenEnv", "TokenEnvState", "split_obs", "make_obs", "target_token_reward"]

PAD = 0
EOS = 1


class TokenEnvState(NamedTuple):
    tokens: jax.Array      # [ctx] int32 — prompt + generated, right-padded
    length: jax.Array      # int32 — filled slots
    prompt_len: jax.Array  # int32
    t: jax.Array           # int32 — decode step within the episode
    finished: jax.Array    # bool — EOS emitted (absorbing under sync mode)


def make_obs(tokens: jax.Array, length: jax.Array, t: jax.Array) -> jax.Array:
    """[ctx] int tokens + scalars -> the float32 [ctx + 2] observation."""
    return jnp.concatenate(
        [
            tokens.astype(jnp.float32),
            length.astype(jnp.float32)[None],
            t.astype(jnp.float32)[None],
        ]
    )


def split_obs(obs: jax.Array, ctx: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Inverse of ``make_obs`` over a batch: obs [..., ctx+2] ->
    (tokens [..., ctx] int32, length [...] int32, t [...] int32)."""
    tokens = obs[..., :ctx].astype(jnp.int32)
    length = obs[..., ctx].astype(jnp.int32)
    t = obs[..., ctx + 1].astype(jnp.int32)
    return tokens, length, t


def target_token_reward(target: int = 3) -> Callable:
    """Stub programmatic reward: fraction of generated (non-PAD) tokens equal
    to ``target``.  Trivially learnable — the acceptance signal for the
    end-to-end PPO-LM plan is this number rising."""

    def reward_fn(tokens: jax.Array, prompt_len: jax.Array, length: jax.Array) -> jax.Array:
        idx = jnp.arange(tokens.shape[0])
        gen = (idx >= prompt_len) & (idx < length) & (tokens != PAD)
        hits = jnp.sum(jnp.where(gen, (tokens == target).astype(jnp.float32), 0.0))
        return hits / jnp.maximum(jnp.sum(gen.astype(jnp.float32)), 1.0)

    return reward_fn


class TokenEnv(Env):
    """Prompts as resets, tokens as actions, programmatic reward at the end.

    ``ctx >= max_prompt + horizon`` is enforced so a generation never
    overruns the token window — which also means a KV cache of window
    ``ctx`` never wraps its ring buffer mid-episode (slot == position), the
    invariant the decode rollout path relies on.
    """

    def __init__(
        self,
        vocab_size: int = 17,
        ctx: int = 32,
        min_prompt: int = 4,
        max_prompt: int = 8,
        horizon: int = 16,
        reward_fn: Optional[Callable] = None,
        sync: bool = True,
    ):
        if ctx < max_prompt + horizon:
            raise ValueError(
                f"ctx={ctx} < max_prompt+horizon={max_prompt + horizon}: "
                "generation would overrun the token window"
            )
        if not (0 < min_prompt <= max_prompt):
            raise ValueError("need 0 < min_prompt <= max_prompt")
        self.vocab_size = vocab_size
        self.ctx = ctx
        self.min_prompt = min_prompt
        self.max_prompt = max_prompt
        self.horizon = horizon
        self.sync = sync
        self.reward_fn = reward_fn or target_token_reward()
        self.obs_dim = ctx + 2
        self.num_actions = vocab_size

    # --------------------------------------------------------------- protocol
    def reset(self, key: jax.Array) -> Tuple[TokenEnvState, jax.Array]:
        kp, kl = jax.random.split(key)
        prompt_len = jax.random.randint(kl, (), self.min_prompt, self.max_prompt + 1)
        # Prompt tokens avoid PAD/EOS so prompts are unambiguous content.
        body = jax.random.randint(kp, (self.ctx,), 2, self.vocab_size)
        tokens = jnp.where(jnp.arange(self.ctx) < prompt_len, body, PAD).astype(jnp.int32)
        st = TokenEnvState(
            tokens=tokens,
            length=prompt_len.astype(jnp.int32),
            prompt_len=prompt_len.astype(jnp.int32),
            t=jnp.zeros((), jnp.int32),
            finished=jnp.zeros((), bool),
        )
        return st, make_obs(st.tokens, st.length, st.t)

    def step_raw(self, st: TokenEnvState, action: jax.Array, key: jax.Array):
        tok = jnp.where(st.finished, PAD, action.astype(jnp.int32))
        tokens = jnp.where(jnp.arange(self.ctx) == st.length, tok, st.tokens)
        length = st.length + 1
        t = st.t + 1
        finished = st.finished | (tok == EOS)
        if self.sync:
            # Absorbing EOS: every lane terminates together at the horizon.
            terminated = t >= self.horizon
            truncated = jnp.zeros((), bool)
        else:
            terminated = (tok == EOS) & ~st.finished
            truncated = (t >= self.horizon) & ~terminated
        done = terminated | truncated
        reward = jnp.where(
            done, self.reward_fn(tokens, st.prompt_len, length).astype(jnp.float32), 0.0
        )
        new = TokenEnvState(tokens, length, st.prompt_len, t, finished)
        return new, make_obs(tokens, length, t), reward, terminated, truncated
