"""Policies: pure-JAX actor-critic / Q / squashed-Gaussian networks + losses.

A Policy bundles parameter construction with jitted ``act`` and ``loss``
functions.  Params are plain dict pytrees.  The dataflow layer never touches
these internals — they are the "numerical concerns" the paper keeps unchanged
while swapping the distributed execution layer.
"""

from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = [
    "mlp_init",
    "mlp_apply",
    "ActorCriticPolicy",
    "DQNPolicy",
    "SACPolicy",
    "DummyPolicy",
]


# ------------------------------------------------------------------ MLP base
def mlp_init(key: jax.Array, sizes: Sequence[int], scale_last: float = 0.01) -> PyTree:
    params = []
    keys = jax.random.split(key, len(sizes) - 1)
    for i, (din, dout) in enumerate(zip(sizes[:-1], sizes[1:])):
        w_scale = scale_last if i == len(sizes) - 2 else float(np.sqrt(2.0 / din))
        params.append(
            {
                "w": jax.random.normal(keys[i], (din, dout), jnp.float32) * w_scale,
                "b": jnp.zeros((dout,), jnp.float32),
            }
        )
    return params


def mlp_apply(params: PyTree, x: jax.Array) -> jax.Array:
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jnp.tanh(x)
    return x


# ------------------------------------------------------------ Actor-critic
class ActorCriticPolicy:
    """Discrete actor-critic with selectable loss: 'pg' (A2C/A3C), 'ppo',
    'vtrace' (IMPALA)."""

    def __init__(
        self,
        obs_dim: int,
        num_actions: int,
        hidden: Sequence[int] = (64, 64),
        loss_kind: str = "pg",
        vf_coef: float = 0.5,
        ent_coef: float = 0.01,
        clip_eps: float = 0.2,
        gamma: float = 0.99,
        rollout_len: int = 0,  # needed for vtrace reshaping
    ):
        self.obs_dim = obs_dim
        self.num_actions = num_actions
        self.hidden = tuple(hidden)
        self.loss_kind = loss_kind
        self.vf_coef = vf_coef
        self.ent_coef = ent_coef
        self.clip_eps = clip_eps
        self.gamma = gamma
        self.rollout_len = rollout_len

    def init_params(self, key: jax.Array) -> PyTree:
        k1, k2 = jax.random.split(key)
        return {
            "pi": mlp_init(k1, (self.obs_dim, *self.hidden, self.num_actions)),
            "vf": mlp_init(k2, (self.obs_dim, *self.hidden, 1), scale_last=1.0),
        }

    def logits_value(self, params: PyTree, obs: jax.Array) -> Tuple[jax.Array, jax.Array]:
        return mlp_apply(params["pi"], obs), mlp_apply(params["vf"], obs)[..., 0]

    def act(self, params: PyTree, obs: jax.Array, key: jax.Array):
        logits, value = self.logits_value(params, obs)
        action = jax.random.categorical(key, logits)
        logp_all = jax.nn.log_softmax(logits)
        logp = jnp.take_along_axis(logp_all, action[..., None], axis=-1)[..., 0]
        return action, logp, value, logits

    def value(self, params: PyTree, obs: jax.Array) -> jax.Array:
        """Critic value only (GAE bootstrap at truncation boundaries)."""
        return mlp_apply(params["vf"], obs)[..., 0]

    def compute_actions(self, params: PyTree, obs: jax.Array, keys: jax.Array):
        """Batched acting with *per-lane* RNG: one dispatch for all N envs.

        ``obs`` is [N, obs_dim], ``keys`` is [N, 2] (one PRNG key per env
        lane).  Equivalent to calling ``act`` once per lane with that lane's
        key — the per-lane split is what lets a vectorized rollout
        bit-reproduce N independent per-env rollouts — but it costs a single
        jitted dispatch instead of N.
        """
        return jax.vmap(self.act, in_axes=(None, 0, 0))(params, obs, keys)

    # ------------------------------------------------------------- losses
    def loss(self, params: PyTree, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
        if self.loss_kind == "ppo":
            return self._ppo_loss(params, batch)
        if self.loss_kind == "vtrace":
            return self._vtrace_loss(params, batch)
        return self._pg_loss(params, batch)

    def _dist_terms(self, params, batch):
        logits, values = self.logits_value(params, batch["obs"])
        logp_all = jax.nn.log_softmax(logits)
        actions = batch["actions"].astype(jnp.int32)
        logp = jnp.take_along_axis(logp_all, actions[:, None], axis=-1)[:, 0]
        entropy = -jnp.sum(jnp.exp(logp_all) * logp_all, axis=-1)
        return logp, entropy, values

    def _pg_loss(self, params, batch):
        logp, entropy, values = self._dist_terms(params, batch)
        adv = batch["advantages"]
        pg = -jnp.mean(logp * adv)
        vf = jnp.mean(jnp.square(values - batch["returns"]))
        ent = jnp.mean(entropy)
        loss = pg + self.vf_coef * vf - self.ent_coef * ent
        return loss, {"pg_loss": pg, "vf_loss": vf, "entropy": ent}

    def _ppo_loss(self, params, batch):
        """Clipped-surrogate PPO loss via ``ops.fused_ppo_loss``: the fused
        Pallas kernel on TPU (one pass over the batch panel, differentiable
        through a hand-written Pallas backward), the bit-identical jnp math
        this method used to inline on CPU — the oracle the kernel is
        parity-tested against (``tests/test_kernel_surrogate.py``)."""
        from repro.kernels.ops import fused_ppo_loss

        logits, values = self.logits_value(params, batch["obs"])
        return fused_ppo_loss(
            logits,
            values,
            batch["actions"],
            batch["logp"],
            batch["advantages"],
            batch["returns"],
            clip_eps=self.clip_eps,
            vf_coef=self.vf_coef,
            ent_coef=self.ent_coef,
        )

    def _vtrace_loss(self, params, batch):
        """IMPALA: importance-corrected off-policy actor-critic.

        Batch rows are [B*T] with contiguous length-T traces (batch-major);
        reshape to [T, N] time-major for the scan.

        The v-trace targets go through ``repro.kernels.ops.fused_vtrace``:
        the Pallas-fused kernel on TPU, the identical lax.scan math on CPU.
        The targets are stop-gradient anyway, so the kernel *inputs* are
        stopped too — no tangent may enter ``pallas_call`` (it has no
        transpose rule; differentiating through it fails at linearize).
        """
        from repro.kernels.ops import fused_vtrace as vtrace

        T = self.rollout_len
        assert T > 0, "vtrace loss needs rollout_len"
        logp, entropy, values = self._dist_terms(params, batch)

        def tm(x):  # [N*T, ...] -> [T, N, ...]
            return x.reshape((-1, T) + x.shape[1:]).swapaxes(0, 1)

        sg = jax.lax.stop_gradient
        vs, pg_adv = vtrace(
            behaviour_logp=tm(batch["logp"]),
            target_logp=sg(tm(logp)),
            rewards=tm(batch["rewards"]),
            values=sg(tm(values)),
            dones=tm(batch["dones"]),
            last_value=sg(tm(values)[-1]),
            gamma=self.gamma,
        )
        pg = -jnp.mean(tm(logp) * pg_adv)
        vf = jnp.mean(jnp.square(tm(values) - vs))
        ent = jnp.mean(entropy)
        loss = pg + self.vf_coef * vf - self.ent_coef * ent
        return loss, {"pg_loss": pg, "vf_loss": vf, "entropy": ent}


# ----------------------------------------------------------------- DQN
class DQNPolicy:
    """Double DQN with target network and Huber TD loss."""

    def __init__(
        self,
        obs_dim: int,
        num_actions: int,
        hidden: Sequence[int] = (64, 64),
        gamma: float = 0.99,
    ):
        self.obs_dim = obs_dim
        self.num_actions = num_actions
        self.hidden = tuple(hidden)
        self.gamma = gamma

    def init_params(self, key: jax.Array) -> PyTree:
        q = mlp_init(key, (self.obs_dim, *self.hidden, self.num_actions), scale_last=1.0)
        return {"q": q}

    def q_values(self, params: PyTree, obs: jax.Array) -> jax.Array:
        return mlp_apply(params["q"], obs)

    def act(self, params: PyTree, obs: jax.Array, key: jax.Array, epsilon: jax.Array):
        q = self.q_values(params, obs)
        greedy = jnp.argmax(q, axis=-1)
        k1, k2 = jax.random.split(key)
        random_a = jax.random.randint(k1, greedy.shape, 0, self.num_actions)
        explore = jax.random.uniform(k2, greedy.shape) < epsilon
        action = jnp.where(explore, random_a, greedy)
        value = jnp.max(q, axis=-1)
        return action, jnp.zeros_like(value), value, q

    def value(self, params: PyTree, obs: jax.Array) -> jax.Array:
        return jnp.max(self.q_values(params, obs), axis=-1)

    def compute_actions(
        self, params: PyTree, obs: jax.Array, keys: jax.Array, epsilon: jax.Array
    ):
        """Per-lane-keyed batched epsilon-greedy (see ActorCriticPolicy)."""
        return jax.vmap(self.act, in_axes=(None, 0, 0, None))(
            params, obs, keys, epsilon
        )

    def loss(
        self, params: PyTree, target_params: PyTree, batch: Dict[str, jax.Array]
    ) -> Tuple[jax.Array, Dict]:
        q = self.q_values(params, batch["obs"])
        actions = batch["actions"].astype(jnp.int32)
        q_sa = jnp.take_along_axis(q, actions[:, None], axis=-1)[:, 0]
        # Double-DQN target: online argmax, target evaluation.
        next_q_online = self.q_values(params, batch["next_obs"])
        next_a = jnp.argmax(next_q_online, axis=-1)
        next_q_target = self.q_values(target_params, batch["next_obs"])
        next_q = jnp.take_along_axis(next_q_target, next_a[:, None], axis=-1)[:, 0]
        target = batch["rewards"] + self.gamma * (1.0 - batch["dones"]) * jax.lax.stop_gradient(next_q)
        td = q_sa - target
        weights = batch["weights"] if "weights" in batch else jnp.ones_like(td)
        huber = jnp.where(jnp.abs(td) < 1.0, 0.5 * td**2, jnp.abs(td) - 0.5)
        loss = jnp.mean(weights * huber)
        return loss, {"td_error": td, "mean_q": jnp.mean(q_sa)}


# ----------------------------------------------------------------- SAC
class SACPolicy:
    """Continuous SAC: squashed Gaussian actor + twin Q critics."""

    def __init__(
        self,
        obs_dim: int,
        action_dim: int,
        hidden: Sequence[int] = (64, 64),
        gamma: float = 0.99,
        alpha: float = 0.2,
    ):
        self.obs_dim = obs_dim
        self.action_dim = action_dim
        self.hidden = tuple(hidden)
        self.gamma = gamma
        self.alpha = alpha

    def init_params(self, key: jax.Array) -> PyTree:
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "pi": mlp_init(k1, (self.obs_dim, *self.hidden, 2 * self.action_dim)),
            "q1": mlp_init(k2, (self.obs_dim + self.action_dim, *self.hidden, 1), scale_last=1.0),
            "q2": mlp_init(k3, (self.obs_dim + self.action_dim, *self.hidden, 1), scale_last=1.0),
        }

    def _pi(self, params, obs, key):
        out = mlp_apply(params["pi"], obs)
        mu, log_std = jnp.split(out, 2, axis=-1)
        log_std = jnp.clip(log_std, -20, 2)
        std = jnp.exp(log_std)
        eps = jax.random.normal(key, mu.shape)
        pre_tanh = mu + std * eps
        action = jnp.tanh(pre_tanh)
        logp = jnp.sum(
            -0.5 * (eps**2 + 2 * log_std + jnp.log(2 * jnp.pi))
            - jnp.log(1 - action**2 + 1e-6),
            axis=-1,
        )
        return action, logp

    def _q(self, q_params, obs, act):
        x = jnp.concatenate([obs, act], axis=-1)
        return mlp_apply(q_params, x)[..., 0]

    def act(self, params: PyTree, obs: jax.Array, key: jax.Array):
        action, logp = self._pi(params, obs, key)
        value = self._q(params["q1"], obs, action)
        return action, logp, value, action

    def compute_actions(self, params: PyTree, obs: jax.Array, keys: jax.Array):
        """Per-lane-keyed batched squashed-Gaussian acting."""
        return jax.vmap(self.act, in_axes=(None, 0, 0))(params, obs, keys)

    def critic_loss(self, params, target_params, batch, key):
        next_a, next_logp = self._pi(params, batch["next_obs"], key)
        tq1 = self._q(target_params["q1"], batch["next_obs"], next_a)
        tq2 = self._q(target_params["q2"], batch["next_obs"], next_a)
        target_v = jnp.minimum(tq1, tq2) - self.alpha * next_logp
        target = batch["rewards"] + self.gamma * (1.0 - batch["dones"]) * jax.lax.stop_gradient(target_v)
        actions = batch["actions"]
        if actions.ndim == 1:
            actions = actions[:, None]
        q1 = self._q(params["q1"], batch["obs"], actions)
        q2 = self._q(params["q2"], batch["obs"], actions)
        td = q1 - target
        return jnp.mean((q1 - target) ** 2) + jnp.mean((q2 - target) ** 2), td

    def actor_loss(self, params, batch, key):
        a, logp = self._pi(params, batch["obs"], key)
        q = jnp.minimum(
            self._q(params["q1"], batch["obs"], a), self._q(params["q2"], batch["obs"], a)
        )
        return jnp.mean(self.alpha * logp - q)

    def loss(self, params, target_params, batch, key):
        k1, k2 = jax.random.split(key)
        closs, td = self.critic_loss(params, target_params, batch, k1)
        aloss = self.actor_loss(params, batch, k2)
        return closs + aloss, {"td_error": td, "critic_loss": closs, "actor_loss": aloss}


# --------------------------------------------------------------- Dummy
class DummyPolicy:
    """One trainable scalar — the paper's sampling-microbenchmark policy."""

    def __init__(self, obs_dim: int = 4, num_actions: int = 2):
        self.obs_dim = obs_dim
        self.num_actions = num_actions

    def init_params(self, key: jax.Array) -> PyTree:
        return {"theta": jnp.zeros((1,), jnp.float32)}

    def act(self, params: PyTree, obs: jax.Array, key: jax.Array):
        action = jax.random.randint(key, obs.shape[:-1], 0, self.num_actions)
        zeros = jnp.zeros(obs.shape[:-1])
        return action, zeros, zeros, zeros

    def value(self, params: PyTree, obs: jax.Array) -> jax.Array:
        return jnp.zeros(obs.shape[:-1])

    def compute_actions(self, params: PyTree, obs: jax.Array, keys: jax.Array):
        """Per-lane-keyed batched random acting (pure RNG: bit-identical to
        per-env acting, which anchors the determinism regression suite)."""
        return jax.vmap(self.act, in_axes=(None, 0, 0))(params, obs, keys)

    def loss(self, params: PyTree, batch: Dict[str, jax.Array]):
        return jnp.sum(params["theta"] ** 2), {}
