"""SampleBatch: the unit of data flowing through RLlib Flow dataflows.

A thin, columnar dict-of-arrays (numpy on host — replay buffers and iterator
plumbing stay off-device; JAX arrays enter only inside jitted steps).  Also
``MultiAgentBatch`` for the multi-agent composition workflows (paper §5.3).
"""

from __future__ import annotations

import time
from typing import Any, Dict, Iterator, List, Mapping, Optional, Sequence

import numpy as np

__all__ = ["SampleBatch", "MultiAgentBatch", "concat_batches"]

# Canonical column names.
OBS = "obs"
ACTIONS = "actions"
REWARDS = "rewards"
DONES = "dones"
NEXT_OBS = "next_obs"
LOGITS = "logits"
LOGP = "logp"
VALUES = "values"
ADVANTAGES = "advantages"
RETURNS = "returns"
WEIGHTS = "weights"  # importance weights (prioritized replay)
EPS_ID = "eps_id"


class SampleBatch(Mapping[str, np.ndarray]):
    """Columnar batch of experiences; all columns share leading dim."""

    def __init__(self, data: Optional[Dict[str, Any]] = None, **cols: Any):
        merged = dict(data or {})
        merged.update(cols)
        self._data: Dict[str, np.ndarray] = {
            k: np.asarray(v) for k, v in merged.items()
        }
        if self._data:
            lens = {k: v.shape[0] for k, v in self._data.items()}
            if len(set(lens.values())) > 1:
                raise ValueError(f"ragged SampleBatch columns: {lens}")
        # Birth stamp (CLOCK_MONOTONIC: comparable across processes on one
        # host) — the data-plane instrumentation measures sample->learn
        # latency from it.  Derived batches inherit/propagate it (slice:
        # same stamp; concat: earliest constituent).
        self.created_at: float = time.perf_counter()

    # Mapping interface -----------------------------------------------------
    def __getitem__(self, k: str) -> np.ndarray:
        return self._data[k]

    def __setitem__(self, k: str, v: Any) -> None:
        v = np.asarray(v)
        if self._data and v.shape[0] != self.count:
            raise ValueError(f"column {k} len {v.shape[0]} != batch len {self.count}")
        self._data[k] = v

    def __iter__(self) -> Iterator[str]:
        return iter(self._data)

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, k: object) -> bool:
        return k in self._data

    def keys(self):
        return self._data.keys()

    def items(self):
        return self._data.items()

    # Batch ops -------------------------------------------------------------
    @property
    def count(self) -> int:
        if not self._data:
            return 0
        return next(iter(self._data.values())).shape[0]

    def slice(self, start: int, end: int) -> "SampleBatch":
        out = SampleBatch({k: v[start:end] for k, v in self._data.items()})
        out.created_at = self.created_at
        return out

    def shuffle(self, rng: np.random.Generator) -> "SampleBatch":
        perm = rng.permutation(self.count)
        out = SampleBatch({k: v[perm] for k, v in self._data.items()})
        out.created_at = self.created_at
        return out

    def minibatches(self, size: int, rng: Optional[np.random.Generator] = None):
        b = self.shuffle(rng) if rng is not None else self
        for i in range(0, b.count - size + 1, size):
            yield b.slice(i, i + size)

    def split_by_episode(self) -> List["SampleBatch"]:
        if EPS_ID not in self._data:
            return [self]
        ids = self._data[EPS_ID]
        out, start = [], 0
        for i in range(1, len(ids)):
            if ids[i] != ids[i - 1]:
                out.append(self.slice(start, i))
                start = i
        out.append(self.slice(start, len(ids)))
        return out

    @staticmethod
    def concat_samples(batches: Sequence["SampleBatch"]) -> "SampleBatch":
        batches = [b for b in batches if b.count > 0]
        if not batches:
            return SampleBatch()
        keys = batches[0].keys()
        out = SampleBatch(
            {k: np.concatenate([b[k] for b in batches], axis=0) for k in keys}
        )
        out.created_at = min(
            getattr(b, "created_at", out.created_at) for b in batches
        )
        return out

    def shard(self, num_shards: int) -> List["SampleBatch"]:
        """Contiguous equal-row split for data-parallel learner groups.

        The transport-boundary half of learner sharding: each shard is a
        zero-copy view batch (numpy slicing) destined for one learner
        device/process.  Rows must tile ``num_shards`` evenly — trimming or
        padding is a *policy* decision left to the caller
        (``ShardedLearnerGroup`` trims and counts).
        """
        if num_shards <= 0:
            raise ValueError(f"num_shards must be positive (got {num_shards})")
        if self.count % num_shards:
            raise ValueError(
                f"cannot shard {self.count} rows into {num_shards} equal parts"
            )
        rows = self.count // num_shards
        return [self.slice(i * rows, (i + 1) * rows) for i in range(num_shards)]

    def copy(self) -> "SampleBatch":
        out = SampleBatch({k: v.copy() for k, v in self._data.items()})
        out.created_at = self.created_at
        return out

    def size_bytes(self) -> int:
        return int(sum(v.nbytes for v in self._data.values()))

    def __repr__(self) -> str:  # pragma: no cover
        cols = {k: tuple(v.shape) for k, v in self._data.items()}
        return f"SampleBatch(count={self.count}, cols={cols})"


def concat_batches(batches: Sequence[SampleBatch]) -> SampleBatch:
    return SampleBatch.concat_samples(batches)


class MultiAgentBatch:
    """Per-policy batches produced by multi-agent rollouts (paper §5.3)."""

    def __init__(self, policy_batches: Dict[str, SampleBatch]):
        self.policy_batches = dict(policy_batches)

    @property
    def count(self) -> int:
        return sum(b.count for b in self.policy_batches.values())

    def select(self, policy_ids: Sequence[str]) -> "MultiAgentBatch":
        return MultiAgentBatch(
            {p: b for p, b in self.policy_batches.items() if p in policy_ids}
        )

    @staticmethod
    def concat_samples(batches: Sequence["MultiAgentBatch"]) -> "MultiAgentBatch":
        merged: Dict[str, List[SampleBatch]] = {}
        for mb in batches:
            for p, b in mb.policy_batches.items():
                merged.setdefault(p, []).append(b)
        return MultiAgentBatch(
            {p: SampleBatch.concat_samples(bs) for p, bs in merged.items()}
        )

    def __repr__(self) -> str:  # pragma: no cover
        return f"MultiAgentBatch({ {p: b.count for p, b in self.policy_batches.items()} })"
