"""Low-level actor/RPC-style algorithm implementations (the paper's baseline).

These mirror RLlib's pre-Flow implementations (paper Listings A2 / A4):
dataflow and control flow intermixed, manual future bookkeeping, manual
timers and weight-sync tracking.  They exist to reproduce the paper's two
comparisons:

  * Table 2 — lines of code vs. the plans in ``repro/core/plans.py``
    (counted by ``benchmarks/bench_loc.py``)
  * Fig 13 — throughput parity of the dataflow executor vs. hand-written
    loops (``benchmarks/bench_sampling.py`` / ``bench_async_opt.py``)

The numerical code (policies, workers) is IDENTICAL to what the plans use —
only the distributed execution layer differs, matching the paper's setup.
"""

from __future__ import annotations

import random
import time
from typing import Any, Dict, Iterator

from repro.core.actor import ActorPool, wait
from repro.core.metrics import TimerStat
from repro.core.workers import WorkerSet
from repro.rl.sample_batch import SampleBatch

__all__ = ["a3c_lowlevel", "apex_lowlevel", "sync_sample_lowlevel"]


def a3c_lowlevel(workers: WorkerSet) -> Iterator[Dict[str, Any]]:
    """Paper Listing A2: manual async gradient loop."""
    # Create timers
    apply_timer = TimerStat()
    wait_timer = TimerStat()
    dispatch_timer = TimerStat()

    # Create training information
    num_steps_sampled = 0
    num_steps_trained = 0

    # Get weights from the local rollout actor
    local_worker = workers.local_worker()
    weights = local_worker.get_weights()

    # type: Dict[future, actor]
    pending_gradients = {}

    # Get the remote rollout actors
    remote_workers = workers.remote_workers()

    # Issue gradient computation tasks
    for worker in remote_workers:
        # Set weight on remote rollout actor
        worker.call("set_weights", weights)
        # Sample then kick off gradient computation on the worker
        future = worker.apply(lambda w: w.compute_gradients(w.sample()))
        # Map the future to the rollout actor
        pending_gradients[future] = worker

    # Training loop
    while pending_gradients:
        # Record the time to wait for a gradient
        with wait_timer:
            futures = list(pending_gradients.keys())
            # Wait for one actor to complete
            ready, _ = wait(futures, num_returns=1)
            future = ready[0]

        # Get the gradient and training info
        gradient, info = future.result()

        # Pop the used gradient from the map
        worker = pending_gradients.pop(future)

        # Check the validity of the gradient
        if gradient is not None:
            # Record the time for the gradient application
            with apply_timer:
                # Apply the gradient on the local worker
                local_worker.apply_gradients(gradient)
            # Record the metrics from the worker
            num_steps_sampled += info.get("batch_count", 0)
            num_steps_trained += info.get("batch_count", 0)

        # Record the time to set new weights and relaunch
        with dispatch_timer:
            # Get the weights from the local rollout actor
            weights = local_worker.get_weights()
            # Set weights on the rollout actor
            worker.call("set_weights", weights)
            # Launch gradient computation task on the worker
            future = worker.apply(lambda w: w.compute_gradients(w.sample()))
            # Map the new future to the corresponding worker
            pending_gradients[future] = worker

        yield {
            "counters": {
                "num_steps_sampled": num_steps_sampled,
                "num_steps_trained": num_steps_trained,
            },
            "timers": {
                "wait": wait_timer.mean,
                "apply": apply_timer.mean,
                "dispatch": dispatch_timer.mean,
            },
        }


def apex_lowlevel(
    workers: WorkerSet,
    replay_actors: ActorPool,
    target_update_freq: int = 2500,
    max_weight_sync_delay: int = 400,
    sample_queue_depth: int = 2,
    replay_queue_depth: int = 4,
) -> Iterator[Dict[str, Any]]:
    """Paper Listing A4: manual Ape-X with task pools and a learner thread."""
    from repro.core.learner_thread import LearnerThread

    local_worker = workers.local_worker()
    learner = LearnerThread(local_worker)
    learner.start()

    timers = {
        k: TimerStat()
        for k in [
            "put_weights", "get_samples", "sample_processing",
            "replay_processing", "update_priorities", "train", "sample",
        ]
    }
    num_weight_syncs = 0
    num_samples_dropped = 0
    num_steps_sampled = 0
    num_steps_trained = 0
    steps_since_update: Dict[int, int] = {}
    last_target_update = 0

    # Kick off replay tasks on the replay actors
    replay_tasks = {}
    for actor in replay_actors:
        for _ in range(replay_queue_depth):
            replay_tasks[actor.call("replay")] = actor

    # Kick off async background sampling on the rollout actors
    weights = local_worker.get_weights()
    sample_tasks = {}
    for worker in workers.remote_workers():
        worker.call("set_weights", weights)
        steps_since_update[worker.actor_id] = 0
        for _ in range(sample_queue_depth):
            sample_tasks[worker.apply(lambda w: w.sample_with_count())] = worker

    while True:
        start = time.time()
        sample_timesteps, train_timesteps = 0, 0

        # --- sampling / replay-store path
        with timers["sample_processing"]:
            completed = [f for f in list(sample_tasks) if f.done()]
            for future in completed:
                worker = sample_tasks.pop(future)
                sample_batch, count = future.result()
                sample_timesteps += count
                # Send the batch to a random replay actor
                random.choice(list(replay_actors)).call("add_batch", sample_batch)
                steps_since_update[worker.actor_id] += count
                # Update weights on the rollout worker if stale
                if steps_since_update[worker.actor_id] >= max_weight_sync_delay:
                    if learner.weights_updated:
                        learner.weights_updated = False
                        with timers["put_weights"]:
                            weights = local_worker.get_weights()
                        worker.call("set_weights", weights)
                        num_weight_syncs += 1
                    steps_since_update[worker.actor_id] = 0
                # Kick off another sample request
                sample_tasks[worker.apply(lambda w: w.sample_with_count())] = worker

        # --- replay -> learner path
        with timers["replay_processing"]:
            for future in [f for f in list(replay_tasks) if f.done()]:
                actor = replay_tasks.pop(future)
                replay_tasks[actor.call("replay")] = actor
                if learner.inqueue.full():
                    num_samples_dropped += 1
                else:
                    with timers["get_samples"]:
                        samples = future.result()
                    if samples is not None:
                        learner.inqueue.put((samples, actor))

        # --- priority updates from the learner out-queue
        with timers["update_priorities"]:
            while not learner.outqueue.empty():
                actor, batch, info = learner.outqueue.get()
                if actor is not None and "batch_indices" in batch:
                    import numpy as np

                    actor.call(
                        "update_priorities",
                        batch["batch_indices"],
                        np.abs(info.get("td_error", np.ones(batch.count))),
                    )
                train_timesteps += batch.count
                if num_steps_trained - last_target_update >= target_update_freq:
                    local_worker.update_target()
                    last_target_update = num_steps_trained

        num_steps_sampled += sample_timesteps
        num_steps_trained += train_timesteps
        time_delta = time.time() - start
        timers["sample"].push(time_delta)
        timers["sample"].push_units_processed(sample_timesteps)

        yield {
            "counters": {
                "num_steps_sampled": num_steps_sampled,
                "num_steps_trained": num_steps_trained,
                "num_weight_syncs": num_weight_syncs,
                "num_samples_dropped": num_samples_dropped,
            },
            "learner": learner,
        }


def sync_sample_lowlevel(workers: WorkerSet) -> Iterator[SampleBatch]:
    """Hand-written bulk-synchronous sampling loop (Fig 13a baseline)."""
    while True:
        futures = [w.apply(lambda t: t.sample()) for w in workers.remote_workers()]
        batches = [f.result() for f in futures]
        yield SampleBatch.concat_samples(batches)
