from repro.rl.advantages import discounted_returns, gae, vtrace
from repro.rl.env import (
    CartPole,
    MultiAgentCartPole,
    Pendulum,
    StubEnv,
    VectorEnv,
    VectorEnvState,
)
from repro.rl.inference import (
    AdmissionQueue,
    CreditGate,
    InferenceActor,
    InferenceClient,
    InferenceRouter,
    InferenceUnavailable,
)
from repro.rl.learner_group import ShardedLearnerGroup
from repro.rl.lm_policy import LMTokenPolicy
from repro.rl.model_based import ModelBasedWorker
from repro.rl.policy import (
    ActorCriticPolicy,
    DQNPolicy,
    DummyPolicy,
    SACPolicy,
)
from repro.rl.replay import ReplayBuffer
from repro.rl.rollout_worker import (
    MultiAgentRolloutWorker,
    PerEnvRolloutWorker,
    RolloutWorker,
    VectorizedRolloutWorker,
)
from repro.rl.sample_batch import MultiAgentBatch, SampleBatch, concat_batches
from repro.rl.stateful_policy import SSMStatePolicy
from repro.rl.token_env import (
    EOS,
    PAD,
    TokenEnv,
    TokenEnvState,
    make_obs,
    split_obs,
    target_token_reward,
)
from repro.rl.transformer_policy import TransformerPolicy

__all__ = [k for k in dir() if not k.startswith("_")]
