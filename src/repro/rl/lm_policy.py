"""LM token policy: the transformer model zoo as an RL actor-critic, with
KV-cache decode as the rollout fast path.

``LMTokenPolicy`` acts on ``TokenEnv`` observations (token window + length +
step, see ``rl/token_env.py``) with a real ``models/transformer.Model`` trunk:

  * **Learner path** — ``logits_value``/``loss`` run the full no-cache
    ``forward`` (flash-attention forward/backward via ``ops.flash_attention``)
    and read logits + value at each sequence's own last position.  This is
    what ``ShardedLearnerGroup`` fine-tunes.
  * **Decode path** — the PR 9 stateful-policy protocol
    (``init_lane_state``/``compute_actions_stateful``) carries a per-lane KV
    cache: prefill once when a lane starts an episode, then one
    ``decode_step`` per action via ``ops.decode_attention`` — O(1) work per
    token instead of re-running the O(S) forward.  The same surface serves
    both the vectorized rollout scan (``decode='cache'``) and the sticky
    serving tier (cache as server-side lane state).

The two paths are parity-gated: decode logits must match forward logits (see
``decode_parity_gap`` and tests/bench).  The prefill-or-decode choice is a
single ``lax.cond`` on "any lane fresh": with the sync ``TokenEnv`` all lanes
reset together so prefill runs exactly once per episode; with ragged resets
(or after a restore that lost lane state) re-prefilling *all* lanes from
their obs windows rebuilds byte-equivalent caches — correctness never
depends on the episodes being synchronized, only the speedup does.

Lane-state layout: every leaf carries the lane axis leading (the serving
tier gathers/scatters per-lane rows with ``tree_map``), so the model's
scan-stacked block caches ``[num_blocks, B, ...]`` are transposed to
``[B, num_blocks, ...]`` at the protocol boundary and back inside.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models.transformer import Model
from repro.rl.policy import mlp_apply, mlp_init
from repro.rl.token_env import split_obs

PyTree = Any

__all__ = ["LMTokenPolicy"]


def _lm_cfg(
    vocab_size: int, d_model: int, n_layers: int, num_heads: int, num_kv_heads: int
) -> ModelConfig:
    return ModelConfig(
        name="rl-lm",
        arch_type="dense",
        num_layers=n_layers,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv_heads,
        d_ff=d_model * 4,
        vocab_size=vocab_size,
        head_dim=d_model // num_heads,
        block_pattern=(LayerSpec(kind="attn", mlp="dense"),),
        dtype="float32",
    )


class LMTokenPolicy:
    """Discrete actor-critic over a causal LM; actions are vocabulary tokens."""

    def __init__(
        self,
        ctx: int,
        vocab_size: int,
        d_model: int = 32,
        n_layers: int = 2,
        num_heads: int = 2,
        num_kv_heads: int = 0,
        loss_kind: str = "ppo",
        vf_coef: float = 0.5,
        ent_coef: float = 0.01,
        clip_eps: float = 0.2,
    ):
        self.ctx = ctx
        self.vocab_size = vocab_size
        self.obs_dim = ctx + 2
        self.num_actions = vocab_size
        self.cfg = _lm_cfg(vocab_size, d_model, n_layers, num_heads, num_kv_heads or num_heads)
        self.model = Model(self.cfg)
        self.loss_kind = loss_kind
        self.vf_coef = vf_coef
        self.ent_coef = ent_coef
        self.clip_eps = clip_eps

    def init_params(self, key: jax.Array) -> PyTree:
        k1, k2 = jax.random.split(key)
        return {
            "lm": self.model.init_params(k1),
            "vf": mlp_init(k2, (self.cfg.d_model, 64, 1), scale_last=1.0),
        }

    # ------------------------------------------------------------ forward path
    def _heads(self, params: PyTree, h_last: jax.Array):
        """(logits [B,V], value [B]) from the last-position hidden [B,d]."""
        logits = self.model._head(params["lm"], h_last)
        value = mlp_apply(params["vf"], h_last)[..., 0]
        return logits, value

    def logits_value(self, params: PyTree, obs: jax.Array):
        """No-cache forward: full-sequence attention, read at length-1.

        Accepts any leading batch shape (the GAE bootstrap passes [T, N, D]).
        """
        lead = obs.shape[:-1]
        tokens, length, _ = split_obs(obs.reshape(-1, obs.shape[-1]), self.ctx)
        h, _ = self.model.forward(params["lm"], tokens)
        idx = jnp.clip(length - 1, 0, self.ctx - 1)
        h_last = h[jnp.arange(h.shape[0]), idx]
        logits, value = self._heads(params, h_last)
        return logits.reshape(lead + (self.vocab_size,)), value.reshape(lead)

    def value(self, params: PyTree, obs: jax.Array) -> jax.Array:
        """Critic value only (GAE bootstrap at truncation boundaries)."""
        return self.logits_value(params, obs)[1]

    def compute_actions(self, params: PyTree, obs: jax.Array, keys: jax.Array):
        """Batched acting with per-lane RNG keys (no cache — the slow path)."""
        logits, value = self.logits_value(params, obs)
        action = jax.vmap(jax.random.categorical)(keys, logits)
        logp_all = jax.nn.log_softmax(logits)
        logp = jnp.take_along_axis(logp_all, action[:, None], axis=-1)[:, 0]
        return action, logp, value, logits

    def act(self, params: PyTree, obs: jax.Array, key: jax.Array):
        """Single-obs acting (legacy per-env contract)."""
        a, lp, v, lg = self.compute_actions(params, obs[None], key[None])
        return a[0], lp[0], v[0], lg[0]

    # ------------------------------------------------ stateful-policy protocol
    def init_lane_state(self, n: int) -> PyTree:
        """Fresh per-lane KV cache (lane axis leading on every leaf)."""
        cache = self.model.init_cache(n, self.ctx)
        cache["pos"] = jnp.zeros((n,), jnp.int32)
        return self._to_lane_layout(cache)

    @staticmethod
    def _to_lane_layout(cache: PyTree) -> PyTree:
        out = dict(cache)
        out["blocks"] = jax.tree_util.tree_map(lambda x: jnp.moveaxis(x, 0, 1), cache["blocks"])
        return out

    @staticmethod
    def _to_model_layout(state: PyTree) -> PyTree:
        out = dict(state)
        out["blocks"] = jax.tree_util.tree_map(lambda x: jnp.moveaxis(x, 1, 0), state["blocks"])
        return out

    def compute_actions_stateful(
        self, params: PyTree, obs: jax.Array, keys: jax.Array, state: PyTree
    ) -> Tuple[jax.Array, jax.Array, jax.Array, PyTree]:
        """One generation step against the per-lane KV cache."""
        # Coerce eager numpy inputs (serving tier, scripts): indexing a
        # numpy array with a tracer inside lax.cond branches fails.
        obs = jnp.asarray(obs)
        B = obs.shape[0]
        tokens, length, t = split_obs(obs, self.ctx)
        cache = self._to_model_layout(state)
        idx = jnp.clip(length - 1, 0, self.ctx - 1)
        # A lane is fresh at episode start (t == 0) or whenever its cache
        # position disagrees with the sequence (state lost/restored/desynced):
        # either way a full re-prefill from the obs window rebuilds it.
        fresh = (t == 0) | (cache["pos"] != length - 1)

        def do_prefill(_):
            _, new_cache, h = self.model.prefill(
                params["lm"], tokens, window=self.ctx, with_hidden=True
            )
            new_cache["pos"] = length
            return h[jnp.arange(B), idx], new_cache

        def do_decode(_):
            last_tok = tokens[jnp.arange(B), idx][:, None]
            _, new_cache, h = self.model.decode_step(
                params["lm"], cache, last_tok, with_hidden=True
            )
            return h[:, 0], new_cache

        h_last, new_cache = jax.lax.cond(jnp.any(fresh), do_prefill, do_decode, None)
        logits, value = self._heads(params, h_last)
        action = jax.vmap(jax.random.categorical)(keys, logits)
        logp_all = jax.nn.log_softmax(logits)
        logp = jnp.take_along_axis(logp_all, action[:, None], axis=-1)[:, 0]
        return action, logp, value, self._to_lane_layout(new_cache)

    # ------------------------------------------------------------ parity gate
    def decode_parity_gap(self, params: PyTree, obs: jax.Array, state: PyTree) -> jax.Array:
        """Max |decode-path logits - forward-path logits| over a batch — the
        number the cache rollout is gated on (tests and bench_rlhf)."""
        tokens, length, _ = split_obs(obs, self.ctx)
        cache = self._to_model_layout(state)
        idx = jnp.clip(length - 1, 0, self.ctx - 1)
        last_tok = tokens[jnp.arange(obs.shape[0]), idx][:, None]
        dec_logits, _ = self.model.decode_step(params["lm"], cache, last_tok)
        fwd_logits, _ = self.logits_value(params, obs)
        return jnp.max(jnp.abs(dec_logits[:, 0] - fwd_logits))

    # ----------------------------------------------------------------- loss
    def loss(self, params: PyTree, batch: Dict[str, jax.Array]):
        from repro.rl.policy import ActorCriticPolicy

        proxy = ActorCriticPolicy.__new__(ActorCriticPolicy)
        proxy.loss_kind = self.loss_kind
        proxy.vf_coef = self.vf_coef
        proxy.ent_coef = self.ent_coef
        proxy.clip_eps = self.clip_eps
        proxy.gamma = 0.99
        proxy.rollout_len = 0
        proxy.logits_value = lambda p, o: self.logits_value(p, o)
        if self.loss_kind == "ppo":
            return proxy._ppo_loss(params, batch)
        return proxy._pg_loss(params, batch)
