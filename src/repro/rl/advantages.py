"""Advantage estimators: discounted returns, GAE, V-trace (IMPALA).

All are pure ``lax.scan``-based functions over time-major arrays so they can
live inside jitted rollout/learn steps.  These are also the *oracles* for
the Pallas-fused advantage kernels (``repro.kernels.advantages``): callers
that want the TPU-fused path go through ``repro.kernels.ops.fused_gae`` /
``fused_vtrace``, which dispatch to the kernels on TPU and to these exact
functions on CPU (parity asserted to 1e-5 by
``tests/test_kernel_advantages.py``).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["discounted_returns", "gae", "vtrace"]


def discounted_returns(
    rewards: jax.Array, dones: jax.Array, last_value: jax.Array, gamma: float
) -> jax.Array:
    """R_t = r_t + gamma * (1 - done_t) * R_{t+1};  time-major [T, ...]."""

    def scan_fn(carry, inp):
        r, d = inp
        ret = r + gamma * (1.0 - d) * carry
        return ret, ret

    _, returns = jax.lax.scan(
        scan_fn, last_value, (rewards, dones.astype(rewards.dtype)), reverse=True
    )
    return returns


def gae(
    rewards: jax.Array,
    values: jax.Array,
    dones: jax.Array,
    last_value: jax.Array,
    gamma: float = 0.99,
    lam: float = 0.95,
) -> Tuple[jax.Array, jax.Array]:
    """Generalized Advantage Estimation; returns (advantages, value_targets)."""
    dones_f = dones.astype(rewards.dtype)
    next_values = jnp.concatenate([values[1:], last_value[None]], axis=0)
    deltas = rewards + gamma * (1.0 - dones_f) * next_values - values

    def scan_fn(carry, inp):
        delta, d = inp
        adv = delta + gamma * lam * (1.0 - d) * carry
        return adv, adv

    _, advantages = jax.lax.scan(scan_fn, jnp.zeros_like(last_value), (deltas, dones_f), reverse=True)
    return advantages, advantages + values


def vtrace(
    behaviour_logp: jax.Array,
    target_logp: jax.Array,
    rewards: jax.Array,
    values: jax.Array,
    dones: jax.Array,
    last_value: jax.Array,
    gamma: float = 0.99,
    rho_clip: float = 1.0,
    c_clip: float = 1.0,
) -> Tuple[jax.Array, jax.Array]:
    """V-trace targets (IMPALA, Espeholt et al. 2018).

    Returns (vs, pg_advantages); all inputs time-major [T, ...].
    """
    rhos = jnp.exp(target_logp - behaviour_logp)
    clipped_rhos = jnp.minimum(rho_clip, rhos)
    cs = jnp.minimum(c_clip, rhos)
    dones_f = dones.astype(rewards.dtype)
    discounts = gamma * (1.0 - dones_f)
    next_values = jnp.concatenate([values[1:], last_value[None]], axis=0)
    deltas = clipped_rhos * (rewards + discounts * next_values - values)

    def scan_fn(acc, inp):
        delta, discount, c = inp
        acc = delta + discount * c * acc
        return acc, acc

    _, vs_minus_v = jax.lax.scan(scan_fn, jnp.zeros_like(last_value), (deltas, discounts, cs), reverse=True)
    vs = vs_minus_v + values
    next_vs = jnp.concatenate([vs[1:], last_value[None]], axis=0)
    pg_adv = clipped_rhos * (rewards + discounts * next_vs - values)
    return vs, pg_adv
