"""Transformer actor-critic policy: the model zoo's attention stack as an
RL trunk (connects repro/models to repro/rl).

The observation is projected into a short learned token sequence, run
through reduced-config transformer blocks (same attention/MLP code the LLM
dry-run lowers at pod scale), mean-pooled, and decoded by policy/value
heads.  Drop-in replacement for ActorCriticPolicy in any plan.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models.layers import attention_apply, attention_init, mlp_apply, mlp_init, rms_norm
from repro.rl.policy import mlp_apply as head_apply, mlp_init as head_init

PyTree = Any

__all__ = ["TransformerPolicy"]


def _trunk_cfg(d_model: int, n_layers: int) -> ModelConfig:
    return ModelConfig(
        name="rl-trunk",
        arch_type="dense",
        num_layers=n_layers,
        d_model=d_model,
        num_heads=max(d_model // 32, 1),
        num_kv_heads=max(d_model // 32, 1),
        d_ff=d_model * 4,
        vocab_size=2,  # unused (no embedding table; obs are projected)
        block_pattern=(LayerSpec(kind="attn", mlp="dense"),),
        dtype="float32",
    )


class TransformerPolicy:
    """Discrete actor-critic with a transformer trunk over obs tokens."""

    def __init__(
        self,
        obs_dim: int,
        num_actions: int,
        d_model: int = 64,
        n_layers: int = 2,
        n_tokens: int = 4,
        loss_kind: str = "ppo",
        vf_coef: float = 0.5,
        ent_coef: float = 0.01,
        clip_eps: float = 0.2,
    ):
        self.obs_dim = obs_dim
        self.num_actions = num_actions
        self.cfg = _trunk_cfg(d_model, n_layers)
        self.n_tokens = n_tokens
        self.loss_kind = loss_kind
        self.vf_coef = vf_coef
        self.ent_coef = ent_coef
        self.clip_eps = clip_eps

    def init_params(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        ks = jax.random.split(key, cfg.num_layers + 4)
        params: Dict[str, Any] = {
            "obs_proj": (
                jax.random.normal(ks[0], (self.obs_dim, self.n_tokens * cfg.d_model), jnp.float32)
                * 0.2
            ),
            "pos": jax.random.normal(ks[1], (self.n_tokens, cfg.d_model), jnp.float32) * 0.02,
            "pi_head": head_init(ks[2], (cfg.d_model, 64, self.num_actions)),
            "vf_head": head_init(ks[3], (cfg.d_model, 64, 1), scale_last=1.0),
        }
        for i in range(cfg.num_layers):
            lk1, lk2 = jax.random.split(ks[4 + i])
            params[f"layer_{i}"] = {
                "norm1": jnp.ones((cfg.d_model,), jnp.float32),
                "attn": attention_init(lk1, cfg),
                "norm2": jnp.ones((cfg.d_model,), jnp.float32),
                "mlp": mlp_init(lk2, cfg, cfg.d_ff),
            }
        return params

    def _trunk(self, params: PyTree, obs: jax.Array) -> jax.Array:
        cfg = self.cfg
        B = obs.shape[0]
        x = (obs @ params["obs_proj"]).reshape(B, self.n_tokens, cfg.d_model)
        x = x + params["pos"][None]
        for i in range(cfg.num_layers):
            lp = params[f"layer_{i}"]
            h = rms_norm(x, lp["norm1"], cfg.norm_eps)
            x = x + attention_apply(lp["attn"], h, cfg)
            h = rms_norm(x, lp["norm2"], cfg.norm_eps)
            x = x + mlp_apply(lp["mlp"], h, cfg)
        return jnp.mean(x, axis=1)  # [B, d]

    def logits_value(self, params: PyTree, obs: jax.Array):
        z = self._trunk(params, obs)
        return head_apply(params["pi_head"], z), head_apply(params["vf_head"], z)[..., 0]

    def act(self, params: PyTree, obs: jax.Array, key: jax.Array):
        logits, value = self.logits_value(params, obs)
        action = jax.random.categorical(key, logits)
        logp_all = jax.nn.log_softmax(logits)
        logp = jnp.take_along_axis(logp_all, action[..., None], axis=-1)[..., 0]
        return action, logp, value, logits

    def value(self, params: PyTree, obs: jax.Array) -> jax.Array:
        """Critic value only (GAE bootstrap at truncation boundaries)."""
        return self.logits_value(params, obs)[1]

    def compute_actions(self, params: PyTree, obs: jax.Array, keys: jax.Array):
        """Batched acting with per-lane RNG keys: obs [N, D], keys [N, 2].

        One trunk dispatch for all lanes; each lane samples from its own key,
        so lane i reproduces ``act(params, obs[i:i+1], keys[i])``.
        """
        logits, value = self.logits_value(params, obs)
        action = jax.vmap(jax.random.categorical)(keys, logits)
        logp_all = jax.nn.log_softmax(logits)
        logp = jnp.take_along_axis(logp_all, action[:, None], axis=-1)[:, 0]
        return action, logp, value, logits

    # ------------------------------------------------ stateful-policy protocol
    # The trunk is memoryless, so the lane state is degenerate — a per-lane
    # step counter.  The surface still matters: it lets this policy ride the
    # sticky serving tier and the decode-configured rollout engine through
    # the exact same protocol a KV-cache or SSM policy uses.
    def init_lane_state(self, n: int) -> PyTree:
        return {"steps": jnp.zeros((n,), jnp.int32)}

    def compute_actions_stateful(
        self, params: PyTree, obs: jax.Array, keys: jax.Array, state: PyTree
    ):
        action, logp, value, _ = self.compute_actions(params, obs, keys)
        return action, logp, value, {"steps": state["steps"] + 1}

    # Reuse ActorCriticPolicy's loss math via composition.
    def loss(self, params: PyTree, batch: Dict[str, jax.Array]):
        from repro.rl.policy import ActorCriticPolicy

        proxy = ActorCriticPolicy.__new__(ActorCriticPolicy)
        proxy.loss_kind = self.loss_kind
        proxy.vf_coef = self.vf_coef
        proxy.ent_coef = self.ent_coef
        proxy.clip_eps = self.clip_eps
        proxy.gamma = 0.99
        proxy.rollout_len = 0
        proxy.logits_value = lambda p, o: self.logits_value(p, o)
        if self.loss_kind == "ppo":
            return proxy._ppo_loss(params, batch)
        return proxy._pg_loss(params, batch)
