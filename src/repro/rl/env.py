"""JAX-native vectorized environments.

Environments are pure functions over explicit state, vmapped over a batch of
parallel env instances and jitted — the whole rollout loop compiles to one
XLA program per worker (``lax.scan`` over time).

    reset(key)            -> EnvState, obs
    step(state, action)   -> EnvState, obs, reward, done

Auto-reset on done (standard vectorized-env semantics).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CartPole", "Pendulum", "Env", "MultiAgentCartPole"]


class Env:
    """Protocol: subclasses define obs_dim / num_actions / reset / step."""

    obs_dim: int
    num_actions: int  # -1 for continuous
    action_dim: int = 0

    def reset(self, key: jax.Array) -> Tuple[Any, jax.Array]:
        raise NotImplementedError

    def step(self, state: Any, action: jax.Array, key: jax.Array):
        raise NotImplementedError


class CartPoleState(NamedTuple):
    x: jax.Array
    x_dot: jax.Array
    theta: jax.Array
    theta_dot: jax.Array
    t: jax.Array


class CartPole(Env):
    """Classic control CartPole-v0 dynamics (the paper's benchmark env)."""

    obs_dim = 4
    num_actions = 2
    max_steps = 200

    gravity = 9.8
    masscart = 1.0
    masspole = 0.1
    total_mass = masspole + masscart
    length = 0.5
    polemass_length = masspole * length
    force_mag = 10.0
    tau = 0.02
    theta_threshold = 12 * 2 * np.pi / 360
    x_threshold = 2.4

    def reset(self, key: jax.Array) -> Tuple[CartPoleState, jax.Array]:
        vals = jax.random.uniform(key, (4,), minval=-0.05, maxval=0.05)
        st = CartPoleState(vals[0], vals[1], vals[2], vals[3], jnp.zeros((), jnp.int32))
        return st, self._obs(st)

    @staticmethod
    def _obs(st: CartPoleState) -> jax.Array:
        return jnp.stack([st.x, st.x_dot, st.theta, st.theta_dot])

    def step(self, st: CartPoleState, action: jax.Array, key: jax.Array):
        force = jnp.where(action == 1, self.force_mag, -self.force_mag)
        costheta, sintheta = jnp.cos(st.theta), jnp.sin(st.theta)
        temp = (
            force + self.polemass_length * st.theta_dot**2 * sintheta
        ) / self.total_mass
        thetaacc = (self.gravity * sintheta - costheta * temp) / (
            self.length * (4.0 / 3.0 - self.masspole * costheta**2 / self.total_mass)
        )
        xacc = temp - self.polemass_length * thetaacc * costheta / self.total_mass
        new = CartPoleState(
            st.x + self.tau * st.x_dot,
            st.x_dot + self.tau * xacc,
            st.theta + self.tau * st.theta_dot,
            st.theta_dot + self.tau * thetaacc,
            st.t + 1,
        )
        done = (
            (jnp.abs(new.x) > self.x_threshold)
            | (jnp.abs(new.theta) > self.theta_threshold)
            | (new.t >= self.max_steps)
        )
        reward = jnp.ones(())
        # Auto-reset on termination.
        reset_st, _ = self.reset(key)
        out = jax.tree_util.tree_map(
            lambda a, b: jnp.where(done, a, b), reset_st, new
        )
        return out, self._obs(out), reward, done


class PendulumState(NamedTuple):
    theta: jax.Array
    theta_dot: jax.Array
    t: jax.Array


class Pendulum(Env):
    """Pendulum-v1 (continuous torque) for SAC-style continuous control."""

    obs_dim = 3
    num_actions = -1
    action_dim = 1
    max_steps = 200
    max_speed = 8.0
    max_torque = 2.0
    dt = 0.05
    g = 10.0
    m = 1.0
    length = 1.0

    def reset(self, key: jax.Array) -> Tuple[PendulumState, jax.Array]:
        k1, k2 = jax.random.split(key)
        theta = jax.random.uniform(k1, (), minval=-np.pi, maxval=np.pi)
        theta_dot = jax.random.uniform(k2, (), minval=-1.0, maxval=1.0)
        st = PendulumState(theta, theta_dot, jnp.zeros((), jnp.int32))
        return st, self._obs(st)

    @staticmethod
    def _obs(st: PendulumState) -> jax.Array:
        return jnp.stack([jnp.cos(st.theta), jnp.sin(st.theta), st.theta_dot])

    def step(self, st: PendulumState, action: jax.Array, key: jax.Array):
        u = jnp.clip(action.reshape(()) * self.max_torque, -self.max_torque, self.max_torque)
        th = ((st.theta + np.pi) % (2 * np.pi)) - np.pi
        cost = th**2 + 0.1 * st.theta_dot**2 + 0.001 * u**2
        new_dot = st.theta_dot + (
            3 * self.g / (2 * self.length) * jnp.sin(st.theta)
            + 3.0 / (self.m * self.length**2) * u
        ) * self.dt
        new_dot = jnp.clip(new_dot, -self.max_speed, self.max_speed)
        new = PendulumState(st.theta + new_dot * self.dt, new_dot, st.t + 1)
        done = new.t >= self.max_steps
        reset_st, _ = self.reset(key)
        out = jax.tree_util.tree_map(lambda a, b: jnp.where(done, a, b), reset_st, new)
        return out, self._obs(out), -cost, done


class MultiAgentCartPole:
    """N independent CartPole agents in one logical env (paper Fig 11/14:
    'multi-agent Atari with four agents per policy' analogue).

    ``policy_mapping`` assigns each agent index to a policy id; rollout
    workers return a MultiAgentBatch keyed by policy id.
    """

    def __init__(self, num_agents: int, policy_mapping: Dict[int, str]):
        self.base = CartPole()
        self.num_agents = num_agents
        self.policy_mapping = dict(policy_mapping)
        self.obs_dim = self.base.obs_dim
        self.num_actions = self.base.num_actions

    def reset(self, key: jax.Array):
        keys = jax.random.split(key, self.num_agents)
        st, obs = jax.vmap(self.base.reset)(keys)
        return st, obs  # obs: [A, obs_dim]

    def step(self, st: Any, actions: jax.Array, key: jax.Array):
        keys = jax.random.split(key, self.num_agents)
        return jax.vmap(self.base.step)(st, actions, keys)
