"""JAX-native vectorized environments.

Environments are pure functions over explicit state, vmapped over a batch of
parallel env instances and jitted — the whole rollout loop compiles to one
XLA program per worker (``lax.scan`` over time).

    reset(key)            -> EnvState, obs
    step(state, action)   -> EnvState, obs, reward, done

Auto-reset on done (standard vectorized-env semantics).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CartPole",
    "Pendulum",
    "StubEnv",
    "Env",
    "MultiAgentCartPole",
    "VectorEnv",
    "VectorEnvState",
    "VectorStep",
]


class Env:
    """Protocol: subclasses define obs_dim / num_actions / reset / step.

    ``step_raw`` is the auto-reset-free half of ``step``: it returns the
    *true* successor state/obs plus a terminated/truncated split, and leaves
    episode-boundary handling to the caller (``VectorEnv`` owns auto-reset
    for the vectorized rollout engine).  ``step`` keeps the legacy
    auto-resetting semantics and is implemented on top of ``step_raw``.
    """

    obs_dim: int
    num_actions: int  # -1 for continuous
    action_dim: int = 0

    def reset(self, key: jax.Array) -> Tuple[Any, jax.Array]:
        raise NotImplementedError

    def step_raw(self, state: Any, action: jax.Array, key: jax.Array):
        """(state, action, key) -> (state', obs', reward, terminated, truncated).

        No auto-reset: ``state'``/``obs'`` are the true successors even on
        episode end.  ``terminated`` is environment death (value bootstrap
        must be zero); ``truncated`` is an artificial horizon (bootstrap from
        the successor value is correct).
        """
        raise NotImplementedError

    def step(self, state: Any, action: jax.Array, key: jax.Array):
        """Legacy auto-resetting step: (state', obs', reward, done)."""
        new, obs, reward, terminated, truncated = self.step_raw(state, action, key)
        done = terminated | truncated
        reset_st, reset_obs = self.reset(key)
        out = jax.tree_util.tree_map(lambda a, b: jnp.where(done, a, b), reset_st, new)
        obs = jnp.where(done, reset_obs, obs)
        return out, obs, reward, done


class CartPoleState(NamedTuple):
    x: jax.Array
    x_dot: jax.Array
    theta: jax.Array
    theta_dot: jax.Array
    t: jax.Array


class CartPole(Env):
    """Classic control CartPole-v0 dynamics (the paper's benchmark env)."""

    obs_dim = 4
    num_actions = 2
    max_steps = 200

    gravity = 9.8
    masscart = 1.0
    masspole = 0.1
    total_mass = masspole + masscart
    length = 0.5
    polemass_length = masspole * length
    force_mag = 10.0
    tau = 0.02
    theta_threshold = 12 * 2 * np.pi / 360
    x_threshold = 2.4

    def reset(self, key: jax.Array) -> Tuple[CartPoleState, jax.Array]:
        vals = jax.random.uniform(key, (4,), minval=-0.05, maxval=0.05)
        st = CartPoleState(vals[0], vals[1], vals[2], vals[3], jnp.zeros((), jnp.int32))
        return st, self._obs(st)

    @staticmethod
    def _obs(st: CartPoleState) -> jax.Array:
        return jnp.stack([st.x, st.x_dot, st.theta, st.theta_dot])

    def step_raw(self, st: CartPoleState, action: jax.Array, key: jax.Array):
        force = jnp.where(action == 1, self.force_mag, -self.force_mag)
        costheta, sintheta = jnp.cos(st.theta), jnp.sin(st.theta)
        temp = (
            force + self.polemass_length * st.theta_dot**2 * sintheta
        ) / self.total_mass
        thetaacc = (self.gravity * sintheta - costheta * temp) / (
            self.length * (4.0 / 3.0 - self.masspole * costheta**2 / self.total_mass)
        )
        xacc = temp - self.polemass_length * thetaacc * costheta / self.total_mass
        new = CartPoleState(
            st.x + self.tau * st.x_dot,
            st.x_dot + self.tau * xacc,
            st.theta + self.tau * st.theta_dot,
            st.theta_dot + self.tau * thetaacc,
            st.t + 1,
        )
        terminated = (jnp.abs(new.x) > self.x_threshold) | (
            jnp.abs(new.theta) > self.theta_threshold
        )
        truncated = (new.t >= self.max_steps) & ~terminated
        reward = jnp.ones(())
        return new, self._obs(new), reward, terminated, truncated


class PendulumState(NamedTuple):
    theta: jax.Array
    theta_dot: jax.Array
    t: jax.Array


class Pendulum(Env):
    """Pendulum-v1 (continuous torque) for SAC-style continuous control."""

    obs_dim = 3
    num_actions = -1
    action_dim = 1
    max_steps = 200
    max_speed = 8.0
    max_torque = 2.0
    dt = 0.05
    g = 10.0
    m = 1.0
    length = 1.0

    def reset(self, key: jax.Array) -> Tuple[PendulumState, jax.Array]:
        k1, k2 = jax.random.split(key)
        theta = jax.random.uniform(k1, (), minval=-np.pi, maxval=np.pi)
        theta_dot = jax.random.uniform(k2, (), minval=-1.0, maxval=1.0)
        st = PendulumState(theta, theta_dot, jnp.zeros((), jnp.int32))
        return st, self._obs(st)

    @staticmethod
    def _obs(st: PendulumState) -> jax.Array:
        return jnp.stack([jnp.cos(st.theta), jnp.sin(st.theta), st.theta_dot])

    def step_raw(self, st: PendulumState, action: jax.Array, key: jax.Array):
        u = jnp.clip(action.reshape(()) * self.max_torque, -self.max_torque, self.max_torque)
        th = ((st.theta + np.pi) % (2 * np.pi)) - np.pi
        cost = th**2 + 0.1 * st.theta_dot**2 + 0.001 * u**2
        new_dot = st.theta_dot + (
            3 * self.g / (2 * self.length) * jnp.sin(st.theta)
            + 3.0 / (self.m * self.length**2) * u
        ) * self.dt
        new_dot = jnp.clip(new_dot, -self.max_speed, self.max_speed)
        new = PendulumState(st.theta + new_dot * self.dt, new_dot, st.t + 1)
        truncated = new.t >= self.max_steps  # pendulum never terminates
        return new, self._obs(new), -cost, jnp.zeros((), bool), truncated


class StubEnvState(NamedTuple):
    x: jax.Array  # [obs_dim]
    t: jax.Array


class StubEnv(Env):
    """Deterministic stub environment for tests and rollout benchmarks.

    All dynamics are *elementwise* (no reductions, no matmuls), so a vmapped
    lane is bit-identical to the same lane stepped alone — the property the
    vectorized-vs-per-env determinism suite relies on.  Episodes terminate
    when ``x[0]`` drifts out of bounds and truncate at ``max_steps``; the
    terminated/truncated split makes it the reference env for bootstrap
    handling.
    """

    obs_dim = 4
    num_actions = 2

    def __init__(self, max_steps: int = 16, drift: float = 0.3, threshold: float = 4.0):
        self.max_steps = max_steps
        self.drift = drift
        self.threshold = threshold

    def reset(self, key: jax.Array) -> Tuple[StubEnvState, jax.Array]:
        x = jax.random.uniform(key, (self.obs_dim,), minval=-0.5, maxval=0.5)
        st = StubEnvState(x, jnp.zeros((), jnp.int32))
        return st, st.x

    def step_raw(self, st: StubEnvState, action: jax.Array, key: jax.Array):
        direction = jnp.where(action == 1, 1.0, -1.0)
        x = st.x * 0.95 + direction * self.drift
        new = StubEnvState(x, st.t + 1)
        terminated = jnp.abs(x[0]) > self.threshold
        truncated = (new.t >= self.max_steps) & ~terminated
        reward = 1.0 + 0.1 * jnp.tanh(x[0])
        return new, new.x, reward, terminated, truncated


# --------------------------------------------------------------- VectorEnv
class VectorEnvState(NamedTuple):
    """Everything the vectorized rollout engine carries between steps.

    ``rng`` holds one PRNG key per lane (the per-lane split the determinism
    suite pins down); ``eps_count`` counts completed episodes per lane so
    fragment assembly can stamp globally unique episode ids; all fields are
    a pure pytree — checkpointable via ``VectorEnv.state_to_numpy``.
    """

    env_state: Any        # batched env pytree, leading dim N
    obs: jax.Array        # [N, obs_dim] current (post-reset) observations
    rng: jax.Array        # [N, 2] per-lane PRNG keys
    ep_return: jax.Array  # [N] running episode returns
    ep_len: jax.Array     # [N] running episode lengths
    eps_count: jax.Array  # [N] int32 completed-episode counter per lane


class VectorStep(NamedTuple):
    """Per-step outputs of ``VectorEnv.step`` (all leading dim N)."""

    obs: jax.Array         # post-auto-reset obs (what the policy sees next)
    next_obs: jax.Array    # TRUE successor obs (pre-reset; bootstrap source)
    reward: jax.Array
    terminated: jax.Array  # bool: env death (zero bootstrap)
    truncated: jax.Array   # bool: horizon cut (bootstrap from next_obs value)
    done: jax.Array        # terminated | truncated (auto-reset happened)
    completed_return: jax.Array  # episode return where done, else 0
    eps_count: jax.Array   # int32 episode index each lane was in THIS step


class VectorEnv:
    """N synchronized instances of a base env with auto-reset semantics.

    The paper's rollout fragment (§4) assumed one env per policy call; the
    vectorized engine steps all N lanes per call with a single batched
    policy dispatch (SRL / HybridFlow's decoupling move).  Everything is
    pure-JAX and vmapped, so a worker's whole T×N rollout still compiles to
    one ``lax.scan`` program.

    Per-lane RNG: ``reset(key)`` folds the lane index into the master key,
    and every step splits each lane's key chain independently — lane ``i``
    of a ``VectorEnv`` consumes exactly the key stream a standalone env
    seeded with ``fold_in(key, i)`` would, which is what makes vectorized
    rollouts bit-reproduce per-env rollouts.

    Auto-reset is owned here (via ``env.step_raw``), so both the true
    successor obs (for bootstrap) and the post-reset obs (for the next
    action) are exposed.  Envs lacking ``step_raw`` fall back to the legacy
    auto-resetting ``step`` with ``truncated == False`` and ``next_obs``
    equal to the post-reset obs.
    """

    def __init__(self, env: Env, num_envs: int):
        if num_envs < 1:
            raise ValueError(f"VectorEnv needs num_envs >= 1 (got {num_envs})")
        self.env = env
        self.num_envs = num_envs
        self.obs_dim = env.obs_dim
        self.num_actions = env.num_actions
        self.action_dim = getattr(env, "action_dim", 0)
        self._has_raw = hasattr(type(env), "step_raw") and (
            type(env).step_raw is not Env.step_raw
        )

    # ---------------------------------------------------------------- reset
    def reset(self, key: jax.Array) -> VectorEnvState:
        lane_keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
            jnp.arange(self.num_envs)
        )
        next_rng, reset_keys = self._split_lanes(lane_keys)
        env_state, obs = jax.vmap(self.env.reset)(reset_keys)
        n = self.num_envs
        return VectorEnvState(
            env_state=env_state,
            obs=obs,
            rng=next_rng,
            ep_return=jnp.zeros((n,), jnp.float32),
            ep_len=jnp.zeros((n,), jnp.int32),
            eps_count=jnp.zeros((n,), jnp.int32),
        )

    @staticmethod
    def _split_lanes(rng: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """[N,2] lane keys -> (next chain keys, per-lane subkeys)."""
        both = jax.vmap(lambda k: jax.random.split(k, 2))(rng)
        return both[:, 0], both[:, 1]

    # ----------------------------------------------------------------- step
    def step(self, state: VectorEnvState, actions: jax.Array) -> Tuple[VectorEnvState, VectorStep]:
        rng, k_step = self._split_lanes(state.rng)
        rng, k_reset = self._split_lanes(rng)
        if self._has_raw:
            new_env, next_obs, reward, terminated, truncated = jax.vmap(
                self.env.step_raw
            )(state.env_state, actions, k_step)
            done = terminated | truncated
            reset_env, reset_obs = jax.vmap(self.env.reset)(k_reset)
            env_state = jax.tree_util.tree_map(
                lambda a, b: jnp.where(
                    done.reshape((-1,) + (1,) * (a.ndim - 1)) if a.ndim > 1 else done,
                    a, b,
                ),
                reset_env, new_env,
            )
            obs = jnp.where(done[:, None], reset_obs, next_obs)
        else:
            env_state, obs, reward, done = jax.vmap(self.env.step)(
                state.env_state, actions, k_step
            )
            next_obs = obs  # legacy envs reset internally; successor is lost
            terminated = done
            truncated = jnp.zeros_like(done)
        new_ret = state.ep_return + reward
        completed = jnp.where(done, new_ret, 0.0)
        out = VectorStep(
            obs=obs,
            next_obs=next_obs,
            reward=reward,
            terminated=terminated,
            truncated=truncated,
            done=done,
            completed_return=completed,
            eps_count=state.eps_count,
        )
        new_state = VectorEnvState(
            env_state=env_state,
            obs=obs,
            rng=rng,
            ep_return=jnp.where(done, 0.0, new_ret),
            ep_len=jnp.where(done, 0, state.ep_len + 1),
            eps_count=state.eps_count + done.astype(jnp.int32),
        )
        return new_state, out

    # ----------------------------------------------------------- durability
    @staticmethod
    def state_to_numpy(state: VectorEnvState) -> Any:
        """Device pytree -> picklable numpy pytree (checkpoint payload)."""
        return jax.tree_util.tree_map(lambda x: np.asarray(x), state)

    @staticmethod
    def state_from_numpy(state: Any) -> VectorEnvState:
        leaves, treedef = jax.tree_util.tree_flatten(state)
        return jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(l) for l in leaves]
        )

    def __repr__(self) -> str:  # pragma: no cover
        return f"VectorEnv({type(self.env).__name__}, num_envs={self.num_envs})"


class MultiAgentCartPole:
    """N independent CartPole agents in one logical env (paper Fig 11/14:
    'multi-agent Atari with four agents per policy' analogue).

    ``policy_mapping`` assigns each agent index to a policy id; rollout
    workers return a MultiAgentBatch keyed by policy id.
    """

    def __init__(self, num_agents: int, policy_mapping: Dict[int, str]):
        self.base = CartPole()
        self.num_agents = num_agents
        self.policy_mapping = dict(policy_mapping)
        self.obs_dim = self.base.obs_dim
        self.num_actions = self.base.num_actions

    def reset(self, key: jax.Array):
        keys = jax.random.split(key, self.num_agents)
        st, obs = jax.vmap(self.base.reset)(keys)
        return st, obs  # obs: [A, obs_dim]

    def step(self, st: Any, actions: jax.Array, key: jax.Array):
        keys = jax.random.split(key, self.num_agents)
        return jax.vmap(self.base.step)(st, actions, keys)
