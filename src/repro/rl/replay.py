"""Replay buffers: the actor target behind ``Replay`` / ``StoreToReplayBuffer``.

Host-memory (numpy) circular storage — replay never occupies device HBM
(DESIGN.md §3.5).  Proportional prioritized sampling (Ape-X / PER) with
importance weights, plus a uniform mode for vanilla DQN/SAC.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, Optional

import numpy as np

from repro.rl.sample_batch import SampleBatch

__all__ = ["ReplayBuffer"]


class ReplayBuffer:
    """Circular replay store keyed by column; thread-safe (actor mailbox
    already serializes calls, the lock guards direct driver access)."""

    def __init__(
        self,
        capacity: int = 50_000,
        sample_batch_size: int = 128,
        prioritized: bool = True,
        alpha: float = 0.6,
        beta: float = 0.4,
        learning_starts: int = 1000,
        seed: int = 0,
    ):
        self.capacity = capacity
        self.sample_batch_size = sample_batch_size
        self.prioritized = prioritized
        self.alpha = alpha
        self.beta = beta
        self.learning_starts = learning_starts
        self._rng = np.random.default_rng(seed)
        self._cols: Dict[str, np.ndarray] = {}
        self._priorities = np.zeros((capacity,), np.float64)
        self._max_prio = 1.0
        self._next = 0
        self._size = 0
        self._lock = threading.Lock()
        self.num_added = 0
        self.num_sampled = 0

    # ------------------------------------------------------------------ add
    def add_batch(self, batch: SampleBatch) -> int:
        with self._lock:
            n = batch.count
            if not self._cols:
                for k, v in batch.items():
                    self._cols[k] = np.zeros((self.capacity,) + v.shape[1:], v.dtype)
            idx = (self._next + np.arange(n)) % self.capacity
            for k, v in batch.items():
                if k in self._cols:
                    self._cols[k][idx] = v
            self._priorities[idx] = self._max_prio
            self._next = int((self._next + n) % self.capacity)
            self._size = int(min(self._size + n, self.capacity))
            self.num_added += n
            return self._size

    # --------------------------------------------------------------- sample
    def replay(self) -> Optional[SampleBatch]:
        with self._lock:
            if self._size < max(self.learning_starts, self.sample_batch_size):
                time.sleep(0.001)  # cold buffer: avoid a hot polling loop
                return None
            n = self.sample_batch_size
            if self.prioritized:
                p = self._priorities[: self._size] ** self.alpha
                p = p / p.sum()
                idx = self._rng.choice(self._size, size=n, p=p, replace=True)
                w = (self._size * p[idx]) ** (-self.beta)
                w = w / w.max()
            else:
                idx = self._rng.integers(0, self._size, size=n)
                w = np.ones((n,), np.float32)
            out = {k: v[idx] for k, v in self._cols.items()}
            out["weights"] = w.astype(np.float32)
            out["batch_indices"] = idx.astype(np.int64)
            self.num_sampled += n
            return SampleBatch(out)

    # ------------------------------------------------------------ priorities
    def update_priorities(self, indices: np.ndarray, priorities: np.ndarray) -> None:
        with self._lock:
            pr = np.asarray(priorities, np.float64) + 1e-6
            self._priorities[np.asarray(indices, np.int64)] = pr
            self._max_prio = max(self._max_prio, float(pr.max()))

    def stats(self) -> Dict[str, Any]:
        return {
            "size": self._size,
            "added": self.num_added,
            "sampled": self.num_sampled,
            # Data-plane accounting (ISSUE 3): resident bytes + bytes per
            # replayed batch, for occupancy dashboards and bytes/step math.
            "size_bytes": int(sum(v.nbytes for v in self._cols.values())),
            "batch_bytes": int(
                sum(v[: self.sample_batch_size].nbytes for v in self._cols.values())
            ),
        }

    # ------------------------------------------------------------ durability
    def get_state(self) -> Dict[str, Any]:
        """Full resumable state (storage, priorities, cursors, RNG) for
        ``Algorithm.save()``: a restore replays *identically*, including the
        sampling stream."""
        with self._lock:
            return {
                "cols": {k: v.copy() for k, v in self._cols.items()},
                "priorities": self._priorities.copy(),
                "next": self._next,
                "size": self._size,
                "max_prio": self._max_prio,
                "num_added": self.num_added,
                "num_sampled": self.num_sampled,
                "rng": self._rng.bit_generator.state,
            }

    def set_state(self, state: Dict[str, Any]) -> None:
        if len(state["priorities"]) != self.capacity:
            raise ValueError(
                f"checkpointed replay state has capacity {len(state['priorities'])} "
                f"but this buffer was built with capacity {self.capacity}; "
                "restore into a matching buffer"
            )
        with self._lock:
            self._cols = {k: v.copy() for k, v in state["cols"].items()}
            self._priorities = state["priorities"].copy()
            self._next = int(state["next"])
            self._size = int(state["size"])
            self._max_prio = float(state["max_prio"])
            self.num_added = int(state["num_added"])
            self.num_sampled = int(state["num_sampled"])
            self._rng.bit_generator.state = state["rng"]

    def __len__(self) -> int:
        return self._size
