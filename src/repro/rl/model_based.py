"""Model-based RL worker: learned dynamics ensemble + synthetic rollouts.

The paper's flexibility argument (§2.2, §6 "an undergraduate implemented
MB-MPO/Dreamer"): model-based training adds a supervised dynamics-model
stream on top of model-free RL, 'breaking the mold' of fixed execution
patterns.  In RLlib Flow it is just one more concurrent sub-flow — see
``plans.mbpo_plan``:

    (1) env rollouts  -> replay                      (real experience)
    (2) replay        -> TrainDynamicsModel          (supervised stream)
    (3) synthetic rollouts (policy x learned model) -> TrainOneStep(policy)

This worker extends RolloutWorker with a probabilistic dynamics ensemble
(predicts delta-obs and reward) and a jitted synthetic-rollout scan.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam
from repro.rl.advantages import gae
from repro.rl.policy import mlp_apply, mlp_init
from repro.rl.rollout_worker import RolloutWorker, _to_numpy_batch
from repro.rl.sample_batch import SampleBatch

PyTree = Any

__all__ = ["ModelBasedWorker"]


class ModelBasedWorker(RolloutWorker):
    """RolloutWorker + dynamics ensemble + synthetic rollouts."""

    def __init__(
        self,
        *args: Any,
        ensemble_size: int = 2,
        model_hidden: Tuple[int, ...] = (64, 64),
        model_lr: float = 1e-3,
        synth_rollout_len: int = 8,
        synth_batch: int = 64,
        **kwargs: Any,
    ):
        super().__init__(*args, **kwargs)
        self.ensemble_size = ensemble_size
        self.synth_rollout_len = synth_rollout_len
        self.synth_batch = synth_batch
        obs_dim = self.env.obs_dim
        in_dim = obs_dim + 1  # obs + discrete action index
        out_dim = obs_dim + 1  # delta obs + reward
        keys = jax.random.split(jax.random.PRNGKey(271 + self.worker_index), ensemble_size)
        self.dyn_params = [
            mlp_init(k, (in_dim, *model_hidden, out_dim), scale_last=0.1) for k in keys
        ]
        self.dyn_opt = adam(model_lr)
        self.dyn_opt_states = [self.dyn_opt.init(p) for p in self.dyn_params]
        self._dyn_learn_jit = jax.jit(self._dyn_learn)
        self._synth_jit = jax.jit(self._synth_rollout)
        self.dyn_losses: list = []

    # ------------------------------------------------------------ dynamics
    def _dyn_forward(self, params: PyTree, obs: jax.Array, act: jax.Array):
        x = jnp.concatenate([obs, act[:, None].astype(jnp.float32)], axis=-1)
        out = mlp_apply(params, x)
        return out[:, :-1], out[:, -1]  # delta obs, reward

    def _dyn_loss(self, params: PyTree, batch: Dict[str, jax.Array]):
        d_obs, rew = self._dyn_forward(params, batch["obs"], batch["actions"])
        target = batch["next_obs"] - batch["obs"]
        return jnp.mean(jnp.square(d_obs - target)) + jnp.mean(
            jnp.square(rew - batch["rewards"])
        )

    def _dyn_learn(self, params: PyTree, opt_state: PyTree, batch: Dict[str, jax.Array]):
        loss, grads = jax.value_and_grad(self._dyn_loss)(params, batch)
        params, opt_state = self.dyn_opt.apply(params, grads, opt_state)
        return params, opt_state, loss

    def train_dynamics(self, batch: SampleBatch) -> Dict[str, float]:
        dev = {k: jnp.asarray(v) for k, v in batch.items() if k != "batch_indices"}
        losses = []
        for i in range(self.ensemble_size):
            self.dyn_params[i], self.dyn_opt_states[i], loss = self._dyn_learn_jit(
                self.dyn_params[i], self.dyn_opt_states[i], dev
            )
            losses.append(float(loss))
        self.dyn_losses = losses
        return {"dyn_loss": float(np.mean(losses))}

    # ---------------------------------------------------- synthetic rollout
    def _synth_rollout(
        self, policy_params: PyTree, dyn_params: PyTree, start_obs: jax.Array, key: jax.Array
    ):
        """Roll the CURRENT policy through the LEARNED model (one ensemble
        member per call; callers alternate members for diversity)."""

        def step_fn(carry, key_t):
            obs = carry
            k_act, k_member = jax.random.split(key_t)
            action, logp, value, _ = self.policy.act(policy_params, obs, k_act)
            d_obs, rew = self._dyn_forward(dyn_params, obs, action)
            next_obs = obs + d_obs
            out = {
                "obs": obs,
                "actions": action,
                "rewards": rew,
                "dones": jnp.zeros_like(rew),
                "logp": logp,
                "values": value,
                "next_obs": next_obs,
            }
            return next_obs, out

        keys = jax.random.split(key, self.synth_rollout_len)
        last_obs, cols = jax.lax.scan(step_fn, start_obs, keys)
        _, _, last_value, _ = self.policy.act(policy_params, last_obs, keys[-1])
        adv, ret = gae(
            cols["rewards"], cols["values"], cols["dones"], last_value, self.gamma, self.lam
        )
        cols["advantages"] = adv
        cols["returns"] = ret
        return cols

    def synthesize(self, batch: SampleBatch) -> SampleBatch:
        """Generate a synthetic on-policy batch branching from replayed
        states (MBPO-style)."""
        idx = np.random.default_rng(len(self.dyn_losses)).integers(
            0, batch.count, min(self.synth_batch, batch.count)
        )
        start = jnp.asarray(batch["obs"][idx])
        self._key, k = jax.random.split(self._key)
        member = int(np.random.default_rng(int(k[0]) % 2**31).integers(self.ensemble_size))
        cols = self._synth_jit(self.params, self.dyn_params[member], start, k)
        return _to_numpy_batch(cols)
