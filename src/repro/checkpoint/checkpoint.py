"""Pytree checkpointing to .npz (no orbax offline).

Keys are '/'-joined tree paths; arrays are gathered to host before save and
restored with the original structure.  Sharding-aware: restoring under a mesh
is done by the caller placing arrays with ``jax.device_put(x, sharding)``.

Durability model follows the paper (§3): checkpoints are the only durable
state; all dataflow operator state is discardable and rebuilt on restart.
"""

from __future__ import annotations

import os
from typing import Any, Dict

import jax
import numpy as np

PyTree = Any

__all__ = ["save_pytree", "restore_pytree"]


def _flatten_with_paths(tree: PyTree) -> Dict[str, np.ndarray]:
    flat: Dict[str, np.ndarray] = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        # npz cannot serialize ml_dtypes (bfloat16, fp8): widen to float32;
        # restore_pytree casts back to the template dtype.
        if arr.dtype.name not in np.sctypeDict and arr.dtype.kind in ("V", "f"):
            arr = arr.astype(np.float32)
        elif arr.dtype.name == "bfloat16":
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _path_str(p: Any) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_pytree(path: str, tree: PyTree) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten_with_paths(tree)
    np.savez(path, **flat)


def restore_pytree(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    with np.load(path) as data:
        treedef = jax.tree_util.tree_structure(like)
        leaves = jax.tree_util.tree_flatten_with_path(like)[0]
        new_leaves = []
        for pth, leaf in leaves:
            key = "/".join(_path_str(p) for p in pth)
            arr = data[key]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            new_leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, new_leaves)
