import os

os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

For each combination this builds the appropriate step function
(train_step / prefill_step / decode_step), lowers it under the production
mesh with full sharding specs, compiles, and records:

  * memory_analysis (bytes per device — proves the program fits)
  * cost_analysis   (FLOPs / bytes — §Roofline numerators)
  * collective bytes parsed from the partitioned HLO
  * the derived roofline terms (single-pod mesh only, per spec)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import ARCHITECTURES, INPUT_SHAPES, get_config
from repro.distributed.hlo_analysis import roofline
from repro.distributed.hlo_cost import analyze_hlo
from repro.distributed.sharding import DEFAULT_RULES, AxisRules, axis_rules_context
from repro.distributed.specs import (
    batch_specs,
    cache_specs,
    opt_state_specs,
    param_specs,
    tree_shardings,
)
from repro.launch.input_specs import (
    abstract_cache,
    abstract_params,
    decode_window_for,
    input_specs,
)
from repro.launch.mesh import make_production_mesh
from repro.models import Model, make_decode_step, make_prefill_step, make_train_step
from repro.optim import adamw, linear_warmup_cosine


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train, 2·N·D prefill, 2·N_active·B decode."""
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch  # one decode step


def run_one(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    overrides: Optional[Dict[str, Any]] = None,
    tag: str = "",
) -> Dict[str, Any]:
    cfg = get_config(arch)
    if overrides:
        plain = {k: v for k, v in overrides.items() if "." not in k}
        nested = {k: v for k, v in overrides.items() if "." in k}
        if plain:
            cfg = dataclasses.replace(cfg, **plain)
        for k, v in nested.items():
            field, sub = k.split(".", 1)
            inner = getattr(cfg, field)
            if inner is not None:
                cfg = dataclasses.replace(
                    cfg, **{field: dataclasses.replace(inner, **{sub: v})}
                )
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.size
    rules = AxisRules(DEFAULT_RULES, mesh)
    model = Model(cfg)
    t0 = time.time()

    with mesh, axis_rules_context(rules):
        params_shape = abstract_params(model)
        pspecs = param_specs(params_shape, rules)
        p_shard = tree_shardings(mesh, pspecs)
        batch = input_specs(cfg, shape)
        b_shard = tree_shardings(mesh, batch_specs(batch, rules))

        if shape.kind == "train":
            opt = adamw(linear_warmup_cosine(3e-4, 200, 10_000), weight_decay=0.1)
            opt_shape = jax.eval_shape(opt.init, params_shape)
            ospecs = opt_state_specs(opt_shape, pspecs, rules)
            o_shard = tree_shardings(mesh, ospecs)
            step = make_train_step(model, opt)
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
            ).lower(params_shape, opt_shape, batch)
        elif shape.kind == "prefill":
            window = 0
            cache_shape = abstract_cache(model, shape.global_batch, shape.seq_len)
            c_shard = tree_shardings(mesh, cache_specs(cache_shape, rules))
            step = make_prefill_step(model, window=0)
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, b_shard),
                out_shardings=(None, c_shard),
            ).lower(params_shape, batch)
        else:  # decode
            window = decode_window_for(cfg, shape)
            cache_shape = abstract_cache(model, shape.global_batch, window)
            c_shard = tree_shardings(mesh, cache_specs(cache_shape, rules))
            step = make_decode_step(model)
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, b_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),  # in-place ring-buffer update
            ).lower(params_shape, cache_shape, batch)

        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        # jax <= 0.4.x returns a one-element list of dicts; newer returns a dict.
        raw_cost = compiled.cost_analysis() or {}
        if isinstance(raw_cost, (list, tuple)):
            raw_cost = raw_cost[0] if raw_cost else {}
        # Trip-count-aware per-device analysis (raw cost_analysis counts
        # while bodies once; our models are scans over blocks).
        walker = analyze_hlo(compiled.as_text())

    bytes_per_dev = None
    try:
        bytes_per_dev = (
            mem.argument_size_in_bytes + mem.output_size_in_bytes + mem.temp_size_in_bytes
        ) / 1.0
    except Exception:
        pass

    # Walker numbers are per-device (SPMD module); globalize for the table.
    cost = {
        "flops": walker.flops * chips,
        "bytes accessed": walker.hbm_bytes * chips,
    }
    coll = {"total": walker.coll_bytes * chips}
    coll.update({k: v * chips for k, v in walker.coll_by_kind.items()})
    rl = roofline(
        arch,
        shape_name,
        mesh_name,
        chips,
        cost,
        coll,
        model_flops(cfg, shape),
        bytes_per_device=bytes_per_dev,
    )
    row = rl.row()
    row.update(
        {
            "tag": tag,
            "ok": True,
            "compile_s": round(time.time() - t0, 1),
            "memory_analysis": str(mem),
            "collectives": {k: v for k, v in coll.items()},
            "raw_cost_flops": float(raw_cost.get("flops", 0.0)),
            "unknown_trip_counts": walker.unknown_trip_counts,
        }
    )
    print(
        f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
        f"compile={row['compile_s']}s flops={row['hlo_flops']:.3e} "
        f"coll={row['coll_bytes']:.3e}B dominant={row['dominant']}"
    )
    print(f"  memory_analysis: {mem}")
    print(f"  cost_analysis: flops={cost.get('flops')} bytes={cost.get('bytes accessed')}")
    return row


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results/dryrun.jsonl")
    ap.add_argument("--tag", default="")
    ap.add_argument(
        "--override",
        action="append",
        default=[],
        help="cfg overrides, e.g. --override shard_residuals=False",
    )
    args = ap.parse_args()
    overrides: Dict[str, Any] = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        overrides[k] = json.loads(v.lower()) if v.lower() in ("true", "false") else (
            int(v) if v.lstrip("-").isdigit() else v
        )

    archs = list(ARCHITECTURES) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    failures = 0
    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    try:
                        row = run_one(arch, shape, mp, overrides=overrides, tag=args.tag)
                    except Exception as e:
                        failures += 1
                        row = {
                            "arch": arch,
                            "shape": shape,
                            "mesh": "2x16x16" if mp else "16x16",
                            "ok": False,
                            "error": f"{type(e).__name__}: {e}",
                        }
                        print(f"[dryrun] {arch} x {shape}: FAIL {e}", file=sys.stderr)
                        traceback.print_exc()
                    f.write(json.dumps(row) + "\n")
                    f.flush()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
