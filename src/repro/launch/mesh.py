"""Production meshes for the multi-pod dry-run (TPU v5e).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def _mk(shape, axes):
    # jax.sharding.AxisType landed in jax 0.5.x; older releases neither have
    # the enum nor accept an ``axis_types`` kwarg to ``jax.make_mesh``.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh():
    """Single-device mesh (CPU smoke tests)."""
    return _mk((1, 1), ("data", "model"))
