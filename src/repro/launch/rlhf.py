"""RLHF-style driver: PPO on a language model through the flow runtime.

The workload the serving + learner tiers were built for, end to end:

    TokenEnv (prompts as resets, one action = one token)
      -> VectorizedRolloutWorker(decode='cache')   KV-cache generation
      -> build_ppo_lm FlowSpec                     same graph as build_ppo
      -> Algorithm.train()                         fine-tunes the LM policy

Rollouts generate through the per-lane KV cache (prefill once per episode,
then one ``ops.decode_attention`` step per token); the learner path runs the
full flash-attention forward/backward.  The two paths are parity-gated
(``--parity`` prints the max logits gap).  The stub reward is programmatic
(fraction of generated tokens equal to a target token), so PPO has a clean
rising signal without a learned reward model.

Usage:
  PYTHONPATH=src python -m repro.launch.rlhf --iters 5
  PYTHONPATH=src python -m repro.launch.rlhf --decode forward   # no-cache A/B
  PYTHONPATH=src python -m repro.launch.rlhf --dot              # graph only
"""

from __future__ import annotations

import argparse
import time


def make_rlhf_worker(
    worker_index: int,
    num_envs: int = 8,
    rollout_len: int = 16,
    vocab_size: int = 17,
    ctx: int = 32,
    horizon: int = 16,
    d_model: int = 32,
    n_layers: int = 2,
    decode: str = "cache",
    seed: int = 0,
    lr: float = 3e-3,
):
    """One vectorized LM rollout worker over TokenEnv (shared with tests)."""
    from repro.optim import adam
    from repro.rl import LMTokenPolicy, TokenEnv, VectorizedRolloutWorker

    env = TokenEnv(vocab_size=vocab_size, ctx=ctx, horizon=horizon)
    policy = LMTokenPolicy(
        ctx=ctx, vocab_size=vocab_size, d_model=d_model, n_layers=n_layers
    )
    return VectorizedRolloutWorker(
        env, policy, algo="ppo", num_envs=num_envs, rollout_len=rollout_len,
        seed=seed, worker_index=worker_index, decode=decode,
        optimizer=adam(lr),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--num-envs", type=int, default=8)
    ap.add_argument("--rollout-len", type=int, default=16)
    ap.add_argument("--vocab", type=int, default=17)
    ap.add_argument("--ctx", type=int, default=32)
    ap.add_argument("--horizon", type=int, default=16)
    ap.add_argument("--d-model", type=int, default=32)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--train-batch", type=int, default=256)
    ap.add_argument("--sgd-iters", type=int, default=4)
    ap.add_argument("--minibatch", type=int, default=64)
    ap.add_argument("--num-learners", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--decode", default="cache", choices=("cache", "forward"),
        help="rollout path: per-lane KV cache vs full re-forward",
    )
    ap.add_argument(
        "--parity", action="store_true",
        help="print the decode-vs-forward max logits gap each iteration",
    )
    ap.add_argument("--dot", action="store_true", help="print the flow graph and exit")
    args = ap.parse_args()

    from repro import flow
    from repro.core.workers import WorkerSet

    def factory(i: int):
        return make_rlhf_worker(
            i, num_envs=args.num_envs, rollout_len=args.rollout_len,
            vocab_size=args.vocab, ctx=args.ctx, horizon=args.horizon,
            d_model=args.d_model, n_layers=args.layers, decode=args.decode,
            seed=args.seed, lr=args.lr,
        )

    ws = WorkerSet.create(factory, args.workers)
    algo = flow.Algorithm.from_plan(
        "ppo_lm", ws,
        train_batch_size=args.train_batch, num_sgd_iter=args.sgd_iters,
        sgd_minibatch_size=args.minibatch, num_learners=args.num_learners,
        decode=args.decode,
    )
    if args.dot:
        print(algo.to_dot())
        algo.stop()
        ws.stop()
        return

    t0 = time.time()
    tokens_per_iter = args.workers * args.num_envs * args.rollout_len
    try:
        for it in range(args.iters):
            res = algo.train()
            ep = res["episodes"]
            line = (
                f"iter {it:3d} reward {ep['episode_reward_mean']:.3f} "
                f"episodes {ep['episodes']:4d} "
                f"trained {res['counters'].get('num_steps_trained', 0):6d} "
                f"({tokens_per_iter / ((time.time() - t0) / (it + 1)):.0f} tok/s)"
            )
            if args.parity:
                import jax
                import numpy as np

                lw = ws.local_worker()
                policy = lw.policy
                obs = np.asarray(lw.vstate.obs)
                # Prefill a cache holding tokens 0..L-2 (drop the newest
                # token, force t=0) so decode_parity_gap measures one true
                # decode_step against the no-cache forward.
                prev = obs.copy()
                prev[:, policy.ctx] -= 1
                prev[:, policy.ctx + 1] = 0
                state = policy.init_lane_state(obs.shape[0])
                _, _, _, state = policy.compute_actions_stateful(
                    lw.params, prev,
                    jax.random.split(jax.random.PRNGKey(0), obs.shape[0]),
                    state,
                )
                gap = float(policy.decode_parity_gap(lw.params, obs, state))
                line += f" parity_gap {gap:.2e}"
            print(line, flush=True)
    finally:
        algo.stop()
        ws.stop()


if __name__ == "__main__":
    main()
