"""LM training driver: the paper's dataflow model driving pjit SPMD steps.

The training loop IS a dataflow graph (ppo-shaped, minus the RL loss),
declared as a ``FlowSpec`` and run through the ``Algorithm`` facade:

    data actors -> par_source -> batch_across_shards -> merge
                -> SPMD train step (pjit-fused synchronous fragment)
                -> report

Data pipeline shards are actors (one per host in production; N virtual
actors here); the learner's ``learn_on_batch`` is the pjit-fused synchronous
fragment (core/spmd.py).  On this CPU container use --smoke for a reduced
config; the same flags drive the full configs on a real pod.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke --steps 20
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def build_lm_flow(workers, pipes):
    """The LM pretrain dataflow as a declarative graph."""
    from repro.core.metrics import get_metrics
    from repro.flow import FlowSpec, pure

    spec = FlowSpec("lm_pretrain")

    def _merge(shards):
        return {
            k: np.concatenate([s[k] for s in shards], axis=0) for k in shards[0]
        }

    @pure
    def _train(batch):  # dict batches (no .count/.minibatches)
        info = workers.local_worker().learn_on_batch(batch)
        get_metrics().counters["num_steps_trained"] += batch["tokens"].shape[0]
        return batch, info

    data_op = (
        spec.par_source(pipes, lambda p: p.sample(), name="TokenPipeline")
        .batch_across_shards()
        .for_each(pure(_merge), label="MergeShards")
    )
    spec.set_output(data_op.for_each(_train, label="SPMDTrainStep").report())
    return spec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-shards", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--dot", action="store_true", help="print the flow graph and exit")
    args = ap.parse_args()

    import jax

    from repro.checkpoint import save_pytree
    from repro.configs import get_config, reduced_config
    from repro.configs.base import InputShape
    from repro.core.actor import ActorPool
    from repro.core.spmd import SPMDLearnerWorker, SPMDTrainContext
    from repro.core.workers import WorkerSet
    from repro.data import TokenPipeline
    from repro.flow import Algorithm
    from repro.launch.mesh import make_local_mesh, make_production_mesh
    from repro.optim import adamw, chain_clip_by_global_norm, linear_warmup_cosine

    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    shape = InputShape("train", args.seq, args.batch, "train")
    mesh = make_local_mesh() if jax.device_count() == 1 else make_production_mesh()

    opt = chain_clip_by_global_norm(
        adamw(linear_warmup_cosine(args.lr, 20, max(args.steps, 100)), weight_decay=0.1),
        max_norm=1.0,
    )
    ctx = SPMDTrainContext(cfg, opt, mesh)
    learner = SPMDLearnerWorker(ctx)

    pipes = ActorPool.from_targets(
        [
            TokenPipeline(cfg, shape, seed=0, host_id=i, num_hosts=args.data_shards)
            for i in range(args.data_shards)
        ],
        name="data",
    )
    workers = WorkerSet(learner, pipes)
    spec = build_lm_flow(workers, pipes)
    if args.dot:
        print(spec.to_dot())
        return

    t0 = time.time()
    with Algorithm.from_plan(spec, workers) as algo:
        for step in range(args.steps):
            res = algo.train()
            loss = res["info"].get("loss", float("nan"))
            if step % max(1, args.steps // 10) == 0 or step == args.steps - 1:
                print(
                    f"step {step:4d} loss {loss:.4f} "
                    f"({(time.time() - t0) / (step + 1):.2f}s/step)",
                    flush=True,
                )
        if args.checkpoint:
            save_pytree(args.checkpoint, learner.params)
            print(f"saved checkpoint to {args.checkpoint}")


if __name__ == "__main__":
    main()
