"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape)`` returns the batch pytree the corresponding step
function consumes; ``abstract_state(...)`` builds params / optimizer /cache
shape trees via ``jax.eval_shape``.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import Model

__all__ = ["input_specs", "decode_window_for", "abstract_params", "abstract_cache"]


def decode_window_for(cfg: ModelConfig, shape: InputShape) -> int:
    """KV window for decode shapes: full context at 32k; sliding window for
    the 500k long-context shape (DESIGN.md §4 long_500k policy)."""
    if shape.kind != "decode":
        return 0
    has_attn = any(
        s.kind == "attn" for s in tuple(cfg.prologue) + tuple(cfg.block_pattern)
    )
    if not has_attn:
        return 1  # attention-free: cache is recurrent state; window unused
    if shape.seq_len > 32_768:
        return cfg.decode_window
    return shape.seq_len


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "decode":
        tok = (b, 1, cfg.num_codebooks) if cfg.modality == "audio" else (b, 1)
        out["tokens"] = jax.ShapeDtypeStruct(tok, i32)
        return out
    if cfg.modality == "audio":
        out["tokens"] = jax.ShapeDtypeStruct((b, s, cfg.num_codebooks), i32)
    elif cfg.modality == "vlm":
        out["tokens"] = jax.ShapeDtypeStruct((b, s - cfg.num_media_tokens), i32)
        out["media_emb"] = jax.ShapeDtypeStruct(
            (b, cfg.num_media_tokens, cfg.d_model), jnp.float32
        )
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    if shape.kind == "train":
        lbl = out["tokens"].shape
        out["labels"] = jax.ShapeDtypeStruct(lbl, i32)
    return out


def abstract_params(model: Model) -> Any:
    return jax.eval_shape(model.init_params, jax.random.PRNGKey(0))


def abstract_cache(model: Model, batch: int, window: int) -> Any:
    return jax.eval_shape(lambda: model.init_cache(batch, window))
