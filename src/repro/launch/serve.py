"""Serving entrypoint: the production inference tier under open-loop load.

Builds the real serving stack — N supervised ``InferenceActor`` replicas
behind an ``InferenceRouter`` with a shared ``CreditGate`` — and drives it
with an **open-loop** synthetic load client: request arrival times are fixed
in advance at the configured rate, independent of completions, so a slow
server accumulates queueing delay instead of silently throttling the
workload (closed-loop clients hide tail latency; see the coordinated-
omission literature).  Latency is measured from the *scheduled* arrival to
completion, so queueing counts.

``benchmarks/bench_serve.py`` imports ``build_serving_tier`` /
``open_loop_load`` for the gated p50/p99 rows; this module's ``main`` is
the human-facing CLI:

  PYTHONPATH=src python -m repro.launch.serve --replicas 3 --policy ssm \
      --rate 200 --requests 400 --lanes 8
"""

from __future__ import annotations

import argparse
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.metrics import LatencyStat
from repro.rl.inference import (
    CreditGate,
    InferenceActor,
    InferenceRouter,
    InferenceUnavailable,
)

__all__ = ["build_serving_tier", "warm_replicas", "open_loop_load", "main"]


def _policy_factory(policy: str, obs_dim: int, num_actions: int):
    if policy == "stateless":
        from repro.rl.policy import DummyPolicy

        return lambda: DummyPolicy(obs_dim, num_actions)
    if policy == "ac":
        from repro.rl.policy import ActorCriticPolicy

        return lambda: ActorCriticPolicy(obs_dim, num_actions)
    if policy == "ssm":
        from repro.rl.stateful_policy import SSMStatePolicy

        return lambda: SSMStatePolicy(obs_dim, num_actions)
    raise ValueError(f"unknown policy {policy!r} (want 'stateless'|'ac'|'ssm')")


def build_serving_tier(
    policy: str = "stateless",
    replicas: int = 1,
    credits: Optional[int] = None,
    routing: str = "auto",
    failure_policy: str = "restart",
    max_batch: Optional[int] = None,
    seed: int = 0,
    obs_dim: int = 4,
    num_actions: int = 2,
    supervised: bool = True,
) -> Tuple[InferenceRouter, List[Any]]:
    """The serving stack the compile() lowering builds, standalone.

    Returns ``(router, actors)``: N replicas (``VirtualActor``-supervised
    when ``supervised``, bare in-process targets otherwise) behind one
    router with a shared credit gate.  All replicas are seeded identically,
    so a stateless tier is bit-interchangeable replica-to-replica.
    """
    factory = _policy_factory(policy, obs_dim, num_actions)

    def make_target():
        return InferenceActor(factory, seed=seed, max_batch=max_batch)

    if supervised:
        from repro.core.actor import VirtualActor

        actors: List[Any] = [
            VirtualActor(
                factory=make_target,
                name=f"serve-replica-{i}",
                max_restarts=1,
                backoff_base=0.0,
            )
            for i in range(replicas)
        ]
    else:
        actors = [make_target() for _ in range(replicas)]
    gate = CreditGate(credits if credits is not None else 2 * replicas)
    router = InferenceRouter(
        actors,
        credits=gate,
        sticky=None if routing == "auto" else routing == "sticky",
        failure_policy=failure_policy,
        name=f"serve-{policy}",
    )
    return router, actors


def warm_replicas(
    router: Any, lanes_n: int = 8, obs_dim: int = 4
) -> None:
    """Compile every replica's dispatch outside the measured window.

    The actor pads dispatch batches to the next power of two, so warming the
    power-of-two shapes up to ``lanes_n`` on *each* replica covers every
    batch size the router can produce (least-loaded ties would otherwise
    leave replicas 1..N-1 cold, paying XLA compile mid-load).  Warm lanes
    are negative — disjoint from any real lane — and their server-side
    state is reset afterwards, so routing and pinning state are untouched.
    """
    shapes = [1 << i for i in range(max(0, lanes_n - 1).bit_length() + 1)]
    for actor in getattr(router, "replicas", [router]):
        virtual = hasattr(actor, "call")
        for n in shapes:
            obs = np.zeros((n, obs_dim), np.float32)
            keys = np.zeros((n, 2), np.uint32)
            lanes = -1 - np.arange(n, dtype=np.int64)
            if virtual:
                ids = actor.sync("submit", obs, keys, lanes)
                while actor.sync("poll", ids) is None:
                    pass
                actor.sync("reset_lanes", lanes)
            else:
                ids = actor.submit(obs, keys, lanes)
                while actor.poll(ids) is None:
                    pass
                actor.reset_lanes(lanes)


def open_loop_load(
    router: Any,
    rate_hz: float = 200.0,
    num_requests: int = 200,
    lanes_per_request: int = 8,
    num_clients: int = 2,
    seed: int = 0,
    obs_dim: int = 4,
    on_failure: str = "recover",
) -> Dict[str, Any]:
    """Drive ``router`` with open-loop synthetic load; returns the summary.

    ``num_clients`` threads split a single arrival schedule (request k is
    *due* at ``k / rate_hz``); each client sleeps until its next request's
    due time and then issues it regardless of how many are still in flight
    — the open-loop discipline.  Per-request latency = completion time
    minus due time.  ``InferenceUnavailable`` is counted as a drop; with
    ``on_failure='recover'`` the client calls ``router.recover()`` and
    carries on (the soak/chaos path).
    """
    lat = LatencyStat(window=max(512, num_requests))
    lock = threading.Lock()
    counts = {"ok": 0, "dropped": 0}
    rng = np.random.RandomState(seed)
    obs_pool = rng.randn(64, lanes_per_request, obs_dim).astype(np.float32)
    keys_pool = rng.randint(0, 2**31, size=(64, lanes_per_request, 2)).astype(
        np.uint32
    )
    sticky = bool(getattr(router, "sticky", False))

    t_start = time.perf_counter()
    due = [t_start + k / rate_hz for k in range(num_requests)]

    def client(cid: int) -> None:
        # Client cid owns requests cid, cid+C, cid+2C... of the shared
        # schedule; its lanes are disjoint from other clients' lanes so
        # sticky routing sees a stable lane universe per client.
        lanes = np.arange(cid * lanes_per_request, (cid + 1) * lanes_per_request)
        for k in range(cid, num_requests, num_clients):
            now = time.perf_counter()
            if due[k] > now:
                time.sleep(due[k] - now)
            obs = obs_pool[k % len(obs_pool)]
            keys = keys_pool[k % len(keys_pool)]
            try:
                if sticky:
                    router.compute_actions(obs, keys, lanes)
                else:
                    router.compute_actions(obs, keys)
            except InferenceUnavailable:
                with lock:
                    counts["dropped"] += 1
                if on_failure == "recover" and hasattr(router, "recover"):
                    router.recover()
                continue
            done = time.perf_counter()
            with lock:
                counts["ok"] += 1
                lat.push(done - due[k])

    threads = [
        threading.Thread(target=client, args=(cid,), name=f"load-client-{cid}")
        for cid in range(num_clients)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t_start
    summary = lat.summary()
    return {
        "requests_ok": counts["ok"],
        "requests_dropped": counts["dropped"],
        "wall_s": wall,
        "rps": counts["ok"] / wall if wall else 0.0,
        "lane_steps_per_s": counts["ok"] * lanes_per_request / wall if wall else 0.0,
        "latency_mean_s": summary["mean"],
        "latency_p50_s": summary["p50"],
        "latency_p99_s": summary["p99"],
        "offered_rate_hz": rate_hz,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--policy", default="stateless",
                    choices=("stateless", "ac", "ssm"))
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--credits", type=int, default=None)
    ap.add_argument("--routing", default="auto",
                    choices=("auto", "least_loaded", "sticky"))
    ap.add_argument("--max-batch", type=int, default=None,
                    help="admission-queue occupancy bound (continuous batching)")
    ap.add_argument("--rate", type=float, default=200.0, help="offered req/s")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--lanes", type=int, default=8, help="env lanes per request")
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    router, _actors = build_serving_tier(
        policy=args.policy,
        replicas=args.replicas,
        credits=args.credits,
        routing=args.routing,
        max_batch=args.max_batch,
        seed=args.seed,
    )
    try:
        # Compile each replica's dispatch (every reachable batch shape —
        # continuous batching can merge all clients' lanes into one
        # dispatch) outside the measured window: serving never charges
        # XLA compile.
        warm_replicas(router, lanes_n=args.lanes * args.clients)
        result = open_loop_load(
            router,
            rate_hz=args.rate,
            num_requests=args.requests,
            lanes_per_request=args.lanes,
            num_clients=args.clients,
            seed=args.seed,
        )
        print(
            f"{args.policy} x{args.replicas} replicas "
            f"(routing={'sticky' if router.sticky else 'least_loaded'}): "
            f"{result['requests_ok']} ok / {result['requests_dropped']} dropped "
            f"in {result['wall_s']:.2f}s = {result['rps']:.1f} req/s "
            f"({result['lane_steps_per_s']:.0f} lane steps/s)"
        )
        print(
            f"action latency: p50 {result['latency_p50_s'] * 1e3:.2f}ms  "
            f"p99 {result['latency_p99_s'] * 1e3:.2f}ms  "
            f"mean {result['latency_mean_s'] * 1e3:.2f}ms"
        )
        stats = router.stats()
        for rep in stats["replicas"]:
            q = rep.get("stats", {}).get("queue", {})
            print(
                f"  {rep['name']}: {rep.get('stats', {}).get('num_requests', 0)} "
                f"requests, occupancy mean {q.get('occupancy_mean', 0.0):.1f} "
                f"peak {q.get('occupancy_peak', 0.0):.0f}, admission p99 "
                f"{q.get('admission_wait_p99_s', 0.0) * 1e3:.2f}ms"
            )
    finally:
        router.stop()


if __name__ == "__main__":
    main()
