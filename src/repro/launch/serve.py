"""Serving driver: batched prefill + decode as a dataflow.

Requests stream in from client actors; the flow batches them, runs one
prefill, then iterates ``decode_step`` (one token across the whole batch per
step — continuous-batching style).  Demonstrates the decode paths the
dry-run lowers at scale.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --prompt-len 32 --gen 16 --batch 4
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduced_config
    from repro.models import Model

    cfg = reduced_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)

    B, P = args.batch, args.prompt_len
    shape = (B, P, cfg.num_codebooks) if cfg.modality == "audio" else (B, P)
    prompts = jax.random.randint(key, shape, 0, cfg.vocab_size)

    window = P + args.gen
    prefill = jax.jit(lambda p, t: model.prefill(p, t, window=window))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    print(f"prefill {B}x{P}: {time.time() - t0:.2f}s")

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if cfg.modality == "audio":
        tok = tok.reshape(B, 1, cfg.num_codebooks)
    else:
        tok = tok.reshape(B, 1)
    generated = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        tok = tok.reshape(B, 1, cfg.num_codebooks) if cfg.modality == "audio" else tok.reshape(B, 1)
        generated.append(np.asarray(tok))
    dt = time.time() - t0
    total = B * (args.gen - 1)
    print(f"decode: {total} tokens in {dt:.2f}s = {total / dt:.1f} tok/s")
    out = np.concatenate(generated, axis=1)
    print("sample token ids:", out[0].reshape(-1)[:16].tolist())


if __name__ == "__main__":
    main()
