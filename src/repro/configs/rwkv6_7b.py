"""rwkv6-7b [ssm] — RWKV-6 "Finch": attention-free, data-dependent decay.

[arXiv:2404.05892] Eagle and Finch: RWKV with Matrix-Valued States and
Dynamic Recurrence.
Assignment: 32L d_model=4096 (attn-free) d_ff=14336 vocab=65536.
"""

from repro.configs.base import LayerSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    arch_type="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=(LayerSpec(kind="rwkv6", mlp="dense"),),
    ssm=SSMConfig(kind="rwkv6", head_dim=64, chunk=64),
    source="arXiv:2404.05892",
)
