"""qwen1.5-4b [dense] — GQA kv=20 (MHA-equal), QKV bias.

[hf:Qwen/Qwen1.5-0.5B family] Qwen1.5 technical configuration, 4B scale.
Assignment: 40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    arch_type="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    block_pattern=(LayerSpec(kind="attn", mlp="dense"),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B",
)
