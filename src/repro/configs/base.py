"""Model / run configuration dataclasses.

A ``ModelConfig`` fully determines parameter shapes and the layer stack.  The
stack is expressed as a repeated ``block_pattern`` of ``LayerSpec`` entries so
heterogeneous architectures (Jamba's 1:7 mamba:attention interleave with MoE
on alternate layers) compile as a ``lax.scan`` over blocks with the pattern
unrolled inside — keeping HLO size independent of depth.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = [
    "MLAConfig",
    "MoEConfig",
    "SSMConfig",
    "LayerSpec",
    "ModelConfig",
    "InputShape",
    "INPUT_SHAPES",
]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 16
    top_k: int = 2
    num_shared: int = 0          # shared (always-on) experts, DeepSeek-style
    d_ff: int = 1408             # per-expert hidden width
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # 'gather': build dispatch/combine with take_along_axis (contiguous
    # slots after the per-row sort) — no forward scatter, so XLA cannot
    # lower it as partial-scatter + all-reduce (§Perf iteration B1).
    # 'scatter': original .at[].add dispatch (baseline).
    dispatch: str = "gather"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"          # 'mamba' | 'rwkv6'
    d_state: int = 16            # mamba state dim
    d_conv: int = 4              # mamba conv width
    expand: int = 2              # mamba inner expansion
    head_dim: int = 64           # rwkv6 head size
    chunk: int = 64              # rwkv6 chunked-scan chunk length


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"           # 'attn' | 'mamba' | 'rwkv6'
    mlp: str = "dense"           # 'dense' | 'moe' | 'none'


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str               # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0            # 0 -> d_model // num_heads
    block_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    prologue: Tuple[LayerSpec, ...] = ()   # unscanned leading layers
    activation: str = "silu"     # silu | gelu | relu2 (squared ReLU)
    qkv_bias: bool = False
    qk_norm: bool = False
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    sliding_window: int = 0      # 0 = full attention (train/prefill)
    decode_window: int = 8192    # sliding-window used for long_500k decode
    # KV-cache storage dtype for decode: '' = model dtype; 'int8' halves
    # cache HBM (per-(position, head) scales; §Perf iteration A1).
    kv_cache_dtype: str = ""
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # Multimodal frontend stubs (DESIGN.md §4).
    modality: str = "text"       # text | vlm | audio
    num_media_tokens: int = 0    # prepended patch/frame embeddings (vlm)
    num_codebooks: int = 1       # EnCodec codebooks (audio)

    tie_embeddings: bool = False
    # Shard the between-block residual activations (the scan-carry remat
    # residuals) over the 'model' axis: cuts per-device activation memory by
    # the model-axis size at the cost of a gather per block (§Perf).
    shard_residuals: bool = True
    source: str = ""             # citation for the config

    def __post_init__(self) -> None:
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        total = len(self.prologue) + len(self.block_pattern) * self.num_blocks
        if total != self.num_layers:
            raise ValueError(
                f"{self.name}: prologue({len(self.prologue)}) + "
                f"pattern({len(self.block_pattern)}) x blocks({self.num_blocks}) "
                f"= {total} != num_layers({self.num_layers})"
            )

    @property
    def num_blocks(self) -> int:
        rem = self.num_layers - len(self.prologue)
        return rem // len(self.block_pattern)

    # ------------------------------------------------------ bookkeeping
    def param_count(self) -> int:
        """Total parameters N (analytic; used for MODEL_FLOPS = 6*N*D)."""
        return self._count(active_only=False)

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k experts only)."""
        return self._count(active_only=True)

    def _count(self, active_only: bool) -> int:
        d, hd = self.d_model, self.head_dim
        n = self.vocab_size * d * self.num_codebooks  # embeddings
        if not self.tie_embeddings:
            n += d * self.vocab_size * self.num_codebooks  # lm head(s)

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                q_dim = m.nope_head_dim + m.rope_head_dim
                p = d * self.num_heads * q_dim                 # W_q
                p += d * (m.kv_lora_rank + m.rope_head_dim)    # W_dkv + W_kr
                p += m.kv_lora_rank * self.num_heads * (m.nope_head_dim + m.v_head_dim)
                p += self.num_heads * m.v_head_dim * d         # W_o
                return p
            p = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
            p += self.num_heads * hd * d
            if self.qkv_bias:
                p += (self.num_heads + 2 * self.num_kv_heads) * hd
            return p

        # Gated (SwiGLU-style) MLPs use 3 matrices; relu2/gelu FFNs use 2.
        mlp_mats = 3 if self.activation == "silu" else 2

        def dense_mlp() -> int:
            return mlp_mats * d * self.d_ff

        def moe_mlp() -> int:
            assert self.moe is not None
            e = self.moe
            per_expert = mlp_mats * d * e.d_ff
            n_experts = (e.num_shared + e.top_k) if active_only else (e.num_shared + e.num_experts)
            return n_experts * per_expert + d * e.num_experts  # + router

        def ssm_params() -> int:
            assert self.ssm is not None
            s = self.ssm
            if s.kind == "rwkv6":
                # r,k,v,g,o projections + decay/mix params (head_dim heads)
                return 5 * d * d + 2 * d * 64 + 6 * d
            d_in = s.expand * d
            p = d * 2 * d_in                  # in_proj (x and z)
            p += d_in * s.d_conv              # conv1d
            p += d_in * (s.d_state * 2 + 1)   # B, C, dt projections (fused)
            p += d_in * s.d_state             # A_log
            p += d_in                          # D
            p += d_in * d                      # out_proj
            return p

        specs = list(self.prologue) + list(self.block_pattern) * self.num_blocks
        for spec in specs:
            if spec.kind == "attn":
                n += attn_params()
            else:
                n += ssm_params()
            if spec.mlp == "dense":
                n += dense_mlp()
            elif spec.mlp == "moe":
                n += moe_mlp()
            n += 2 * d  # norms
        return n


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
