"""musicgen-large [audio] — decoder-only over EnCodec tokens; codec is a STUB.

[arXiv:2306.05284] Simple and Controllable Music Generation.
Assignment: 48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048.

Per DESIGN.md §4 the EnCodec frontend is not implemented: the decoder
consumes 4 parallel codebook token streams (delay pattern); embeddings are
summed across codebooks and the LM head predicts all 4 codebooks per step.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    block_pattern=(LayerSpec(kind="attn", mlp="dense"),),
    modality="audio",
    num_codebooks=4,
    activation="gelu",
    source="arXiv:2306.05284",
)
