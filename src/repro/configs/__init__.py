"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``.

Ten assigned architectures (DESIGN.md §4) plus reduced variants for CPU
smoke tests and the paper's own RL configs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import (
    INPUT_SHAPES,
    InputShape,
    LayerSpec,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)

from repro.configs.deepseek_v2_lite_16b import CONFIG as _deepseek
from repro.configs.jamba_v01_52b import CONFIG as _jamba
from repro.configs.llava_next_34b import CONFIG as _llava
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.nemotron_4_15b import CONFIG as _nemotron
from repro.configs.phi35_moe_42b import CONFIG as _phi
from repro.configs.qwen15_32b import CONFIG as _qwen32
from repro.configs.qwen15_4b import CONFIG as _qwen4
from repro.configs.qwen3_14b import CONFIG as _qwen3
from repro.configs.rwkv6_7b import CONFIG as _rwkv

ARCHITECTURES: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _deepseek,
        _jamba,
        _rwkv,
        _qwen4,
        _llava,
        _qwen32,
        _musicgen,
        _nemotron,
        _phi,
        _qwen3,
    ]
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHITECTURES:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(ARCHITECTURES)}"
        )
    return ARCHITECTURES[arch_id]


def reduced_config(arch_id: str, num_layers: int = 2, d_model: int = 256) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (<=4 experts,
    2 layers, d_model<=512)."""
    cfg = get_config(arch_id)
    head_dim = 64
    num_heads = max(d_model // head_dim, 1)
    num_kv = num_heads if cfg.num_kv_heads == cfg.num_heads else max(num_heads // 2, 1)
    if cfg.num_heads == 0:  # attention-free
        num_heads = num_kv = 0
    pattern = cfg.block_pattern[: min(len(cfg.block_pattern), num_layers)]
    blocks = num_layers // len(pattern)
    replace = dict(
        num_layers=len(pattern) * blocks,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim if num_heads else 0,
        d_ff=d_model * 3,
        vocab_size=512,
        prologue=(),
        block_pattern=pattern,
        num_media_tokens=min(cfg.num_media_tokens, 16),
        decode_window=64,
    )
    if cfg.moe is not None:
        replace["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, num_shared=min(cfg.moe.num_shared, 1),
            d_ff=d_model * 2,
        )
    if cfg.mla is not None:
        replace["mla"] = MLAConfig(
            kv_lora_rank=64, rope_head_dim=32, nope_head_dim=head_dim, v_head_dim=head_dim
        )
    if cfg.ssm is not None:
        replace["ssm"] = dataclasses.replace(cfg.ssm, head_dim=32, chunk=16)
    return dataclasses.replace(cfg, name=f"{cfg.name}-smoke", **replace)


__all__ = [
    "ARCHITECTURES",
    "INPUT_SHAPES",
    "InputShape",
    "LayerSpec",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "get_config",
    "reduced_config",
]
