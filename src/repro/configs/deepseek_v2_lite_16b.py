"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, shared+routed MoE top-6.

[arXiv:2405.04434] DeepSeek-V2: A Strong, Economical, and Efficient
Mixture-of-Experts Language Model (Lite variant).
Assignment: 27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
MoE 64e top-6, MLA kv_lora=512, 2 shared experts.
First layer uses a dense MLP (DeepSeek-V2 convention).
"""

from repro.configs.base import LayerSpec, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,  # dense (first-layer) MLP width, DeepSeek-V2-Lite
    vocab_size=102400,
    prologue=(LayerSpec(kind="attn", mlp="dense"),),
    block_pattern=(LayerSpec(kind="attn", mlp="moe"),),
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_ff=1408),
    rope_theta=10000.0,
    source="arXiv:2405.04434",
)
