"""qwen3-14b [dense] — qk-norm, GQA kv=8.

[hf:Qwen/Qwen3-8B family] Qwen3 technical configuration, 14B scale.
Assignment: 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    block_pattern=(LayerSpec(kind="attn", mlp="dense"),),
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)
