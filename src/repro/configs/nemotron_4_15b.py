"""nemotron-4-15b [dense] — GQA kv=8, squared-ReLU MLP, 256k vocab.

[arXiv:2402.16819] Nemotron-4 15B Technical Report.
Assignment: 32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    arch_type="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    block_pattern=(LayerSpec(kind="attn", mlp="dense"),),
    activation="relu2",
    source="arXiv:2402.16819",
)
