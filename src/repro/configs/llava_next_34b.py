"""llava-next-34b [vlm] — anyres tiling; vision frontend is a STUB.

[hf:llava-hf/llava-v1.6-mistral-7b-hf family] LLaVA-NeXT, 34B backbone.
Assignment: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

Per DESIGN.md §4 the ViT/projector is not implemented: ``input_specs``
provides precomputed patch embeddings (anyres: base 576 tokens + 4 tiles
x 576 = 2880 media tokens) prepended to the text tokens.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    arch_type="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    block_pattern=(LayerSpec(kind="attn", mlp="dense"),),
    modality="vlm",
    num_media_tokens=2880,  # anyres: (1 base + 4 tiles) x 24x24 patches
    rope_theta=5_000_000.0,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
