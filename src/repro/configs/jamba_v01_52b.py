"""jamba-v0.1-52b [hybrid] — Mamba:attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887] Jamba: A Hybrid Transformer-Mamba Language Model.
Assignment: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16e top-2.  Jamba block = 8 layers, attention at index 3 (1 attn : 7
mamba), MoE replacing the MLP on every other layer (odd indices).
"""

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig, SSMConfig

_BLOCK = tuple(
    LayerSpec(
        kind="attn" if i == 3 else "mamba",
        mlp="moe" if i % 2 == 1 else "dense",
    )
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=_BLOCK,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=14336),
    ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2),
    source="arXiv:2403.19887",
)
