"""qwen1.5-32b [dense] — GQA kv=40 (MHA-equal), QKV bias.

[hf:Qwen/Qwen1.5-0.5B family] Qwen1.5 technical configuration, 32B scale.
Assignment: 64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064.
"""

from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    arch_type="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    block_pattern=(LayerSpec(kind="attn", mlp="dense"),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B",
)
