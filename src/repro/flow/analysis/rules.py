"""The built-in rule set, grounded in failure modes from PRs 2-5.

Every rule checks a *graph property* — something knowable before a single
actor spawns, the way MSRL validates fragment partitions statically.  The
catalog, severity policy, and example output per rule live in
``docs/flowcheck.md``; each rule here cites the concrete runtime failure it
front-runs.
"""

from __future__ import annotations

import os
import pickle
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.core.executor import FailurePolicy
from repro.core.transport import OverflowPolicy
from repro.flow.analysis.diagnostics import Diagnostic, Severity
from repro.flow.analysis.engine import (
    CREDIT_KINDS,
    SOURCE_KINDS,
    GraphView,
    rule,
)

__all__: List[str] = []  # rules register via the decorator, not by import

# Annotation keys lowered onto TrainOneStep-like stages / source nodes.
_LEARNER_KEYS = ("num_learners", "microbatch")
_VECTOR_KEYS = (
    "vector",
    "inference",
    "inference_credits",
    "inference_replicas",
    "inference_routing",
    "decode",
)


# --------------------------------------------------------------------------
# graph-structure: FlowSpec.validate() as diagnostics + dead-subflow checks
# --------------------------------------------------------------------------
@rule("graph-structure", "output set, single consumption, resource wiring")
def _graph_structure(view: GraphView) -> Iterator[Diagnostic]:
    spec = view.spec
    if spec.output is None:
        yield Diagnostic(
            "graph-structure", Severity.ERROR,
            "no output set: nothing will ever be pulled from this flow",
            hint="call spec.set_output(stream) on the result stream",
        )
    consumed: Dict[Tuple[str, int], int] = {}
    for node in spec.nodes.values():
        for ref in node.inputs:
            consumed[ref] = consumed.get(ref, 0) + 1
    if spec.output is not None:
        consumed[spec.output] = consumed.get(spec.output, 0) + 1
    for ref, n in sorted(consumed.items()):
        if n > 1:
            yield Diagnostic(
                "graph-structure", Severity.ERROR,
                f"edge {ref} is consumed {n} times; each stream edge feeds "
                "exactly one consumer",
                node=ref[0], edge=ref,
                hint="split the stream explicitly with duplicate(n)",
            )
    for name in spec._referenced_resources():
        if name not in spec.resources:
            yield Diagnostic(
                "graph-structure", Severity.ERROR,
                f"enqueue/dequeue references undeclared resource {name!r}",
                hint="declare it first (spec.learner_thread(workers, name=...))",
            )
    for name in spec.resources:
        if name not in view.enqueues and name not in view.dequeues:
            yield Diagnostic(
                "graph-structure", Severity.WARN,
                f"resource {name!r} is declared but no enqueue/dequeue node "
                "references it; it will be started and never fed",
                hint="wire it (stream.enqueue(ref) / spec.dequeue(ref)) or "
                "drop the declaration",
            )
    # Dead sub-flows: an output port nobody consumes is work that never runs
    # (or, for duplicate ports, a buffer that grows while its siblings are
    # pulled).
    for node in spec.nodes.values():
        for port in range(node.num_outputs):
            ref = (node.id, port)
            if consumed.get(ref):
                continue
            if spec.output is not None and spec.output == ref:
                continue
            yield Diagnostic(
                "graph-structure", Severity.WARN,
                f"output port {port} of {node.label!r} is never consumed: "
                "this sub-flow is dead (its operators never execute)",
                node=node.id, edge=ref,
                hint="merge the branch into the flow (concurrently/enqueue) "
                "or remove it",
            )


# --------------------------------------------------------------------------
# credit-deadlock: bounded windows that can wedge the pull cycle (PR 3)
# --------------------------------------------------------------------------
@rule("credit-deadlock", "credit/queue cycles whose demand exceeds supply")
def _credit_deadlock(view: GraphView) -> Iterator[Diagnostic]:
    spec = view.spec
    for name, res in spec.resources.items():
        if res.kind != "learner_thread":
            continue
        out_policy = res.params.get("out_policy", OverflowPolicy.DROP_NEWEST)
        if out_policy != OverflowPolicy.BLOCK:
            continue
        in_size = res.params.get("in_queue_size", 16)
        out_size = res.params.get("out_queue_size", 64)
        demand = in_size + out_size + 2  # queues + item in learner + in feed
        blocking = [
            n for n in view.enqueues.get(name, ())
            if view.effective_enqueue_policy(n) == OverflowPolicy.BLOCK
        ]
        deqs = view.dequeues.get(name, ())
        if blocking and not deqs:
            for enq in blocking:
                yield Diagnostic(
                    "credit-deadlock", Severity.ERROR,
                    f"blocking enqueue into {name!r} whose out-queue policy "
                    "is 'block' but which no dequeue node drains: after "
                    f"~{demand} items the learner wedges on its out-queue, "
                    "the in-queue fills, and this enqueue (plus any credits "
                    "held upstream) blocks forever",
                    node=enq.id,
                    hint=f"add spec.dequeue({name!r}) to a consuming branch, "
                    "or declare the learner with out_policy='drop_newest'",
                )
            continue
        # Both sides exist: the cycle deadlocks when a single round-robin
        # driver owns both branches — it blocks pulling the enqueue branch
        # and never reaches the dequeue branch that would free the cycle.
        for enq in blocking:
            union = view.union_of(enq.id)
            if union is None or union.params.get("mode") != "round_robin":
                continue
            for deq in deqs:
                deq_union = view.union_of(deq.id)
                if deq_union is not None and deq_union.id == union.id:
                    yield Diagnostic(
                        "credit-deadlock", Severity.ERROR,
                        f"blocking enqueue and dequeue of {name!r} (out-queue "
                        "policy 'block') are merged by a round_robin union: "
                        "one driver thread pulls both branches in turn, so "
                        f"once ~{demand} items are in flight it blocks on "
                        "the full in-queue and never pulls the dequeue "
                        "branch that would drain the cycle",
                        node=union.id,
                        hint="use concurrently(mode='async') so each branch "
                        "gets its own driver, or relax one queue policy",
                    )
                    break
    # Credit starvation: a window smaller than the shard set leaves shards
    # idle every round (FIFO backfill keeps liveness, but parallelism and
    # throughput silently shrink).
    for node in spec.nodes.values():
        if node.kind not in CREDIT_KINDS:
            continue
        credits = view.effective_credits(node)
        if credits is None or not isinstance(credits, int):
            continue
        src = node if node.kind in SOURCE_KINDS else view.source_of(node.id)
        shards = view.shard_count(src) if src is not None else None
        if shards and credits < shards:
            yield Diagnostic(
                "credit-deadlock", Severity.WARN,
                f"credits={credits} is below the {shards}-shard pool: at "
                f"most {credits} shards can have work in flight, so "
                f"{shards - credits} shards sit starved every round",
                node=node.id,
                hint=f"raise credits to >= {shards} (or remove the bound "
                "for the num_async * shards default)",
            )


# --------------------------------------------------------------------------
# unbounded-queue: async windows with no credit bound feeding blocking queues
# --------------------------------------------------------------------------
@rule("unbounded-queue", "blocking queue feeds with an unbounded async window")
def _unbounded_queue(view: GraphView) -> Iterator[Diagnostic]:
    spec = view.spec
    for node in spec.nodes.values():
        if node.kind == "enqueue":
            if view.effective_enqueue_policy(node) != OverflowPolicy.BLOCK:
                continue
            window = _async_window(view, node)
            if window is None:
                continue
            win_node, bounded = window
            if bounded:
                continue
            yield Diagnostic(
                "unbounded-queue", Severity.WARN,
                f"blocking enqueue is fed by {win_node.label!r} with no "
                "credit bound: the in-flight window is num_async x shards "
                "and grows under elastic add_workers, so a stalled learner "
                "backs pressure into an ever-larger dispatched backlog",
                node=node.id,
                hint=f"set credits= on {win_node.label!r} (or an overflow "
                "policy on the enqueue) to make the window explicit",
            )
        elif node.kind == "duplicate":
            union = view.union_of(node.id)
            if union is not None and union.params.get("mode") == "async":
                yield Diagnostic(
                    "unbounded-queue", Severity.WARN,
                    f"{node.label!r} branches merge in an async union: "
                    "branches are pulled at independent rates, so the "
                    "slower branch's duplicate buffer grows without bound",
                    node=node.id,
                    hint="merge duplicate branches with a round_robin union "
                    "(rate-coupled pulls) or bound the fast branch",
                )


def _async_window(
    view: GraphView, enq: Any
) -> Optional[Tuple[Any, bool]]:
    """The async dispatch window feeding ``enq``: (node, has_credit_bound).

    Returns None when the feed is synchronous (bulk_sync rollouts,
    gather_sync rounds, from_items) — those are bounded by construction.
    """
    for up in view.upstream(enq.id):
        if up.kind == "gather_async":
            return up, view.effective_credits(up) is not None
        if up.kind == "rollouts" and up.params.get("mode") == "async":
            return up, view.effective_credits(up) is not None
        if up.kind == "replay":
            return up, view.effective_credits(up) is not None
    return None


# --------------------------------------------------------------------------
# annotation-lowering: annotations that can't lower (PR 4/5 fallbacks)
# --------------------------------------------------------------------------
@rule("annotation-lowering", "annotations that cannot lower on their node")
def _annotation_lowering(view: GraphView) -> Iterator[Diagnostic]:
    spec = view.spec
    policy_by_pool: Dict[int, Tuple[str, str]] = {}  # id(pool) -> (policy, node)
    for node in spec.nodes.values():
        ann = node.annotations
        yield from _check_learner_annotations(node, ann)
        yield from _check_vector_annotations(view, node, ann)
        # overflow_policy: only the enqueue lowering reads it.
        op = ann.get("overflow_policy")
        if op is not None:
            if node.kind != "enqueue":
                yield Diagnostic(
                    "annotation-lowering", Severity.ERROR,
                    f"overflow_policy={op!r} annotates a {node.kind!r} node; "
                    "only enqueue nodes lower it — the annotation is "
                    "silently ignored",
                    node=node.id,
                    hint="move the annotation onto the enqueue node",
                )
            elif op not in OverflowPolicy.ALL:
                yield Diagnostic(
                    "annotation-lowering", Severity.ERROR,
                    f"unknown overflow_policy {op!r} "
                    f"(want one of {sorted(OverflowPolicy.ALL)})",
                    node=node.id,
                    hint="pick 'block', 'drop_newest', or 'drop_oldest'",
                )
        # credits: only async gathers and async sources lower it.
        credits = ann.get("credits")
        if credits is not None:
            if node.kind not in CREDIT_KINDS:
                yield Diagnostic(
                    "annotation-lowering", Severity.ERROR,
                    f"credits={credits!r} annotates a {node.kind!r} node; "
                    "only gather_async/rollouts/replay lower credits — the "
                    "annotation is silently ignored",
                    node=node.id,
                    hint="move the bound onto the async gather or source",
                )
            elif not isinstance(credits, int) or credits < 1:
                yield Diagnostic(
                    "annotation-lowering", Severity.ERROR,
                    f"credits={credits!r} is not a positive int",
                    node=node.id, hint="credits must be >= 1 (or unset)",
                )
            elif node.kind == "rollouts" and node.params.get("mode") != "async":
                yield Diagnostic(
                    "annotation-lowering", Severity.ERROR,
                    f"credits={credits} on rollouts(mode="
                    f"{node.params.get('mode')!r}): only async rollouts "
                    "have an in-flight pipeline to bound",
                    node=node.id, hint="use mode='async' or drop the bound",
                )
        # failure_policy: applied to source actors only.
        fp = ann.get("failure_policy")
        if fp is not None:
            if fp not in FailurePolicy.ALL:
                yield Diagnostic(
                    "annotation-lowering", Severity.ERROR,
                    f"unknown failure_policy {fp!r} "
                    f"(want one of {sorted(FailurePolicy.ALL)})",
                    node=node.id,
                    hint="pick 'raise', 'restart', or 'drop_shard'",
                )
            elif node.kind not in SOURCE_KINDS:
                yield Diagnostic(
                    "annotation-lowering", Severity.ERROR,
                    f"failure_policy={fp!r} annotates a {node.kind!r} node; "
                    "policies lower onto source actors only — the "
                    "annotation is silently ignored",
                    node=node.id,
                    hint="annotate the source node (rollouts/replay/...)",
                )
            else:
                pool = view.node_pool(node)
                prior = policy_by_pool.get(id(pool))
                if prior is not None and prior[0] != fp:
                    yield Diagnostic(
                        "annotation-lowering", Severity.WARN,
                        f"failure_policy={fp!r} conflicts with "
                        f"{prior[0]!r} set by node {prior[1]} on the same "
                        "actor pool; the policy is per-actor and the last "
                        "lowered node wins for every stream sharing it",
                        node=node.id,
                        hint="annotate the pool's nodes consistently",
                    )
                policy_by_pool[id(pool)] = (fp, node.id)


def _check_learner_annotations(node: Any, ann: Dict[str, Any]) -> Iterator[Diagnostic]:
    if not any(k in ann for k in _LEARNER_KEYS):
        return
    carried = {k: ann[k] for k in _LEARNER_KEYS if k in ann}
    for key, val in carried.items():
        if not isinstance(val, int) or val < 1:
            yield Diagnostic(
                "annotation-lowering", Severity.ERROR,
                f"{key}={val!r} is not a positive int",
                node=node.id, hint=f"{key} must be >= 1",
            )
    if node.kind != "for_each":
        yield Diagnostic(
            "annotation-lowering", Severity.ERROR,
            f"{'/'.join(carried)} annotates a {node.kind!r} node; the "
            "learner group lowers only onto TrainOneStep-like for_each "
            "stages — the annotation is silently ignored",
            node=node.id,
            hint="chain .learners(n)/.microbatch(k) on the train stage",
        )
        return
    if node.parallel:
        yield Diagnostic(
            "annotation-lowering", Severity.ERROR,
            f"{'/'.join(carried)} annotates a *parallel* for_each; the "
            "learner group lowers only onto local train stages",
            node=node.id,
            hint="sequence the stream first "
            "(gather_sync/gather_async/batch_across_shards)",
        )
        return
    stages = node.params["stages"]
    capable = [
        s for s in stages
        if not s.ctx
        and hasattr(s.fn, "num_learners") and hasattr(s.fn, "microbatch")
    ]
    if capable:
        return
    if any(s.ctx for s in stages):
        yield Diagnostic(
            "annotation-lowering", Severity.INFO,
            f"{'/'.join(carried)} on a context-built stage: the static "
            "pass cannot verify the compiled callable accepts learner "
            "knobs (checked again at lowering)",
            node=node.id,
            hint="prefer annotating a plain TrainOneStep stage",
        )
    else:
        names = ", ".join(s.label for s in stages) or "<none>"
        yield Diagnostic(
            "annotation-lowering", Severity.ERROR,
            f"{'/'.join(carried)} but no stage of this node accepts "
            f"learner knobs (stages: {names}); the annotation is silently "
            "ignored and training stays single-device",
            node=node.id,
            hint="attach the annotation to the TrainOneStep stage's node",
        )


def _check_vector_annotations(
    view: GraphView, node: Any, ann: Dict[str, Any]
) -> Iterator[Diagnostic]:
    if not any(k in ann for k in _VECTOR_KEYS):
        return
    carried = {k: ann[k] for k in _VECTOR_KEYS if k in ann}
    if node.kind not in ("rollouts", "par_gradients"):
        yield Diagnostic(
            "annotation-lowering", Severity.ERROR,
            f"{'/'.join(carried)} annotates a {node.kind!r} node; the "
            "vectorized rollout engine lowers only onto rollouts/"
            "par_gradients sources — the annotation is silently ignored",
            node=node.id,
            hint="pass vector=/inference= to spec.rollouts()/par_gradients()",
        )
        return
    vec = carried.get("vector")
    if vec is not None and (not isinstance(vec, int) or vec < 1):
        yield Diagnostic(
            "annotation-lowering", Severity.ERROR,
            f"vector={vec!r} is not a positive lane count",
            node=node.id, hint="vector must be >= 1",
        )
    creds = carried.get("inference_credits")
    if creds is not None and (not isinstance(creds, int) or creds < 1):
        yield Diagnostic(
            "annotation-lowering", Severity.ERROR,
            f"inference_credits={creds!r} is not a positive int",
            node=node.id, hint="inference_credits must be >= 1",
        )
    replicas = carried.get("inference_replicas")
    if replicas is not None and (not isinstance(replicas, int) or replicas < 1):
        yield Diagnostic(
            "annotation-lowering", Severity.ERROR,
            f"inference_replicas={replicas!r} is not a positive int",
            node=node.id, hint="inference_replicas must be >= 1",
        )
    dec = carried.get("decode")
    if dec is not None and dec not in ("forward", "cache"):
        yield Diagnostic(
            "annotation-lowering", Severity.ERROR,
            f"unknown decode mode {dec!r} (want 'forward'|'cache')",
            node=node.id, hint="pick 'forward' or 'cache'",
        )
    routing = carried.get("inference_routing")
    if routing is not None and routing not in ("auto", "least_loaded", "sticky"):
        yield Diagnostic(
            "annotation-lowering", Severity.ERROR,
            f"unknown inference routing {routing!r} "
            "(want 'auto'|'least_loaded'|'sticky')",
            node=node.id, hint="pick 'auto', 'least_loaded', or 'sticky'",
        )
    inf = carried.get("inference")
    if inf != "server" and (replicas is not None or routing is not None):
        keys = "/".join(
            k for k in ("inference_replicas", "inference_routing") if k in carried
        )
        yield Diagnostic(
            "annotation-lowering", Severity.WARN,
            f"{keys} without inference='server': the serving tier only "
            "lowers in server mode, so the annotation is silently ignored",
            node=node.id,
            hint="add inference='server' (or drop the serving knobs)",
        )
    if inf is not None and inf not in ("local", "server"):
        yield Diagnostic(
            "annotation-lowering", Severity.ERROR,
            f"unknown inference mode {inf!r} (want 'local'|'server')",
            node=node.id, hint="pick 'local' or 'server'",
        )
    elif inf == "server":
        pool = view.node_pool(node)
        lw = pool.local_worker() if hasattr(pool, "local_worker") else None
        if lw is not None and getattr(lw, "policy", None) is None:
            yield Diagnostic(
                "annotation-lowering", Severity.ERROR,
                "inference='server' but the local worker has no .policy to "
                "serve; lowering falls back to local inference",
                node=node.id,
                hint="use a worker type exposing .policy, or drop "
                "inference='server'",
            )


# --------------------------------------------------------------------------
# cross-host-placement: host annotations that cannot partition cleanly (PR 7)
# --------------------------------------------------------------------------
@rule("cross-host-placement", "host fragments that cannot lower cleanly")
def _cross_host_placement(view: GraphView) -> Iterator[Diagnostic]:
    """Validate the multi-host fragment partition before any host launches.

    ``compile()`` splits a spec into per-host fragments along ``host=``
    annotations, rehoming each annotated source pool onto a
    ``RemoteBackend`` (socket transport).  Everything that would make that
    partition unsound is a graph property: placement on a node lowering
    never reads, an undeclared host, an shm data plane that cannot span
    the host boundary, or a driver-pinned inference server claimed by a
    remote fragment.
    """
    spec = view.spec
    host_by_pool: Dict[int, Tuple[str, str]] = {}  # id(pool) -> (host, node)
    for node in spec.nodes.values():
        host = node.annotations.get("host")
        if host is None:
            continue
        if not isinstance(host, str) or not host:
            yield Diagnostic(
                "cross-host-placement", Severity.ERROR,
                f"host={host!r} is not a host name",
                node=node.id,
                hint="annotate with the name passed to spec.declare_host()",
            )
            continue
        if node.kind not in SOURCE_KINDS:
            yield Diagnostic(
                "cross-host-placement", Severity.ERROR,
                f"host={host!r} annotates a {node.kind!r} node; placement "
                "lowers onto source actor pools only — the annotation is "
                "silently ignored and the node stays on the driver",
                node=node.id,
                hint="annotate the source node (rollouts/replay/"
                "par_gradients/par_source)",
            )
            continue
        if host not in spec.hosts:
            yield Diagnostic(
                "cross-host-placement", Severity.ERROR,
                f"host={host!r} is not declared on this spec; lowering "
                "degrades the fragment to the driver's local backend",
                node=node.id,
                hint=f"call spec.declare_host({host!r}) before compiling",
            )
            continue
        # shm edges may not span fragments: a SharedMemoryTransport ref
        # names a segment in the *driver's* /dev/shm, which does not exist
        # on the remote host — rehoming a process(shm)-backed actor would
        # swap its data plane out from under the pool mid-flow.
        procs = view.process_backed(node)
        if procs:
            yield Diagnostic(
                "cross-host-placement", Severity.ERROR,
                f"host={host!r} on a source pool with process-backed "
                f"actors ({', '.join(procs)}): their shm/pipe data plane "
                "is local to the driver machine and cannot span the host "
                "boundary",
                node=node.id,
                hint="build the pool on the thread backend and let host= "
                "move it onto the socket transport, or drop the annotation",
            )
        # The decoupled inference server is a driver-side VirtualActor
        # shared by all shards; a remote fragment's shards would call back
        # across the host boundary on every action, defeating the split.
        if node.annotations.get("inference") == "server":
            yield Diagnostic(
                "cross-host-placement", Severity.ERROR,
                f"inference='server' on a node placed on host {host!r}: "
                "the inference server is pinned to the driver fragment, so "
                "every action round-trips the socket and the fragment "
                "split buys nothing",
                node=node.id,
                hint="use inference='local' on remote fragments, or keep "
                "the served pool on the driver",
            )
        pool = view.node_pool(node)
        prior = host_by_pool.get(id(pool))
        if prior is not None and prior[0] != host:
            yield Diagnostic(
                "cross-host-placement", Severity.WARN,
                f"host={host!r} conflicts with {prior[0]!r} set by node "
                f"{prior[1]} on the same actor pool; placement is "
                "per-actor and the first lowered node wins",
                node=node.id,
                hint="annotate the pool's nodes with one host",
            )
        host_by_pool[id(pool)] = (host, node.id)
    for name in spec.hosts:
        if not any(
            n.annotations.get("host") == name for n in spec.nodes.values()
        ):
            yield Diagnostic(
                "cross-host-placement", Severity.WARN,
                f"host {name!r} is declared but no node is placed on it; "
                "the declaration is dead (hosts launch lazily, so nothing "
                "runs there)",
                hint=f"place a source on it (.host({name!r})) or drop the "
                "declaration",
            )


# --------------------------------------------------------------------------
# pickle-safety: process-backend boundaries that silently change semantics
# --------------------------------------------------------------------------
@rule("pickle-safety", "state that cannot cross a ProcessBackend boundary")
def _pickle_safety(view: GraphView) -> Iterator[Diagnostic]:
    spec = view.spec
    for node in spec.nodes.values():
        if (
            node.kind in ("rollouts", "par_gradients")
            and node.annotations.get("inference") == "server"
        ):
            procs = view.process_backed(node)
            if procs:
                yield Diagnostic(
                    "pickle-safety", Severity.WARN,
                    "inference='server' with process-backed workers "
                    f"({', '.join(procs)}): InferenceClient handles do not "
                    "pickle, so these workers silently fall back to local "
                    "inference (vectorization still applies)",
                    node=node.id,
                    hint="use thread-backend rollout workers for decoupled "
                    "inference, or accept local inference explicitly",
                )
        if node.kind == "for_each" and node.parallel:
            src = view.source_of(node.id)
            if src is None or not view.process_backed(src):
                continue
            for stage in node.params["stages"]:
                if stage.ctx:
                    continue
                exc = _unpicklable(stage.fn)
                if exc is not None:
                    yield Diagnostic(
                        "pickle-safety", Severity.WARN,
                        f"parallel stage {stage.label!r} over a "
                        "process-backed pool is not picklable "
                        f"({exc}): it cannot be cloned per shard, so all "
                        "shards share one driver-side instance (per-shard "
                        "state becomes global state)",
                        node=node.id,
                        hint="make the stage a module-level callable "
                        "without live handles, or mark it "
                        "share_across_shards=True to document the sharing",
                    )
        if node.kind == "par_source" and view.process_backed(node):
            exc = _unpicklable(node.params["pull_fn"])
            if exc is not None:
                yield Diagnostic(
                    "pickle-safety", Severity.INFO,
                    f"par_source pull_fn is not picklable ({exc}); it runs "
                    "driver-side against RPC proxies, so every pulled item "
                    "round-trips the process boundary",
                    node=node.id,
                    hint="keep pull_fn free of live handles where possible",
                )


def _unpicklable(fn: Any) -> Optional[str]:
    try:
        pickle.dumps(fn)
        return None
    except Exception as exc:
        return f"{type(exc).__name__}: {exc}"


# --------------------------------------------------------------------------
# resource-oversubscription: declared demand vs visible hardware (PR 4)
# --------------------------------------------------------------------------
@rule("resource-oversubscription", "declared demand beyond visible hardware")
def _resource_oversubscription(view: GraphView) -> Iterator[Diagnostic]:
    spec = view.spec
    try:
        import jax

        ndev: Optional[int] = len(jax.devices())
    except Exception:  # pragma: no cover - jax is a hard dep in this repo
        ndev = None
    if ndev is not None:
        for node in spec.nodes.values():
            nl = node.annotations.get("num_learners")
            if isinstance(nl, int) and nl > ndev:
                yield Diagnostic(
                    "resource-oversubscription", Severity.ERROR,
                    f"num_learners={nl} exceeds the {ndev} visible "
                    "device(s); the learner group will clamp the mesh and "
                    "train on fewer shards than declared",
                    node=node.id,
                    hint=f"lower num_learners to <= {ndev}, or simulate "
                    "devices with XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N",
                )
        for res in spec.resources.values():
            nl = res.params.get("num_learners") or 0
            if isinstance(nl, int) and nl > ndev:
                yield Diagnostic(
                    "resource-oversubscription", Severity.ERROR,
                    f"resource {res.name!r} declares num_learners={nl} but "
                    f"only {ndev} device(s) are visible; the learner group "
                    "will clamp the mesh",
                    hint=f"lower num_learners to <= {ndev}, or simulate "
                    "devices with XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N",
                )
    ncpu = os.cpu_count()
    if ncpu:
        demand = 0
        anchors: List[str] = []
        for node in spec.nodes.values():
            if node.kind not in SOURCE_KINDS:
                continue
            res = node.annotations.get("resources") or {}
            per_shard = res.get("num_cpus")
            if not per_shard:
                continue
            shards = view.shard_count(node) or 1
            demand += per_shard * shards
            anchors.append(node.id)
        if anchors and demand > ncpu:
            yield Diagnostic(
                "resource-oversubscription", Severity.WARN,
                f"declared CPU demand totals {demand} across "
                f"{len(anchors)} source node(s) but only {ncpu} CPUs are "
                "visible; shards will contend instead of running in "
                "parallel",
                node=anchors[0],
                details={"declared": demand, "available": ncpu},
                hint="shrink num_cpus/shard counts or run on a bigger host",
            )


# --------------------------------------------------------------------------
# determinism-hazard: ambient RNG reaching a plan (PR 5 determinism work)
# --------------------------------------------------------------------------
@rule("determinism-hazard", "stages drawing from ambient (unseeded) RNG")
def _determinism_hazard(view: GraphView) -> Iterator[Diagnostic]:
    spec = view.spec
    for node in spec.nodes.values():
        for fn in view.stage_fns(node):
            reason = _ambient_rng_use(fn)
            if reason is not None:
                label = getattr(fn, "__name__", type(fn).__name__)
                yield Diagnostic(
                    "determinism-hazard", Severity.WARN,
                    f"stage {label!r} references {reason}: replayed runs "
                    "diverge and the PR 5 bit-determinism guarantees do "
                    "not cover this plan",
                    node=node.id,
                    hint="thread explicit seeded keys (jax.random / "
                    "np.random.Generator) through the stage instead",
                )


def _ambient_rng_use(fn: Any) -> Optional[str]:
    """Best-effort code-object scan for global-RNG use inside a stage.

    Flags the stdlib ``random`` module (resolved through the function's
    globals, so a local variable named ``random`` never trips it) and the
    ``np.random``/``numpy.random`` global generator.  ``jax.random`` is
    keyed and deterministic, so it is deliberately not flagged.
    """
    import random as _stdlib_random

    target = fn if hasattr(fn, "__code__") else getattr(type(fn), "__call__", None)
    code = getattr(target, "__code__", None)
    if code is None:
        return None
    names: set = set()
    stack = [code]
    while stack:
        c = stack.pop()
        names.update(c.co_names)
        for const in c.co_consts:
            if hasattr(const, "co_names"):
                stack.append(const)
    if "random" not in names:
        return None
    bound = getattr(target, "__globals__", {}).get("random")
    if bound is _stdlib_random:
        return "the stdlib `random` module (process-global state)"
    if "np" in names or "numpy" in names:
        return "the `np.random` global generator (process-global state)"
    return None
