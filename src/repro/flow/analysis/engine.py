"""The analysis engine: a read-only graph view, a rule registry, ``analyze``.

``analyze(spec)`` builds one ``GraphView`` (forward adjacency, resource
links, pool introspection — everything rules keep re-deriving) and runs every
registered ``Rule`` over it.  Rules are pure functions ``view -> iterable of
Diagnostic``; a rule that crashes is itself reported as an ``error``
diagnostic (``analyzer-internal``) instead of taking the pass down — the
analyzer must never be the thing that breaks a build.

Registering a rule (see ``docs/flowcheck.md`` for the full how-to)::

    from repro.flow.analysis.engine import rule
    from repro.flow.analysis.diagnostics import Diagnostic, Severity

    @rule("my-rule", "one-line description")
    def _my_rule(view):
        for node in view.spec.nodes.values():
            if looks_wrong(node):
                yield Diagnostic("my-rule", Severity.WARN, "...", node=node.id,
                                 hint="do this instead")
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Set

from repro.flow.analysis.diagnostics import Diagnostic, Severity, sort_diagnostics
from repro.flow.spec import FlowSpec, Node

__all__ = ["GraphView", "Rule", "RULES", "rule", "analyze"]

# Node kinds that own an actor pool (sources; ``compile()`` lowers failure
# annotations onto exactly these).
SOURCE_KINDS = frozenset(("rollouts", "replay", "par_gradients", "par_source"))

# Node kinds whose lowering consumes a ``credits`` bound.
CREDIT_KINDS = frozenset(("gather_async", "rollouts", "replay"))


class GraphView:
    """Read-only derived state over one ``FlowSpec`` shared by all rules."""

    def __init__(self, spec: FlowSpec):
        self.spec = spec
        # Forward stream adjacency: producer node id -> consumer node ids.
        self.consumers: Dict[str, List[str]] = {nid: [] for nid in spec.nodes}
        for node in spec.nodes.values():
            for src, _port in node.inputs:
                if src in self.consumers:
                    self.consumers[src].append(node.id)
        # Resource links (the dotted edges in ``to_dot``).
        self.enqueues: Dict[str, List[Node]] = {}
        self.dequeues: Dict[str, List[Node]] = {}
        for node in spec.nodes.values():
            if node.kind == "enqueue":
                self.enqueues.setdefault(node.params["resource"], []).append(node)
            elif node.kind == "dequeue":
                self.dequeues.setdefault(node.params["resource"], []).append(node)

    # ------------------------------------------------------------ traversal
    def downstream(self, node_id: str) -> Iterator[Node]:
        """Transitive stream-edge successors of ``node_id`` (excl. itself)."""
        seen: Set[str] = set()
        stack = list(self.consumers.get(node_id, ()))
        while stack:
            nid = stack.pop()
            if nid in seen:
                continue
            seen.add(nid)
            yield self.spec.nodes[nid]
            stack.extend(self.consumers.get(nid, ()))

    def upstream(self, node_id: str) -> Iterator[Node]:
        """Transitive stream-edge predecessors of ``node_id`` (excl. itself)."""
        seen: Set[str] = set()
        stack = [src for src, _ in self.spec.nodes[node_id].inputs]
        while stack:
            nid = stack.pop()
            if nid in seen or nid not in self.spec.nodes:
                continue
            seen.add(nid)
            yield self.spec.nodes[nid]
            stack.extend(src for src, _ in self.spec.nodes[nid].inputs)

    def union_of(self, node_id: str) -> Optional[Node]:
        """The first ``concurrently`` node the branch of ``node_id`` feeds."""
        for node in self.downstream(node_id):
            if node.kind == "concurrently":
                return node
        return None

    # -------------------------------------------------------- introspection
    @staticmethod
    def node_pool(node: Node) -> Any:
        """The worker group / actor pool a source node is built over."""
        p = node.params
        return p.get("workers") or p.get("actors") or p.get("pool")

    @classmethod
    def pool_actors(cls, node: Node) -> List[Any]:
        """Remote actors behind a source node ([] when not introspectable)."""
        pool = cls.node_pool(node)
        if pool is None:
            return []
        try:
            if hasattr(pool, "remote_workers"):
                return list(pool.remote_workers())
            return list(pool)
        except Exception:
            return []

    @classmethod
    def shard_count(cls, node: Node) -> Optional[int]:
        actors = cls.pool_actors(node)
        return len(actors) if actors else None

    @classmethod
    def process_backed(cls, node: Node) -> List[str]:
        """Names of the node's actors living on a process backend."""
        return [
            getattr(a, "name", repr(a))
            for a in cls.pool_actors(node)
            if getattr(a, "backend_name", None) == "process"
        ]

    def source_of(self, node_id: str) -> Optional[Node]:
        """The (first) source node feeding ``node_id``'s stream, if any."""
        node = self.spec.nodes[node_id]
        if node.kind in SOURCE_KINDS:
            return node
        for up in self.upstream(node_id):
            if up.kind in SOURCE_KINDS:
                return up
        return None

    def effective_enqueue_policy(self, node: Node) -> str:
        """Mirror of the lowering precedence: annotation > policy > block."""
        policy = node.annotations.get("overflow_policy", node.params.get("policy"))
        if policy is None:
            policy = "block" if node.params.get("block", True) else "drop_newest"
        return policy

    def effective_credits(self, node: Node) -> Optional[int]:
        """Mirror of the lowering precedence: annotation > credits param."""
        return node.annotations.get("credits", node.params.get("credits"))

    def stage_fns(self, node: Node) -> List[Any]:
        """Statically visible callables of a node (ctx factories excluded)."""
        if node.kind == "for_each":
            return [s.fn for s in node.params["stages"] if not s.ctx]
        if node.kind == "filter":
            return [node.params["predicate"]]
        if node.kind == "par_source":
            return [node.params["pull_fn"]]
        return []


@dataclass(frozen=True)
class Rule:
    """One registered analysis: a name, a description, and a check."""

    name: str
    description: str
    fn: Callable[[GraphView], Iterable[Diagnostic]]


RULES: Dict[str, Rule] = {}


def rule(name: str, description: str) -> Callable:
    """Register an analysis rule under ``name`` (kebab-case)."""

    def deco(fn: Callable[[GraphView], Iterable[Diagnostic]]) -> Callable:
        if name in RULES:
            raise ValueError(f"duplicate rule {name!r}")
        RULES[name] = Rule(name, description, fn)
        return fn

    return deco


def analyze(
    spec: FlowSpec, rules: Optional[Sequence[str]] = None
) -> List[Diagnostic]:
    """Run the rule set over ``spec`` and return sorted diagnostics.

    ``rules`` restricts the pass to a subset of rule names (default: all
    registered).  Never raises on account of the spec: structural breakage
    surfaces as ``graph-structure`` errors, and a crashing rule surfaces as
    an ``analyzer-internal`` error naming the rule.
    """
    # Importing for side effect: the built-in rules register on first use.
    from repro.flow.analysis import rules as _builtin  # noqa: F401

    view = GraphView(spec)
    selected = (
        [RULES[r] for r in rules] if rules is not None else list(RULES.values())
    )
    out: List[Diagnostic] = []
    for r in selected:
        try:
            out.extend(r.fn(view))
        except Exception as exc:
            out.append(
                Diagnostic(
                    rule="analyzer-internal",
                    severity=Severity.ERROR,
                    message=f"rule {r.name!r} crashed: {exc!r}",
                    hint="this is an analyzer bug; file it with the spec that "
                    "triggered it",
                )
            )
    return sort_diagnostics(out)
