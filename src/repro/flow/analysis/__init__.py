"""repro.flow.analysis: static analysis over the FlowSpec IR (flowcheck).

The paper's claim — an RL program *is* a dataflow graph — cuts both ways:
misconfigurations (credit deadlocks, unbounded queues, annotations that
cannot lower) are graph properties, detectable before a single actor
spawns.  This package is the rule-based pass that detects them:

    from repro.flow.analysis import analyze
    diags = analyze(spec)              # or spec.check()
    spec.compile(strict=True)          # raise FlowAnalysisError on errors

Layout: ``diagnostics`` (the Diagnostic/Severity vocabulary, shared with
the lowering fallbacks in ``flow/compile.py``), ``engine`` (GraphView +
rule registry + ``analyze``), ``rules`` (the built-in rule set), ``audit``
(the all-committed-plans sweep behind ``scripts/flowcheck.py``).
"""

from repro.flow.analysis.audit import audit_plans
from repro.flow.analysis.diagnostics import (
    Diagnostic,
    FlowAnalysisError,
    Severity,
    format_report,
    sort_diagnostics,
)
from repro.flow.analysis.engine import RULES, GraphView, Rule, analyze, rule

__all__ = [
    "Diagnostic",
    "FlowAnalysisError",
    "GraphView",
    "RULES",
    "Rule",
    "Severity",
    "analyze",
    "audit_plans",
    "format_report",
    "rule",
    "sort_diagnostics",
]
