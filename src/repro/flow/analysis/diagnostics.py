"""The diagnostics vocabulary shared by static analysis and lowering.

A ``Diagnostic`` is one finding about a ``FlowSpec``: which rule fired, how
bad it is, which node/edge it anchors to, and — always — a fix hint.  The
same vocabulary is used by

  * the static pass (``repro.flow.analysis.analyze`` / ``FlowSpec.check()``),
    which inspects the graph before anything is constructed, and
  * the lowering fallbacks in ``repro.flow.compile`` (``CompiledFlow
    .diagnostics``), which previously degraded semantics behind warn-once
    ``logger.warning`` calls.

Severity policy (documented in ``docs/flowcheck.md``):

  ERROR — the graph property makes the plan wrong: it cannot lower, will
          wedge, or will silently train something other than what was
          declared.  ``scripts/flowcheck.py`` and ``compile(strict=True)``
          gate on these.
  WARN  — the plan runs but with degraded or surprising behaviour
          (fallbacks, unbounded buffering, nondeterminism hazards).
  INFO  — observations that need runtime context to resolve (e.g. a
          context-built stage the static pass cannot see inside).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["Severity", "Diagnostic", "FlowAnalysisError", "format_report"]


class Severity:
    """Diagnostic severity ladder (mirrors ``FailurePolicy``-style enums)."""

    ERROR = "error"
    WARN = "warn"
    INFO = "info"
    ALL = frozenset((ERROR, WARN, INFO))
    _ORDER = {ERROR: 0, WARN: 1, INFO: 2}

    @classmethod
    def validate(cls, severity: str) -> str:
        if severity not in cls.ALL:
            raise ValueError(
                f"unknown severity {severity!r}; expected one of {sorted(cls.ALL)}"
            )
        return severity

    @classmethod
    def rank(cls, severity: str) -> int:
        """Sort key: errors first."""
        return cls._ORDER[severity]

    @classmethod
    def at_least(cls, severity: str, floor: str) -> bool:
        """True if ``severity`` is as bad as ``floor`` or worse."""
        return cls._ORDER[severity] <= cls._ORDER[floor]


@dataclass(frozen=True)
class Diagnostic:
    """One analyzer finding, anchored to a node (and optionally an edge).

    ``rule`` is the kebab-case rule name (``credit-deadlock``); ``node`` is
    the offending node id (``n3_enqueue``) or None for whole-graph findings;
    ``edge`` is a ``(producer_node_id, port)`` ref when the finding is about
    a specific stream edge; ``hint`` says how to fix it.
    """

    rule: str
    severity: str
    message: str
    node: Optional[str] = None
    edge: Optional[Tuple[str, int]] = None
    hint: Optional[str] = None
    details: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        Severity.validate(self.severity)

    @property
    def is_error(self) -> bool:
        return self.severity == Severity.ERROR

    def format(self) -> str:
        """One human-readable block: ``severity[rule] anchor: message``."""
        anchor = self.node or "<flow>"
        if self.edge is not None:
            anchor += f" (edge {self.edge[0]}:{self.edge[1]})"
        out = f"{self.severity}[{self.rule}] {anchor}: {self.message}"
        if self.hint:
            out += f"\n  hint: {self.hint}"
        return out

    def to_json(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "rule": self.rule,
            "severity": self.severity,
            "message": self.message,
            "node": self.node,
            "hint": self.hint,
        }
        if self.edge is not None:
            out["edge"] = list(self.edge)
        if self.details:
            out["details"] = dict(self.details)
        return out


class FlowAnalysisError(ValueError):
    """Raised by strict compilation when a plan carries error diagnostics.

    Carries the full diagnostic list so callers (tests, CLIs) can inspect
    which rules fired instead of parsing the message.
    """

    def __init__(self, diagnostics: Sequence[Diagnostic], flow: str = "flow"):
        self.diagnostics: List[Diagnostic] = list(diagnostics)
        errors = [d for d in self.diagnostics if d.is_error]
        body = "\n".join(d.format() for d in self.diagnostics)
        super().__init__(
            f"flow {flow!r} failed static analysis with "
            f"{len(errors)} error(s) ({len(self.diagnostics)} total):\n{body}"
        )


def sort_diagnostics(diags: Sequence[Diagnostic]) -> List[Diagnostic]:
    """Stable order: severity first, then rule name, then node anchor."""
    return sorted(
        diags, key=lambda d: (Severity.rank(d.severity), d.rule, d.node or "")
    )


def format_report(diags: Sequence[Diagnostic], name: str = "flow") -> str:
    """The text report ``scripts/flowcheck.py`` prints per plan."""
    diags = sort_diagnostics(diags)
    if not diags:
        return f"{name}: clean (0 diagnostics)"
    counts: Dict[str, int] = {}
    for d in diags:
        counts[d.severity] = counts.get(d.severity, 0) + 1
    summary = ", ".join(
        f"{counts[s]} {s}" for s in (Severity.ERROR, Severity.WARN, Severity.INFO)
        if s in counts
    )
    body = "\n".join("  " + d.format().replace("\n", "\n  ") for d in diags)
    return f"{name}: {summary}\n{body}"
