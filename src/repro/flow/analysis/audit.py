"""Audit the committed plan catalog: build each plan, run the analyzer.

Shared by ``scripts/flowcheck.py`` (the CLI/CI gate) and
``tests/test_flow_analysis.py`` (the error-clean regression) so both check
exactly the same thing: every builder in ``PLAN_BUILDERS``, constructed over
a small real worker group, must carry zero error-severity diagnostics.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.flow.analysis.diagnostics import Diagnostic
from repro.flow.analysis.engine import analyze

__all__ = ["audit_plans", "build_plan_specs"]


def build_plan_specs(plans: Optional[Sequence[str]] = None):
    """Yield ``(name, spec)`` for each requested committed plan.

    Builds one shared 2-worker group (and a replay pool for the plans that
    need one) exactly the way ``scripts/render_figures.py`` does, and tears
    both down when the generator is exhausted or closed.
    """
    from repro.core.actor import ActorPool
    from repro.core.workers import WorkerSet
    from repro.flow.plans import PLAN_BUILDERS, REPLAY_PLANS
    from repro.rl import ActorCriticPolicy, CartPole, ReplayBuffer, RolloutWorker

    names = sorted(PLAN_BUILDERS) if plans is None else list(plans)
    unknown = sorted(set(names) - set(PLAN_BUILDERS))
    if unknown:
        raise KeyError(f"unknown plans: {unknown}")

    def factory(i: int) -> RolloutWorker:
        return RolloutWorker(
            CartPole(), ActorCriticPolicy(4, 2), algo="pg",
            num_envs=2, rollout_len=8, seed=0, worker_index=i,
        )

    workers = WorkerSet.create(factory, 2)
    replay = None
    try:
        for name in names:
            if name in REPLAY_PLANS:
                if replay is None:
                    replay = ActorPool.from_targets([
                        ReplayBuffer(
                            capacity=1024, sample_batch_size=32,
                            learning_starts=64,
                        )
                    ])
                yield name, PLAN_BUILDERS[name](workers, replay)
            else:
                yield name, PLAN_BUILDERS[name](workers)
    finally:
        if replay is not None:
            replay.stop()
        workers.stop()


def audit_plans(
    plans: Optional[Sequence[str]] = None,
    rules: Optional[Sequence[str]] = None,
) -> Dict[str, List[Diagnostic]]:
    """Analyze each committed plan; plan name -> sorted diagnostics."""
    return {
        name: analyze(spec, rules=rules)
        for name, spec in build_plan_specs(plans)
    }
