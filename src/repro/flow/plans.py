"""The paper's Table 2 algorithm suite as declarative flow graphs.

Each ``build_*`` function assembles a ``FlowSpec`` — the graph the paper
draws in Figures 9–12, as a value you can inspect (``to_dot()``), optimize
(stage fusion), and lower (``compile()``).  ``repro.core.plans`` keeps the
original eager plan functions as thin compat shims over these builders, and
``repro.flow.Algorithm`` is the run-facade.

``benchmarks/bench_loc.py`` counts these builders against the low-level
ports in ``repro/rl/lowlevel.py`` to reproduce Table 2.
"""

from __future__ import annotations

from typing import Any, Dict, Sequence

from repro.core.actor import ActorPool
from repro.core.metrics import STEPS_TRAINED_COUNTER, get_metrics
from repro.core.operators import (
    ApplyGradients,
    AverageGradients,
    ConcatBatches,
    SelectExperiences,
    StandardizeFields,
    StoreToReplayBuffer,
    TrainOneStep,
    UpdateReplayPriorities,
    UpdateTargetNetwork,
    UpdateWorkerWeights,
)
from repro.core.workers import WorkerSet
from repro.flow.spec import FlowSpec, pure

__all__ = [
    "PLAN_BUILDERS",
    "REPLAY_PLANS",
    "build_a3c",
    "build_a2c",
    "build_ppo",
    "build_ppo_lm",
    "build_dqn",
    "build_apex",
    "build_impala",
    "build_sac",
    "build_maml",
    "build_appo",
    "build_mbpo",
    "build_multi_agent_ppo_dqn",
]


# --------------------------------------------------------------------- A3C
def build_a3c(workers: WorkerSet, num_async: int = 1) -> FlowSpec:
    """Figure 9a: async per-worker gradients applied centrally."""
    spec = FlowSpec("a3c")
    grads = spec.par_gradients(workers).gather_async(num_async=num_async)
    apply_op = grads.for_each(ApplyGradients(workers, update_all=False))
    spec.set_output(apply_op.report(workers))
    return spec


# --------------------------------------------------------------------- A2C
def build_a2c(
    workers: WorkerSet,
    vector: int = 0,
    inference: str = None,
) -> FlowSpec:
    """Synchronous A3C: barrier-gather gradients, average, apply, broadcast.

    ``vector=N`` runs each gradient worker's sampling through the
    vectorized rollout engine (N lanes, one batched dispatch per step);
    ``inference='server'`` decouples acting onto a shared InferenceActor.
    """
    spec = FlowSpec("a2c")
    grads = spec.par_gradients(
        workers, vector=vector or None, inference=inference
    ).batch_across_shards()
    apply_op = grads.for_each(AverageGradients()).for_each(
        ApplyGradients(workers, update_all=True)
    )
    spec.set_output(apply_op.report(workers))
    return spec


# --------------------------------------------------------------------- PPO
def build_ppo(
    workers: WorkerSet,
    train_batch_size: int = 4000,
    num_sgd_iter: int = 8,
    sgd_minibatch_size: int = 128,
    num_learners: int = 0,
    microbatch: int = 0,
    vector: int = 0,
    inference: str = None,
    inference_replicas: int = 0,
    inference_routing: str = None,
    failure_policy: str = None,
    host: str = None,
) -> FlowSpec:
    """Synchronous sample -> concat -> standardize -> multi-epoch SGD.

    ``num_learners``/``microbatch`` annotate the TrainOneStep node
    (``stream.learners(n).microbatch(k)``); ``compile()`` lowers the
    annotations onto a sharded SPMD learner group (ISSUE 4).

    ``vector``/``inference`` annotate the rollouts node with the vectorized
    rollout engine (ISSUE 5): N synchronized env lanes per worker with one
    batched policy dispatch per step, optionally served by a decoupled
    InferenceActor (``inference='server'``).  ``inference_replicas``/
    ``inference_routing`` scale that into a multi-replica serving tier
    behind an ``InferenceRouter`` (ISSUE 9); ``failure_policy`` on the
    rollouts node doubles as the replica-loss policy.

    ``host`` places the rollout fragment on a declared host (ISSUE 7): the
    caller must also ``spec.declare_host(host)`` on the returned spec, and
    ``compile()`` rehomes the rollout actors onto that host's
    ``RemoteBackend`` so samples cross the socket transport.
    """
    spec = FlowSpec("ppo")
    train_op = (
        spec.rollouts(
            workers, mode="bulk_sync", vector=vector or None, inference=inference,
            inference_replicas=inference_replicas or None,
            inference_routing=inference_routing,
            failure_policy=failure_policy,
            host=host,
        )
        .for_each(ConcatBatches(train_batch_size), label=f"ConcatBatches({train_batch_size})")
        .for_each(StandardizeFields(["advantages"]))
        .for_each(
            TrainOneStep(
                workers,
                num_sgd_iter=num_sgd_iter,
                sgd_minibatch_size=sgd_minibatch_size,
            )
        )
    )
    if num_learners:
        train_op = train_op.learners(num_learners)
    if microbatch:
        train_op = train_op.microbatch(microbatch)
    spec.set_output(train_op.report(workers))
    return spec


# ------------------------------------------------------------------ PPO-LM
def build_ppo_lm(
    workers: WorkerSet,
    train_batch_size: int = 256,
    num_sgd_iter: int = 4,
    sgd_minibatch_size: int = 64,
    num_learners: int = 0,
    microbatch: int = 0,
    vector: int = 0,
    inference: str = None,
    inference_replicas: int = 0,
    inference_routing: str = None,
    decode: str = "cache",
) -> FlowSpec:
    """PPO on a language-model workload (RLHF-style token generation).

    Same dataflow shape as ``build_ppo`` — sample -> concat -> standardize
    -> multi-epoch SGD — but the rollouts node carries ``decode='cache'``:
    ``compile()`` lowers it onto the stateful-policy protocol so each env
    lane generates tokens through a per-lane KV cache (prefill once per
    episode, then one ``ops.decode_attention`` step per action) instead of
    re-running the O(S) forward every token.  Pass ``decode='forward'`` to
    fall back to the no-cache path; workers whose policy lacks the protocol
    (e.g. the generic CartPole smoke workers in ``audit_plans``) fall back
    automatically with a warning.

    Defaults are sized for the small-vocab ``TokenEnv`` workload (see
    ``launch/rlhf.py``); all the PPO knobs (sharded learners, inference
    serving tier) compose unchanged.
    """
    spec = FlowSpec("ppo_lm")
    train_op = (
        spec.rollouts(
            workers, mode="bulk_sync", vector=vector or None, inference=inference,
            inference_replicas=inference_replicas or None,
            inference_routing=inference_routing,
            decode=decode,
        )
        .for_each(ConcatBatches(train_batch_size), label=f"ConcatBatches({train_batch_size})")
        .for_each(StandardizeFields(["advantages"]))
        .for_each(
            TrainOneStep(
                workers,
                num_sgd_iter=num_sgd_iter,
                sgd_minibatch_size=sgd_minibatch_size,
            )
        )
    )
    if num_learners:
        train_op = train_op.learners(num_learners)
    if microbatch:
        train_op = train_op.microbatch(microbatch)
    spec.set_output(train_op.report(workers))
    return spec


# --------------------------------------------------------------------- DQN
def build_dqn(
    workers: WorkerSet,
    replay_actors: ActorPool,
    target_update_freq: int = 500,
    store_weight: int = 1,
    replay_weight: int = 1,
    name: str = "dqn",
) -> FlowSpec:
    """Store/replay sub-flows composed round-robin (rate-limited 1:1)."""
    spec = FlowSpec(name)
    store_op = spec.rollouts(workers, mode="bulk_sync").for_each(
        StoreToReplayBuffer(replay_actors)
    )

    # Train on replayed batches, then push new priorities back to the source
    # replay actor (fine-grained message passing).
    train = TrainOneStep(workers)

    @pure
    def _train_keeping_actor(pair):
        batch, actor = pair
        return train(batch), actor

    replay_op = (
        spec.replay(replay_actors)
        .zip_with_source_actor()
        .for_each(_train_keeping_actor, label="TrainOneStep")
        .for_each(UpdateReplayPriorities())
        .for_each(UpdateTargetNetwork(workers, target_update_freq))
    )
    merged = spec.concurrently(
        [store_op, replay_op],
        mode="round_robin",
        output_indexes=[1],
        round_robin_weights=[store_weight, replay_weight],
    )
    spec.set_output(merged.report(workers))
    return spec


# -------------------------------------------------------------------- Ape-X
def build_apex(
    workers: WorkerSet,
    replay_actors: ActorPool,
    target_update_freq: int = 2500,
    max_weight_sync_delay: int = 400,
    num_async_rollouts: int = 2,
    num_async_replay: int = 4,
    block_on_enqueue: bool = True,
    enqueue_policy: str = None,
    replay_credits: int = None,
) -> FlowSpec:
    """Listing A3: three concurrent sub-flows around a learner thread.

    The learner thread is a *deferred resource*: declared here, constructed
    at compile time, started on the first pull, joined on ``stop()``.

    Backpressure knobs (data plane, ISSUE 3): ``enqueue_policy`` sets the
    learner-feed overflow policy directly ("block" | "drop_newest" |
    "drop_oldest"); ``block_on_enqueue=False`` remains as shorthand for the
    paper's lossy feed ("drop_newest": when the learner falls behind,
    batches are dropped and counted as ``num_samples_dropped`` in train()
    results instead of backpressuring the replay sub-flow).
    ``replay_credits`` caps the replay gather's total in-flight window.
    """
    spec = FlowSpec("apex")
    learner = spec.learner_thread(workers)

    # (1) rollouts -> replay actors; fine-grained weight refresh.
    store_op = (
        spec.rollouts(workers, mode="async", num_async=num_async_rollouts)
        .for_each(StoreToReplayBuffer(replay_actors))
        .zip_with_source_actor()
        .for_each(UpdateWorkerWeights(workers, max_weight_sync_delay))
    )

    # (2) replayed batches -> learner in-queue (credit-bounded gather).
    replay_op = (
        spec.replay(replay_actors, num_async=num_async_replay, credits=replay_credits)
        .zip_with_source_actor()
        .enqueue(learner, block=block_on_enqueue, policy=enqueue_policy)
    )

    # (3) learner out-queue -> priority updates + target sync + metrics.
    @pure
    def _record(item):
        actor, batch, info = item
        get_metrics().counters[STEPS_TRAINED_COUNTER] += batch.count
        return ((batch, info), actor)

    update_op = (
        spec.dequeue(learner)
        .for_each(_record, label="CountTrained")
        .for_each(UpdateReplayPriorities())
        .for_each(UpdateTargetNetwork(workers, target_update_freq))
    )

    merged = spec.concurrently(
        [store_op, replay_op, update_op], mode="async", output_indexes=[2]
    )
    spec.set_output(merged.report(workers))
    return spec


# ------------------------------------------------------------------- IMPALA
def build_impala(
    workers: WorkerSet,
    train_batch_size: int = 512,
    num_async: int = 2,
    broadcast_interval: int = 1,
    enqueue_policy: str = None,
    rollout_credits: int = None,
    num_learners: int = 0,
    microbatch: int = 0,
    vector: int = 0,
    inference: str = None,
    name: str = "impala",
) -> FlowSpec:
    """Async rollouts -> learner thread -> periodic weight broadcast.

    ``enqueue_policy``/``rollout_credits`` expose the data-plane
    backpressure knobs (see ``build_apex``); the default blocking enqueue
    backpressures the rollout pipeline when the learner saturates.
    ``num_learners``/``microbatch`` shard the learner thread's update onto
    an SPMD learner group (ISSUE 4) — the async dataflow is unchanged;
    only the learner fragment's execution mapping moves.
    ``vector``/``inference`` configure the vectorized rollout engine on the
    sampling side (ISSUE 5) — the many-shard async pipeline with N env
    lanes per shard is the high-env-count IMPALA scenario.
    """
    spec = FlowSpec(name)
    learner = spec.learner_thread(
        workers, num_learners=num_learners, microbatch=microbatch
    )

    enqueue_op = (
        spec.rollouts(
            workers, mode="async", num_async=num_async, credits=rollout_credits,
            vector=vector or None, inference=inference,
        )
        .for_each(ConcatBatches(train_batch_size), label=f"ConcatBatches({train_batch_size})")
        .enqueue(learner, block=True, policy=enqueue_policy)
    )

    # The broadcast gate reads the learner thread's dirty bit, so it is a
    # context stage: the callable is built at compile time from the runtime.
    def _broadcast_factory(rt):
        lt = rt.resource("learner")
        state = {"since_broadcast": 0}

        @pure
        def _broadcast(item):
            _actor, batch, info = item
            get_metrics().counters[STEPS_TRAINED_COUNTER] += batch.count
            state["since_broadcast"] += 1
            if state["since_broadcast"] >= broadcast_interval and lt.weights_updated:
                lt.weights_updated = False
                state["since_broadcast"] = 0
                workers.sync_weights()
            return batch, info

        return _broadcast

    update_op = spec.dequeue(learner).for_each_ctx(_broadcast_factory, label="BroadcastWeights")
    merged = spec.concurrently([enqueue_op, update_op], mode="async", output_indexes=[1])
    spec.set_output(merged.report(workers))
    return spec


# ---------------------------------------------------------------------- SAC
def build_sac(
    workers: WorkerSet,
    replay_actors: ActorPool,
    target_update_freq: int = 1,
    store_weight: int = 1,
    replay_weight: int = 1,
) -> FlowSpec:
    """Off-policy continuous control: same dataflow shape as DQN."""
    return build_dqn(
        workers,
        replay_actors,
        target_update_freq=target_update_freq,
        store_weight=store_weight,
        replay_weight=replay_weight,
        name="sac",
    )


# --------------------------------------------------------------------- MAML
def build_maml(workers: WorkerSet, inner_steps: int = 1) -> FlowSpec:
    """Figure A2: nested optimization — inner adaptation on workers, meta
    update on the driver, broadcast."""
    spec = FlowSpec("maml")

    def _inner_adaptation(w: Any) -> Any:
        pre = w.sample()
        for _ in range(inner_steps):
            w.inner_adapt(pre)
        post = w.sample()
        return {"pre": pre, "post": post}

    rollouts = spec.par_source(workers.remote_workers(), _inner_adaptation, name="MAMLInner")
    meta = TrainOneStep(workers)

    @pure
    def _meta_update(items: Sequence[Dict[str, Any]]) -> Any:
        from repro.rl.sample_batch import SampleBatch

        batch = SampleBatch.concat_samples([d["post"] for d in items])
        out = meta(batch)
        # TrainOneStep already broadcast new weights; workers reset inner state.
        for f in workers.remote_workers().broadcast("reset_inner"):
            f.result()
        return out

    train_op = rollouts.batch_across_shards().for_each(_meta_update, label="MetaUpdate")
    spec.set_output(train_op.report(workers))
    return spec


# --------------------------------------------------------------------- APPO
def build_appo(
    workers: WorkerSet,
    train_batch_size: int = 512,
    num_async: int = 2,
    broadcast_interval: int = 1,
) -> FlowSpec:
    """Async PPO (IMPACT/APPO): IMPALA's async pipeline with a clipped-
    surrogate learner — same dataflow, different numerics."""
    return build_impala(
        workers,
        train_batch_size=train_batch_size,
        num_async=num_async,
        broadcast_interval=broadcast_interval,
        name="appo",
    )


# --------------------------------------------------------------------- MBPO
def build_mbpo(
    workers: WorkerSet,
    replay_actors: ActorPool,
    model_train_weight: int = 1,
    policy_train_weight: int = 1,
) -> FlowSpec:
    """Model-based RL as three concurrent sub-flows (paper §2.2):

      (1) real rollouts -> replay buffer
      (2) replayed real batches -> supervised dynamics-model training
      (3) replayed states -> synthetic rollouts through the learned model
          -> policy TrainOneStep
    """
    spec = FlowSpec("mbpo")
    lw = workers.local_worker()
    store_op = spec.rollouts(workers, mode="bulk_sync").for_each(
        StoreToReplayBuffer(replay_actors)
    )

    model_op = spec.replay(replay_actors).for_each(
        pure(lambda b: lw.train_dynamics(b)), label="TrainDynamicsModel"
    )

    policy_op = (
        spec.replay(replay_actors)
        .for_each(pure(lambda b: lw.synthesize(b)), label="SynthesizeRollouts")
        .for_each(TrainOneStep(workers))
    )

    merged = spec.concurrently(
        [store_op, model_op, policy_op],
        mode="round_robin",
        output_indexes=[2],
        round_robin_weights=[1, model_train_weight, policy_train_weight],
    )
    spec.set_output(merged.report(workers))
    return spec


# ------------------------------------------------- Multi-agent composition
def build_multi_agent_ppo_dqn(
    workers: WorkerSet,
    replay_actors: ActorPool,
    ppo_policies: Sequence[str] = ("ppo_policy",),
    dqn_policies: Sequence[str] = ("dqn_policy",),
    ppo_batch_size: int = 1024,
    dqn_target_update_freq: int = 500,
) -> FlowSpec:
    """Figure 11/12: one environment, PPO trains some policies, DQN others.

    The rollout stream is duplicated; each branch selects its policies and
    runs its own training dataflow; the union composes them.
    """
    spec = FlowSpec("multi_agent_ppo_dqn")
    ppo_rollouts, dqn_rollouts = spec.rollouts(workers, mode="bulk_sync").duplicate(2)

    ppo_op = (
        ppo_rollouts.for_each(SelectExperiences(ppo_policies), label="SelectExperiences(ppo)")
        .for_each(ConcatBatches(ppo_batch_size), label=f"ConcatBatches({ppo_batch_size})")
        .for_each(StandardizeFields(["advantages"]))
        .for_each(TrainOneStep(workers, policies=ppo_policies), label="TrainOneStep(ppo)")
    )

    @pure
    def _select_dqn(batch):
        selected = SelectExperiences(dqn_policies)(batch)
        # Replay stores flat SampleBatches; all dqn policies share the buffer.
        from repro.rl.sample_batch import SampleBatch

        return SampleBatch.concat_samples(list(selected.policy_batches.values()))

    store_op = dqn_rollouts.for_each(_select_dqn, label="SelectExperiences(dqn)").for_each(
        StoreToReplayBuffer(replay_actors)
    )
    train_dqn = TrainOneStep(workers, policies=dqn_policies)

    @pure
    def _train_keeping_actor(pair):
        batch, actor = pair
        return train_dqn(batch), actor

    dqn_op = (
        spec.replay(replay_actors)
        .zip_with_source_actor()
        .for_each(_train_keeping_actor, label="TrainOneStep(dqn)")
        .for_each(UpdateReplayPriorities())
        .for_each(UpdateTargetNetwork(workers, dqn_target_update_freq))
    )

    merged = spec.concurrently(
        [ppo_op, store_op, dqn_op], mode="round_robin", output_indexes=[0, 2]
    )
    spec.set_output(merged.report(workers))
    return spec


PLAN_BUILDERS: Dict[str, Any] = {
    "a3c": build_a3c,
    "a2c": build_a2c,
    "ppo": build_ppo,
    "ppo_lm": build_ppo_lm,
    "dqn": build_dqn,
    "apex": build_apex,
    "impala": build_impala,
    "sac": build_sac,
    "maml": build_maml,
    "appo": build_appo,
    "mbpo": build_mbpo,
    "multi_agent_ppo_dqn": build_multi_agent_ppo_dqn,
}

# Plans whose builders take (workers, replay_actors, ...).
REPLAY_PLANS = frozenset({"dqn", "apex", "sac", "mbpo", "multi_agent_ppo_dqn"})
