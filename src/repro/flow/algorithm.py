"""``Algorithm``: the unified runtime facade over compiled flow graphs.

One object owns the whole lifecycle every driver used to hand-roll:

    algo = Algorithm.from_plan("apex", workers, replay_actors,
                               target_update_freq=2000)
    result = algo.train()          # one result dict from the plan's stream
    algo.save("ckpt.npz")          # durable state = policy weights (§3)
    algo.stop()                    # joins learner threads, stops actors

or as a context manager::

    with Algorithm.from_plan("ppo", workers, train_batch_size=1024) as algo:
        for _ in range(100):
            print(algo.train()["episodes"]["episode_reward_mean"])

Side effects are deferred: constructing the Algorithm compiles the graph but
starts nothing; the first ``train()`` starts learner threads; ``stop()``
joins them — after it returns, no flow-owned threads are alive.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Union

from repro.core.iterators import LocalIterator
from repro.flow.compile import CompiledFlow
from repro.flow.plans import PLAN_BUILDERS, REPLAY_PLANS
from repro.flow.spec import FlowSpec

__all__ = ["Algorithm"]


class Algorithm:
    """Run-facade for a compiled flow: train / checkpoint / introspect / stop."""

    def __init__(
        self,
        compiled: CompiledFlow,
        workers: Any,
        replay_actors: Any = None,
        own_workers: bool = True,
    ):
        self._compiled = compiled
        self._workers = workers
        self._replay = replay_actors
        self._own_workers = own_workers
        self._it: LocalIterator = compiled.iterator()
        self._stopped = False

    # ------------------------------------------------------------ creation
    @classmethod
    def from_plan(
        cls,
        plan: Union[str, Callable[..., FlowSpec], FlowSpec],
        workers: Any,
        replay_actors: Any = None,
        *,
        fuse: bool = True,
        strict: bool = False,
        own_workers: bool = True,
        **plan_kwargs: Any,
    ) -> "Algorithm":
        """Build, optimize, and lower a plan.

        ``plan`` is a registered name (``"ppo"``, ``"apex"``, ...), a builder
        callable returning a ``FlowSpec``, or an already-built ``FlowSpec``.
        ``strict=True`` gates compilation on the static analyzer: a plan
        carrying error-severity diagnostics raises ``FlowAnalysisError``
        before any resource is built (see ``docs/flowcheck.md``).
        """
        if isinstance(plan, FlowSpec):
            if plan_kwargs:
                raise ValueError(
                    "plan kwargs have no effect on an already-built FlowSpec; "
                    f"pass them to the builder instead (got {sorted(plan_kwargs)})"
                )
            spec = plan
        else:
            if isinstance(plan, str):
                if plan not in PLAN_BUILDERS:
                    raise ValueError(
                        f"unknown plan {plan!r}; known: {sorted(PLAN_BUILDERS)}"
                    )
                if plan in REPLAY_PLANS and replay_actors is None:
                    raise ValueError(f"plan {plan!r} requires replay_actors")
                builder = PLAN_BUILDERS[plan]
            else:
                builder = plan
            args = (workers,) if replay_actors is None else (workers, replay_actors)
            spec = builder(*args, **plan_kwargs)
        return cls(
            spec.compile(fuse=fuse, strict=strict),
            workers,
            replay_actors,
            own_workers=own_workers,
        )

    # ------------------------------------------------------------ training
    def train(self) -> Dict[str, Any]:
        """Pull one result dict (starts deferred resources on first call)."""
        if self._stopped:
            raise RuntimeError("Algorithm is stopped")
        return next(self._it)

    def iterate(self, n: int) -> List[Dict[str, Any]]:
        """Pull ``n`` results (fewer if the flow is finite and drains)."""
        if self._stopped:
            raise RuntimeError("Algorithm is stopped")
        return self._it.take(n)

    def __iter__(self):
        if self._stopped:
            raise RuntimeError("Algorithm is stopped")
        return iter(self._it)

    # ------------------------------------------------------ introspection
    @property
    def spec(self) -> FlowSpec:
        return self._compiled.spec

    @property
    def compiled(self) -> CompiledFlow:
        return self._compiled

    @property
    def workers(self) -> Any:
        return self._workers

    @property
    def resources(self) -> Dict[str, Any]:
        """Deferred runtime resources by name (e.g. learner threads)."""
        return self._compiled.runtime.resources

    def check(self) -> List[Any]:
        """Static analysis of this algorithm's plan (``FlowSpec.check``).

        Returns the combined diagnostic list: the analyzer's findings over
        the *source* spec (pre-fusion, so node ids match what the builder
        created) plus anything the lowering fallbacks recorded while this
        flow compiled.  Empty list = clean.
        """
        from repro.flow.analysis.diagnostics import sort_diagnostics

        return sort_diagnostics(
            list(self._compiled.source_spec.check())
            + list(self._compiled.diagnostics)
        )

    def explain(self, hw: Any = None) -> Any:
        """Roofline-driven per-stage cost attribution (``ExplainReport``).

        Lowers each stage's jitted program (rollout scan, fused SGD step) to
        optimized HLO, prices it with the trip-count-aware cost model
        against ``hw`` (default TPU v5e), and joins the live per-node
        metrics this flow has accumulated — so run a few ``train()`` calls
        first if you want the wall-time columns populated.  Memory-bound
        stages are flagged as Pallas-kernel candidates.  Purely
        introspective: nothing is executed and worker state is unchanged.
        """
        if self._stopped:
            raise RuntimeError("Algorithm is stopped")
        from repro.distributed.hlo_analysis import HW_V5E
        from repro.flow.explain import explain_flow

        return explain_flow(
            self._compiled, self._workers, self._it.metrics,
            hw=hw if hw is not None else HW_V5E,
        )

    def to_dot(self, with_metrics: bool = False) -> str:
        """DOT rendering of the plan; ``with_metrics=True`` labels data-plane
        edges with live bytes-moved counters and queue occupancy."""
        if with_metrics:
            return self._compiled.spec.to_dot(metrics=self._it.metrics)
        return self._compiled.to_dot()

    # ------------------------------------------------- fault tolerance
    def recover(self) -> Dict[str, List[str]]:
        """Heal the worker group after failures: dead rollout workers are
        restarted in place (factory rebuild) or replaced, then the canonical
        weights are re-broadcast.  Pool-aware gather loops pick the healed
        workers back up mid-stream.  Returns a report of what was done."""
        if self._stopped:
            raise RuntimeError("Algorithm is stopped")
        if not hasattr(self._workers, "recover"):
            raise RuntimeError("workers do not support recover()")
        return self._workers.recover()

    def add_workers(self, num_workers: int) -> List[str]:
        """Elastically grow the rollout group mid-training; new workers join
        the compiled flow's gather loops via the pool version bump."""
        if self._stopped:
            raise RuntimeError("Algorithm is stopped")
        return [a.name for a in self._workers.add_workers(num_workers)]

    def remove_workers(self, num_workers: int = 1) -> List[str]:
        """Elastically shrink the rollout group mid-training."""
        if self._stopped:
            raise RuntimeError("Algorithm is stopped")
        return self._workers.remove_workers(num_workers)

    # -------------------------------------------------------- durability
    def save(self, path: str) -> None:
        """Checkpoint the canonical policy weights plus the flow's resumable
        state (metrics counters, replay-buffer contents + RNG).

        Weights go to ``path`` (.npz, backward compatible); the flow state
        goes to ``path + ".state.pkl"`` so a mid-stream restore resumes
        training with identical counters and replay state (ISSUE 2).

        All state is collected *before* any file is written: a dead replay
        actor raises here (recover() first), never leaving a half-written
        checkpoint that would later restore silently without flow state."""
        import pickle

        from repro.checkpoint import save_pytree

        weights = self._workers.local_worker().get_weights()
        state: Dict[str, Any] = {"counters": self._it.metrics.snapshot_counters()}
        if self._replay is not None:
            try:
                state["replay"] = [a.sync("get_state") for a in self._replay]
            except AttributeError:
                pass  # replay target predates get_state(): counters-only state
        # Rollout-side state (mid-rollout resume): env auto-reset state and
        # per-lane RNG keys for the local worker and every remote worker
        # exposing the get_state protocol (VectorizedRolloutWorker et al).
        lw = self._workers.local_worker()
        if hasattr(lw, "get_state"):
            state["local_worker"] = lw.get_state()
        if hasattr(self._workers, "remote_workers"):
            remote_states: Dict[str, Any] = {}
            for actor in self._workers.remote_workers():
                if not getattr(actor, "alive", True):
                    continue
                try:
                    remote_states[actor.name] = actor.sync("get_state")
                except AttributeError:
                    pass  # worker predates get_state(): weights-only worker
            if remote_states:
                state["remote_workers"] = remote_states
        save_pytree(path, weights)
        with open(path + ".state.pkl", "wb") as f:
            pickle.dump(state, f)

    def restore(self, path: str) -> None:
        """Restore weights into the local worker, broadcast to remotes, and
        (when a state sidecar exists) restore metrics counters and replay
        state so training resumes exactly where ``save()`` left off."""
        import os
        import pickle

        from repro.checkpoint import restore_pytree

        lw = self._workers.local_worker()
        lw.set_weights(restore_pytree(path, lw.get_weights()))
        self._workers.sync_weights()
        sidecar = path + ".state.pkl"
        if not os.path.exists(sidecar):
            return
        with open(sidecar, "rb") as f:
            state = pickle.load(f)
        metrics = self._it.metrics
        metrics.counters.clear()
        metrics.counters.update(state.get("counters", {}))
        replay_states = state.get("replay")
        if replay_states and self._replay is not None:
            if len(replay_states) != len(self._replay):
                raise ValueError(
                    f"checkpoint has {len(replay_states)} replay-actor states "
                    f"but this Algorithm has {len(self._replay)} replay actors; "
                    "restore into a matching topology"
                )
            for actor, rstate in zip(self._replay, replay_states):
                actor.sync("set_state", rstate)
        if "local_worker" in state and hasattr(lw, "set_state"):
            lw.set_state(state["local_worker"])
        remote_states = state.get("remote_workers")
        if remote_states and hasattr(self._workers, "remote_workers"):
            # Matched by actor name (rollout-<index>), so restore works into
            # a fresh WorkerSet of the same topology; extra/missing workers
            # are left as-is (weights were already broadcast above).
            for actor in self._workers.remote_workers():
                rstate = remote_states.get(actor.name)
                if rstate is not None:
                    try:
                        actor.sync("set_state", rstate)
                    except AttributeError:
                        pass

    # ------------------------------------------------------------ shutdown
    def stop(self) -> None:
        """Stop learner threads (joined), then workers and replay actors."""
        if self._stopped:
            return
        self._stopped = True
        self._compiled.stop()
        if self._own_workers:
            self._workers.stop()
            if self._replay is not None:
                self._replay.stop()

    def __enter__(self) -> "Algorithm":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    def __repr__(self) -> str:  # pragma: no cover
        return f"Algorithm({self.spec.name!r}, stopped={self._stopped})"
