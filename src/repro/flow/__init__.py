"""repro.flow: declarative dataflow-graph IR + unified Algorithm runtime.

The three layers (build / optimize+lower / run):

    from repro.flow import FlowSpec, Algorithm, build_apex

    spec = build_apex(workers, replay_actors)     # declarative graph
    print(spec.to_dot())                          # paper Fig 9-12, live
    algo = Algorithm.from_plan(spec, workers, replay_actors)
    result = algo.train()                         # side effects start here
    algo.stop()                                   # ... and end here
"""

from repro.flow.algorithm import Algorithm
from repro.flow.analysis import Diagnostic, FlowAnalysisError, Severity, analyze
from repro.flow.compile import (
    CompiledFlow,
    FlowRuntime,
    compose_stages,
    fuse_for_each,
    partition_flowspec,
)
from repro.flow.explain import ExplainReport, StageCost, explain_flow
from repro.flow.plans import (
    PLAN_BUILDERS,
    REPLAY_PLANS,
    build_a2c,
    build_a3c,
    build_apex,
    build_appo,
    build_dqn,
    build_impala,
    build_maml,
    build_mbpo,
    build_multi_agent_ppo_dqn,
    build_ppo,
    build_ppo_lm,
    build_sac,
)
from repro.flow.spec import (
    FlowSpec,
    HostSpec,
    Node,
    ResourceRef,
    StageSpec,
    Stream,
    pure,
)

__all__ = [
    "Algorithm",
    "CompiledFlow",
    "Diagnostic",
    "ExplainReport",
    "FlowAnalysisError",
    "FlowRuntime",
    "FlowSpec",
    "HostSpec",
    "Node",
    "PLAN_BUILDERS",
    "REPLAY_PLANS",
    "ResourceRef",
    "Severity",
    "StageCost",
    "StageSpec",
    "Stream",
    "analyze",
    "build_a2c",
    "build_a3c",
    "build_apex",
    "build_appo",
    "build_dqn",
    "build_impala",
    "build_maml",
    "build_mbpo",
    "build_multi_agent_ppo_dqn",
    "build_ppo",
    "build_ppo_lm",
    "build_sac",
    "compose_stages",
    "explain_flow",
    "fuse_for_each",
    "partition_flowspec",
    "pure",
]
