"""Lowering: FlowSpec -> LocalIterator/ParallelIterator runtime + passes.

``CompiledFlow`` walks the graph from the output node and maps every node
onto the existing iterator runtime (``repro.core``).  Deferred resources
(learner threads) are instantiated here but *started* only on the first pull
of the compiled iterator, and stopped + joined by ``stop()`` — no side
effects at build or compile time.

Graph-level optimization: ``fuse_for_each`` merges chains of adjacent local
``for_each`` nodes into a single node whose stages compose into one closure
(``compose_stages``).  The composition elides the ``NextValueNotReady``
sentinel check after stages marked pure (``repro.flow.spec.pure`` /
``flow_pure = True``), so an N-stage chain costs one stage dispatch per item
instead of N — ``benchmarks/bench_streaming.py`` measures the win.
"""

from __future__ import annotations

import copy
import logging
import threading
import types
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.concurrency import Concurrently, Dequeue, Enqueue
from repro.core.iterators import (
    LocalIterator,
    NextValueNotReady,
    ParallelIterator,
    from_items,
)
from repro.core.learner_thread import LearnerThread
from repro.core.operators import (
    ParallelRollouts,
    Replay,
    StandardMetricsReporting,
    par_compute_gradients,
)
from repro.flow.analysis.diagnostics import Diagnostic, FlowAnalysisError, Severity
from repro.flow.spec import EdgeRef, FlowSpec, Node, StageSpec, is_pure

__all__ = [
    "CompiledFlow",
    "FlowRuntime",
    "fuse_for_each",
    "compose_stages",
    "partition_flowspec",
]

logger = logging.getLogger(__name__)


# --------------------------------------------------------------------------
# Partitioning pass: per-host dataflow fragments
# --------------------------------------------------------------------------
def partition_flowspec(spec: FlowSpec) -> Dict[Optional[str], List[str]]:
    """Split a FlowSpec into per-host dataflow fragments.

    Returns ``{host_name_or_None: [node_id, ...]}``: every node annotated
    ``host=<name>`` lands in that host's fragment; everything else — the
    driver-side remainder, including all learner/report nodes — lands under
    ``None``.  Undeclared host names still get their own fragment here (the
    ``cross-host-placement`` analysis rule flags them; lowering degrades
    them to the driver), so callers can see exactly what the annotations
    asked for.  Node order within a fragment follows the spec's insertion
    order, which is topological for the fluent builder.
    """
    fragments: Dict[Optional[str], List[str]] = {None: []}
    for name in spec.hosts:
        fragments[name] = []
    for nid, node in spec.nodes.items():
        host = node.annotations.get("host")
        fragments.setdefault(host, []).append(nid)
    return fragments


# --------------------------------------------------------------------------
# Optimization pass: stage fusion
# --------------------------------------------------------------------------
def fuse_for_each(spec: FlowSpec) -> FlowSpec:
    """Fuse adjacent local ``for_each`` nodes into single multi-stage nodes.

    Only local stages are fused: parallel ``for_each`` stages keep their
    per-shard clone semantics from ``ParallelIterator.for_each``.
    """
    while True:
        pair = _find_fusable(spec)
        if pair is None:
            return spec
        spec = _merge_pair(spec, *pair)


def _find_fusable(spec: FlowSpec) -> Optional[tuple]:
    for node in spec.nodes.values():
        if node.kind != "for_each" or node.parallel or len(node.inputs) != 1:
            continue
        pred = spec.nodes[node.inputs[0][0]]
        if pred.kind != "for_each" or pred.parallel:
            continue
        if spec.consumers(pred.id) != 1:
            continue
        return (pred.id, node.id)
    return None


def _merge_pair(spec: FlowSpec, pred_id: str, node_id: str) -> FlowSpec:
    nodes = dict(spec.nodes)
    pred, node = nodes.pop(pred_id), nodes[node_id]
    stages = tuple(pred.params["stages"]) + tuple(node.params["stages"])
    nodes[node_id] = Node(
        id=node.id,
        kind="for_each",
        inputs=pred.inputs,
        params={"stages": stages},
        label=" + ".join(s.label for s in stages),
        parallel=False,
        num_outputs=1,
        annotations={**pred.annotations, **node.annotations},
    )
    return spec.replace_nodes(nodes)


def compose_stages(fns: Sequence[Callable]) -> Callable:
    """Whole-stage codegen: compose stage callables into one flat function.

    Generates a single function body with one direct call per stage — no
    dispatch loop, no extra call frames — and a ``NextValueNotReady``
    sentinel check only after stages that may emit it (anything not marked
    pure).  The same trick streaming/SQL engines use for operator fusion.
    """
    if len(fns) == 1:
        return fns[0]
    ns: Dict[str, Any] = {f"_f{i}": fn for i, fn in enumerate(fns)}
    ns["_NotReady"] = NextValueNotReady
    lines = ["def _fused(item):"]
    for i, fn in enumerate(fns):
        lines.append(f"    item = _f{i}(item)")
        if not is_pure(fn) and i < len(fns) - 1:
            lines.append("    if isinstance(item, _NotReady): return item")
    lines.append("    return item")
    exec("\n".join(lines), ns)  # noqa: S102 - compile-time codegen, no user input
    fused = ns["_fused"]
    fused.__name__ = f"fused[{len(fns)}]"
    fused.flow_pure = all(is_pure(f) for f in fns)
    return fused


# --------------------------------------------------------------------------
# Runtime: deferred resources
# --------------------------------------------------------------------------
class FlowRuntime:
    """Owns the compiled flow's deferred resources.

    Resources are built (never started) at construction; ``ensure_started``
    is invoked by the output iterator on its first pull; ``stop`` flags all
    resources and joins their threads so none outlive the flow.
    """

    def __init__(self, spec: FlowSpec):
        self.spec = spec
        self.resources: Dict[str, Any] = {}
        self._lock = threading.Lock()
        self._started = False
        self._stopped = False
        for res in spec.resources.values():
            if res.kind == "learner_thread":
                params = dict(res.params)
                workers = params.pop("workers")
                self.resources[res.name] = LearnerThread(workers.local_worker(), **params)
            else:
                raise ValueError(f"unknown resource kind {res.kind!r}")

    def resource(self, name: str) -> Any:
        return self.resources[name]

    @property
    def started(self) -> bool:
        return self._started

    def ensure_started(self, metrics: Any = None) -> None:
        with self._lock:
            if self._started or self._stopped:
                return
            for r in self.resources.values():
                # Hand resources the flow's shared metrics context before
                # they run: the learner thread records sample->learn /
                # queue-wait latencies and queue occupancy into it.
                if metrics is not None and hasattr(r, "metrics"):
                    r.metrics = metrics
                r.start()
            self._started = True

    def stop(self) -> None:
        with self._lock:
            self._stopped = True
            for r in self.resources.values():
                r.stop()
            for r in self.resources.values():
                if r.ident is not None:
                    r.join(timeout=5.0)
            # Drain learner in-queues so producers blocked on a full
            # blocking Enqueue wake up and can observe flow teardown.
            for r in self.resources.values():
                q = getattr(r, "inqueue", None)
                while q is not None:
                    try:
                        q.get_nowait()
                    except Exception:
                        break


# --------------------------------------------------------------------------
# Lowering
# --------------------------------------------------------------------------
class CompiledFlow:
    """A FlowSpec lowered onto the iterator runtime, ready to run.

    Lowering fallbacks (annotations that cannot apply, degraded inference)
    surface as structured ``Diagnostic`` objects on ``self.diagnostics`` —
    the same vocabulary ``FlowSpec.check()`` uses statically.  With
    ``strict=True`` the static pass runs first (raising ``FlowAnalysisError``
    before any resource is built) and any error-severity diagnostic emitted
    during lowering also raises, after tearing the partial flow back down.
    """

    def __init__(self, spec: FlowSpec, fuse: bool = True, strict: bool = False):
        spec.validate()
        if strict:
            from repro.flow.analysis.engine import analyze

            static = analyze(spec)
            if any(d.is_error for d in static):
                raise FlowAnalysisError(static, flow=spec.name)
        self.source_spec = spec
        self.spec = fuse_for_each(spec) if fuse else spec
        self.diagnostics: List[Diagnostic] = []
        self._diag_logged: set = set()
        self.runtime = FlowRuntime(self.spec)
        self._cache: Dict[str, Any] = {}
        self._annotated_policies: Dict[int, str] = {}
        self._inference_actors: List[Any] = []
        self._weight_sink_regs: List[Any] = []  # (workers, sink) to undo on stop
        # node id -> {"router": InferenceRouter, "gate": CreditGate} for every
        # served source node: the serving-tier handle explain()/tests reach.
        self._inference_meta: Dict[str, Dict[str, Any]] = {}
        # Multi-host fragments: host name -> owned LocalHostHandle (only for
        # driver-managed hosts this compile launched), host name -> the
        # RemoteBackend its actors were rehomed onto (None = launch failed,
        # don't retry per node), and (actor, original backend) pairs so
        # stop() can return a *shared* WorkerSet's actors to their local
        # backend before the flow tears its hosts down.
        self.fragments = partition_flowspec(self.spec)
        self.host_handles: Dict[str, Any] = {}
        self._host_backends: Dict[str, Any] = {}
        self._placed_actors: Dict[int, str] = {}
        self._rehomed: List[Any] = []  # (actor, original ExecutionBackend)
        assert self.spec.output is not None  # validate() guarantees it
        inner = self._lower_ref(self.spec.output)
        # Serving metrics flow into train() results via MetricsContext
        # probes: each router publishes occupancy / admission latency /
        # credit stalls under inference/<node-id>/ at every save().
        for nid, meta in self._inference_meta.items():
            register = getattr(inner.metrics, "register_probe", None)
            if register is not None:
                register(meta["router"].metrics_probe(nid))
        self._out = self._deferred_start_wrapper(inner)
        if strict and any(d.is_error for d in self.diagnostics):
            self.stop()
            raise FlowAnalysisError(self.diagnostics, flow=spec.name)

    # ------------------------------------------------------------- running
    def iterator(self) -> LocalIterator:
        """The result stream; first pull starts deferred resources."""
        return self._out

    def __iter__(self):
        return iter(self._out)

    def take(self, n: int) -> List[Any]:
        return self._out.take(n)

    def stop(self) -> None:
        """Stop and join all deferred resources, then close the lowered
        iterators so stream teardown (joining Concurrently/union driver
        threads) happens now rather than at GC time (idempotent)."""
        self.runtime.stop()
        # Unhook this flow's weight sinks BEFORE stopping the actors they
        # feed: a shared WorkerSet outlives the flow, and a sink bound to a
        # stopped InferenceActor would fail on every later broadcast.
        for workers, sink in self._weight_sink_regs:
            try:
                workers.remove_weight_sink(sink)
            except Exception:  # pragma: no cover - teardown is best-effort
                pass
        self._weight_sink_regs = []
        for a in self._inference_actors:
            try:
                a.stop()
            except Exception:  # pragma: no cover - teardown is best-effort
                pass
        try:
            self._out.close()
        except Exception:  # pragma: no cover - teardown is best-effort
            pass
        for obj in self._cache.values():
            for it in obj if isinstance(obj, list) else [obj]:
                if isinstance(it, LocalIterator):
                    try:
                        it.close()
                    except Exception:  # pragma: no cover
                        pass
        # Return rehomed actors to their original (local) backend before the
        # flow kills the hosts it launched: a shared WorkerSet outlives the
        # flow, and its actors must not be left pointing at a dead host.
        # Actors already dead (e.g. a chaos machine-loss kill) are skipped —
        # WorkerSet.recover() replaces them on their original backend.
        for actor, backend in self._rehomed:
            try:
                if getattr(actor, "alive", False):
                    actor.rehome(backend, timeout=30.0)
            except Exception:  # pragma: no cover - teardown is best-effort
                pass
        self._rehomed = []
        for handle in self.host_handles.values():
            try:
                handle.stop()
            except Exception:  # pragma: no cover - teardown is best-effort
                pass
        self.host_handles = {}

    def to_dot(self) -> str:
        return self.spec.to_dot()

    # ------------------------------------------------------------ internal
    def _deferred_start_wrapper(self, inner: LocalIterator) -> LocalIterator:
        runtime = self.runtime

        def _base():
            runtime.ensure_started(metrics=inner.metrics)
            yield from iter(inner)

        return LocalIterator(_base, metrics=inner.metrics, name=self.spec.name)

    def _diag(
        self,
        severity: str,
        message: str,
        node: Optional[str] = None,
        hint: Optional[str] = None,
    ) -> Diagnostic:
        """Record a lowering diagnostic (rule ``lowering-fallback``).

        The one dedup path for every fallback site: each distinct
        (node, message) logs once per compile — previously each site
        hand-rolled its own warn-once flag or per-actor dict.
        """
        d = Diagnostic(
            rule="lowering-fallback", severity=severity, message=message,
            node=node, hint=hint,
        )
        self.diagnostics.append(d)
        key = (node, message)
        if key not in self._diag_logged:
            self._diag_logged.add(key)
            log = logger.error if d.is_error else logger.warning
            log("flow %s: %s", self.spec.name, d.format())
        return d

    def _lower_ref(self, ref: EdgeRef) -> Any:
        nid, port = ref
        obj = self._lower(nid)
        return obj[port] if isinstance(obj, list) else obj

    def _lower(self, nid: str) -> Any:
        if nid in self._cache:
            return self._cache[nid]
        node = self.spec.nodes[nid]
        out = self._lower_node(node)
        self._cache[nid] = out
        return out

    def _host_backend(self, host: str, node: Node) -> Any:
        """Resolve (and memoize) the RemoteBackend for a declared host.

        A driver-managed host (``HostSpec.address is None``) is launched
        here via ``start_local_host`` and owned by this flow — ``stop()``
        tears it down.  An external host (``"host:port"``) is only
        connected to; its lifetime is the operator's problem.  A launch or
        connect failure degrades that host's fragment to the driver (one
        error diagnostic, memoized so each host fails at most once).
        """
        if host in self._host_backends:
            return self._host_backends[host]
        from repro.core.remote import RemoteBackend, start_local_host

        hspec = self.spec.hosts[host]
        backend: Any = None
        try:
            if hspec.address is None:
                handle = start_local_host()
                self.host_handles[host] = handle
                address: Any = handle.address
            else:
                address = hspec.address
            backend = RemoteBackend(address=address)
        except Exception as exc:
            self._diag(
                Severity.ERROR,
                f"failed to launch/connect host {host!r}: {exc!r}; its "
                "fragment stays on the driver's local backend",
                node=node.id,
                hint="check the host address, or use a driver-managed host "
                "(declare_host with no address)",
            )
        self._host_backends[host] = backend
        return backend

    def _lower_host(self, node: Node, actors: Any) -> None:
        """Lower a source node's ``host=`` placement annotation.

        This is the cross-host lowering step: the graph says *where* a
        fragment runs declaratively; here each of the node's pool actors is
        rehomed onto the host's ``RemoteBackend``, so its target lives in
        the host process and every edge to the driver crosses the socket
        transport.  Placement is per-actor (like ``failure_policy``): a pool
        shared by nodes annotated with different hosts keeps the first
        placement and warns, rather than bouncing actors between hosts.
        """
        host = node.annotations.get("host")
        if host is None:
            return
        if host not in self.spec.hosts:
            self._diag(
                Severity.ERROR,
                f"host={host!r} is not declared on this spec; the node "
                "stays on the driver's local backend",
                node=node.id,
                hint=f"call spec.declare_host({host!r}) before building the node",
            )
            return
        backend = self._host_backend(host, node)
        if backend is None:
            return
        stranded: List[str] = []
        for a in actors:
            placed = self._placed_actors.get(id(a))
            if placed == host:
                continue
            if placed is not None:
                self._diag(
                    Severity.WARN,
                    f"actor {getattr(a, 'name', repr(a))} is already placed "
                    f"on host {placed!r}; host={host!r} on this node is "
                    "ignored (placement is per-actor, first lowered node "
                    "wins)",
                    node=node.id,
                    hint="annotate the pool's nodes with one host",
                )
                continue
            try:
                original = a._backend  # rehome() swaps this; keep for stop()
                a.rehome(backend, timeout=60.0)
            except Exception as exc:
                stranded.append(f"{getattr(a, 'name', repr(a))} ({exc!r})")
                continue
            self._placed_actors[id(a)] = host
            self._rehomed.append((a, original))
        if stranded:
            self._diag(
                Severity.ERROR,
                f"could not rehome onto host {host!r}: {', '.join(stranded)}; "
                "those shards stay on the driver's local backend",
                node=node.id,
                hint="actors need a picklable factory (WorkerSet.create / "
                "VirtualActor(factory=...)) to cross a host boundary",
            )

    def _lower_annotations(self, node: Node, actors: Any) -> None:
        """Apply a node's failure annotations to its source actors.

        This is the lowering step for fault tolerance: the graph carries the
        policy declaratively; the chosen backend's actors enforce it (gather
        loops read ``actor.failure_policy``).  The policy is a property of
        the *actor*, so two nodes annotating the same pool differently is a
        conflict (last writer wins) — flagged loudly.
        """
        policy = node.annotations.get("failure_policy")
        if policy is None:
            return
        from repro.core.executor import FailurePolicy

        FailurePolicy.validate(policy)
        overridden: List[str] = []
        prior_policy: Optional[str] = None
        for a in actors:
            prior = self._annotated_policies.get(id(a))
            if prior is not None and prior != policy:
                overridden.append(getattr(a, "name", repr(a)))
                prior_policy = prior
            self._annotated_policies[id(a)] = policy
            a.failure_policy = policy
        if overridden:
            self._diag(
                Severity.WARN,
                f"failure_policy={policy!r} overrides {prior_policy!r} set "
                f"by another node of this flow on {', '.join(overridden)}; "
                "the policy is per-actor, and the last lowered node wins "
                "for every stream sharing the pool",
                node=node.id,
                hint="annotate the pool's nodes consistently",
            )

    def _lower_learner_annotations(self, node: Node, fns: Sequence[Callable]) -> None:
        """Lower ``learners(n)``/``microbatch(k)`` onto the node's train stages.

        The graph carries the SPMD execution mapping declaratively (the
        paper's dataflow/numerics split); at lowering time any instantiated
        stage exposing the learner-group knobs — ``TrainOneStep`` — gets
        them set so its update runs on a sharded learner group.  Stage
        fusion merges annotations node-wise, so the knobs survive
        ``fuse_for_each``.
        """
        n = node.annotations.get("num_learners")
        k = node.annotations.get("microbatch")
        if n is None and k is None:
            return
        hit = False
        for fn in fns:
            if hasattr(fn, "num_learners") and hasattr(fn, "microbatch"):
                if n is not None:
                    fn.num_learners = int(n)
                if k is not None:
                    fn.microbatch = int(k)
                hit = True
        if not hit:
            self._diag(
                Severity.ERROR,
                "learners/microbatch annotations but none of the node's "
                "stages accept them (expected a TrainOneStep-like operator); "
                "training stays single-device",
                node=node.id,
                hint="attach the annotation to the TrainOneStep stage's node",
            )

    def _lower_inference(self, node: Node, workers: Any) -> Optional[List[Any]]:
        """Build the decoupled-inference serving tier for a source node.

        ``inference='server'`` lowers to ``inference_replicas`` (default 1)
        ``InferenceActor`` replicas — each a ``VirtualActor`` with a restart
        budget, so the chaos/FailurePolicy path can heal them — behind one
        ``InferenceRouter`` shared by the node's rollout shards (the router
        satisfies the client API; the node's ``failure_policy`` doubles as
        the replica-loss policy).  ``inference_routing`` picks dispatch:
        ``'auto'`` probes the served policy for statefulness, else
        ``'least_loaded'``/``'sticky'`` force it.  The router serves the
        local worker's policy and is registered as a weight sink on the
        WorkerSet, so every ``sync_weights`` broadcast bumps the weight
        version on every replica.  Owned by this CompiledFlow: ``stop()``
        stops the replicas.
        """
        if node.annotations.get("inference") != "server":
            return None
        from repro.core.actor import VirtualActor
        from repro.rl.inference import CreditGate, InferenceActor, InferenceRouter

        lw = workers.local_worker()
        policy = getattr(lw, "policy", None)
        if policy is None:
            self._diag(
                Severity.ERROR,
                "inference='server' but the local worker has no .policy to "
                "serve; falling back to local inference",
                node=node.id,
                hint="use a worker type exposing .policy, or drop "
                "inference='server'",
            )
            return None
        num_shards = max(1, len(workers.remote_workers()))
        credits = node.annotations.get("inference_credits") or 2 * num_shards
        replicas_n = int(node.annotations.get("inference_replicas") or 1)
        routing = node.annotations.get("inference_routing", "auto")
        failure_policy = node.annotations.get("failure_policy")
        if failure_policy not in ("restart", "drop_shard"):
            failure_policy = "restart"
        actors = [
            VirtualActor(
                factory=lambda: InferenceActor(
                    lambda: policy,
                    algo=getattr(lw, "algo", "pg"),
                    epsilon=getattr(lw, "epsilon", 0.0),
                ),
                name=(
                    f"inference-{node.id}"
                    if replicas_n == 1
                    else f"inference-{node.id}-r{i}"
                ),
                max_restarts=1,
                backoff_base=0.0,
            )
            for i in range(replicas_n)
        ]
        gate = CreditGate(int(credits))
        router = InferenceRouter(
            actors,
            credits=gate,
            weights_provider=lw.get_weights,
            sticky=None if routing == "auto" else routing == "sticky",
            failure_policy=failure_policy,
            name=f"inference-router-{node.id}",
        )
        router.sync_weights()  # serve canonical weights from the start
        if hasattr(workers, "add_weight_sink"):
            workers.add_weight_sink(router.sync_weights)
            self._weight_sink_regs.append((workers, router.sync_weights))
        self._inference_actors.extend(actors)
        self._inference_meta[node.id] = {"router": router, "gate": gate}
        # One router shared by every shard: dispatch and health are global.
        return [router] * num_shards

    def _lower_node(self, node: Node) -> Any:
        k, p = node.kind, node.params
        if k == "rollouts":
            self._lower_host(node, p["workers"].remote_workers())
            self._lower_annotations(node, p["workers"].remote_workers())
            return ParallelRollouts(
                p["workers"],
                mode=p["mode"],
                num_async=p["num_async"],
                credits=node.annotations.get("credits", p.get("credits")),
                metrics_key=node.id,
                vector=node.annotations.get("vector"),
                inference=node.annotations.get("inference"),
                inference_clients=self._lower_inference(node, p["workers"]),
                decode=node.annotations.get("decode"),
            )
        if k == "replay":
            self._lower_host(node, p["actors"])
            self._lower_annotations(node, p["actors"])
            return Replay(
                p["actors"],
                num_async=p["num_async"],
                credits=node.annotations.get("credits", p.get("credits")),
                metrics_key=node.id,
            )
        if k == "par_gradients":
            self._lower_host(node, p["workers"].remote_workers())
            self._lower_annotations(node, p["workers"].remote_workers())
            return par_compute_gradients(
                p["workers"],
                vector=node.annotations.get("vector"),
                inference=node.annotations.get("inference"),
                inference_clients=self._lower_inference(node, p["workers"]),
                decode=node.annotations.get("decode"),
            )
        if k == "par_source":
            self._lower_host(node, p["pool"])
            self._lower_annotations(node, p["pool"])
            return ParallelIterator.from_actors(p["pool"], p["pull_fn"], name=node.label)
        if k == "from_items":
            return from_items(p["items"], repeat=p["repeat"])
        if k == "dequeue":
            res = self.runtime.resource(p["resource"])
            return Dequeue(res.outqueue, check=res.is_alive, metrics_key=node.id)

        up = self._lower_ref(node.inputs[0]) if node.inputs else None
        if k == "for_each":
            if isinstance(up, ParallelIterator):
                if "num_learners" in node.annotations or "microbatch" in node.annotations:
                    self._diag(
                        Severity.ERROR,
                        "learners/microbatch annotations on a *parallel* "
                        "for_each; the learner group lowers only onto local "
                        "train stages, so the annotations are ignored",
                        node=node.id,
                        hint="sequence the stream first "
                        "(gather_sync/gather_async/batch_across_shards)",
                    )
                # Parallel stages keep ParallelIterator's own per-shard
                # cloning; apply each stage separately, uninstantiated.
                for stage in p["stages"]:
                    fn = stage.fn(self.runtime) if stage.ctx else stage.fn
                    up = up.for_each(fn)
                return up
            fns = [self._instantiate(s) for s in p["stages"]]
            self._lower_learner_annotations(node, fns)
            return up.for_each(compose_stages(fns))
        if k == "filter":
            return up.filter(p["predicate"])
        if k == "zip_source_actor":
            return up.zip_with_source_actor()
        if k == "gather_async":
            # Backpressure lowering: an explicit credits= param or a
            # credits annotation bounds the in-flight window (ISSUE 3).
            credits = node.annotations.get("credits", p.get("credits"))
            return up.gather_async(
                num_async=p["num_async"], credits=credits, metrics_key=node.id
            )
        if k == "gather_sync":
            return up.gather_sync(metrics_key=node.id)
        if k == "batch_across_shards":
            return up.batch_across_shards(metrics_key=node.id)
        if k == "enqueue":
            res = self.runtime.resource(p["resource"])
            # Overflow-policy lowering: annotation > explicit policy param >
            # legacy block flag.  check=is_alive: a blocking feed must not
            # wedge its driver thread once the learner is gone (teardown/
            # crash) — it raises and the Concurrently driver unwinds instead.
            policy = node.annotations.get("overflow_policy", p.get("policy"))
            if policy is None:
                policy = "block" if p["block"] else "drop_newest"
            return up.for_each(
                Enqueue(
                    res.inqueue,
                    policy=policy,
                    check=res.is_alive,
                    metrics_key=node.id,
                )
            )
        if k == "concurrently":
            ops = [self._lower_ref(r) for r in node.inputs]
            return Concurrently(
                ops,
                mode=p["mode"],
                output_indexes=p["output_indexes"],
                round_robin_weights=p["round_robin_weights"],
            )
        if k == "duplicate":
            return up.duplicate(p["n"])
        if k == "report":
            return StandardMetricsReporting(up, p["workers"], report_interval=p["interval"])
        raise ValueError(f"unknown node kind {k!r}")

    def _instantiate(self, stage: StageSpec) -> Callable:
        """Materialize a stage callable for this compile.

        Context factories see the runtime; stateful operator instances are
        deep-copied when possible so recompiling the same spec yields fresh
        operator state (operators holding live actor handles fall back to
        the shared instance, matching ``ParallelIterator.for_each``).
        """
        if stage.ctx:
            return stage.fn(self.runtime)
        fn = stage.fn
        if not isinstance(fn, types.FunctionType) and not isinstance(fn, type):
            try:
                fn = copy.deepcopy(fn)
            except Exception:
                fn = stage.fn
        # Warn-once latches are per-*compile* state: whether the instance was
        # deep-copied (copies the set latch along) or fell back to the shared
        # original (same latch object across Algorithms), re-arm it so every
        # compiled flow emits its own fallback warnings exactly once.
        reset = getattr(fn, "reset_warnings", None)
        if callable(reset):
            reset()
        return fn

    def __repr__(self) -> str:  # pragma: no cover
        return f"CompiledFlow({self.spec.name!r}, nodes={len(self.spec.nodes)})"
