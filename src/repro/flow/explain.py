"""``Algorithm.explain()``: roofline-driven per-stage cost attribution.

Each FlowSpec node of a compiled flow is attributed three cost sources:

  * **static** — the node's jitted stage program is lowered (not run), its
    optimized HLO fed through the trip-count-aware cost model
    (``repro.distributed.hlo_cost.analyze_hlo``) and the roofline terms
    (``repro.distributed.hlo_analysis.roofline``): FLOPs, HBM bytes,
    collective bytes, and the dominant bottleneck at the target hardware's
    peak rates.  Today two node kinds carry a jitted program: ``rollouts``
    (the local worker's scanned env+policy step) and any ``for_each`` node
    containing a ``TrainOneStep`` stage (the worker's fused SGD step).
  * **live** — the shared ``MetricsContext`` joined by node id: wall time
    from the canonical operator timers (``sample`` / ``learn``), data-plane
    bytes moved out of the node (``bytes_moved/<node-id>`` counters, keyed
    by *fused* node id at lowering time — the same ids this report uses),
    and current queue occupancy for enqueue/dequeue nodes.
  * **verdict** — a stage whose roofline is memory-bound is flagged as a
    *kernel candidate*: its arithmetic intensity is below the hardware
    ridge, so fusing its element-wise chain into one Pallas pass over the
    batch panel (the ``kernels/`` recipe, see ``docs/kernels.md``) converts
    HBM round-trips into on-chip VMEM traffic.

The probe is effectively side-effect free: lowering compiles but never
executes the programs, and the learn-stage probe batch is drawn via a
``get_state``/``sample``/``set_state`` snapshot-restore so worker RNG and
env state are unchanged.  Stages that cannot be lowered (no jitted program,
exotic worker) degrade to metrics-only rows with a ``note`` — the report
never raises because one stage is opaque.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

from repro.core.metrics import (
    BYTES_MOVED_PREFIX,
    GATHER_TIMER_PREFIX,
    LEARN_ON_BATCH_TIMER,
    QUEUE_OCCUPANCY_PREFIX,
    SAMPLE_TIMER,
    MetricsContext,
)
from repro.distributed.hlo_analysis import HW_V5E, Hardware, collective_bytes, roofline
from repro.distributed.hlo_cost import analyze_hlo

__all__ = ["StageCost", "ExplainReport", "explain_flow"]


@dataclasses.dataclass
class StageCost:
    """One FlowSpec node's attributed cost (static + live + verdict)."""

    node_id: str
    label: str
    kind: str
    # Static (lowered-HLO) terms; zero when the node carries no jitted program.
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    # Live metrics joined by node id / canonical timer.
    wall_s_total: float = 0.0
    wall_s_mean: float = 0.0
    calls: int = 0
    bytes_moved: int = 0
    queue_occupancy: Optional[float] = None
    # Serving-tier join (ISSUE 9): populated for source nodes running
    # inference='server' — CreditGate contention on the request path plus
    # the router's continuous-batching occupancy/admission-latency gauges
    # (published under ``inference/<node-id>/`` by the router probe).
    credit_stalls: int = 0
    credit_stall_time_s: float = 0.0
    serve_replicas: Optional[float] = None
    serve_occupancy_mean: Optional[float] = None
    serve_admission_p99_s: Optional[float] = None
    # Verdict.
    kernel_candidate: bool = False
    note: str = ""

    def row(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ExplainReport:
    """Per-stage cost rows plus the hardware model they were priced against."""

    plan: str
    hw: Hardware
    rows: List[StageCost]

    def kernel_candidates(self) -> List[StageCost]:
        return [r for r in self.rows if r.kernel_candidate]

    def to_json(self) -> str:
        doc = {
            "plan": self.plan,
            "hw": self.hw.name,
            "stages": [r.row() for r in self.rows],
            "kernel_candidates": [r.node_id for r in self.kernel_candidates()],
        }
        return json.dumps(doc, indent=2, sort_keys=True)

    def table(self) -> str:
        hdr = (
            "| node | kind | flops | hbm_bytes | dominant | wall_mean_s | "
            "calls | bytes_moved | kernel? |\n|---|---|---|---|---|---|---|---|---|"
        )
        lines = [hdr]
        for r in self.rows:
            lines.append(
                "| {id} | {kind} | {f} | {b} | {dom} | {w} | {c} | {mv} | {k} |".format(
                    id=r.node_id,
                    kind=r.kind,
                    f=f"{r.flops:.2e}" if r.flops else "-",
                    b=f"{r.hbm_bytes:.2e}" if r.hbm_bytes else "-",
                    dom=r.dominant or "-",
                    w=f"{r.wall_s_mean:.2e}" if r.calls else "-",
                    c=r.calls or "-",
                    mv=r.bytes_moved or "-",
                    k="yes" if r.kernel_candidate else "",
                )
            )
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.table()


def _is_train_stage(stage: Any) -> bool:
    fn = getattr(stage, "fn", None)
    return type(fn).__name__ == "TrainOneStep" or "TrainOneStep" in getattr(
        stage, "label", ""
    )


def _has_train_stage(node: Any) -> bool:
    return node.kind == "for_each" and any(
        _is_train_stage(s) for s in node.params.get("stages", ())
    )


def _lower_rollout_hlo(workers: Any) -> str:
    """Optimized HLO of the local worker's jitted rollout program."""
    import jax

    lw = workers.local_worker()
    key = jax.random.PRNGKey(0)
    lowered = lw._rollout_jit.lower(lw.params, lw.env_state, lw.obs, lw._ep_returns, key)
    return str(lowered.compile().as_text())


def _lower_learn_hlo(workers: Any) -> str:
    """Optimized HLO of the local worker's jitted learn step.

    The probe batch comes from one ``sample()`` under a state
    snapshot/restore, so the worker's env state and RNG are untouched; only
    the batch *shape* matters to the lowering (the per-call program a
    TrainOneStep minibatch runs), never its values.
    """
    import jax

    lw = workers.local_worker()
    snapshot = lw.get_state() if hasattr(lw, "get_state") else None
    try:
        batch = lw.sample()
    finally:
        if snapshot is not None:
            lw.set_state(snapshot)
    device_batch = lw._device_batch(batch)
    key = jax.random.PRNGKey(0)
    lowered = lw._learn_jit.lower(
        lw.params, lw.target_params, lw.opt_state, device_batch, key
    )
    return str(lowered.compile().as_text())


def _attribute_static(row: StageCost, hlo: str, hw: Hardware) -> None:
    cost = analyze_hlo(hlo)
    coll = collective_bytes(hlo)
    rl = roofline(
        arch="stage",
        shape=row.node_id,
        mesh_name="local",
        chips=1,
        cost={"flops": cost.flops, "bytes accessed": cost.hbm_bytes},
        coll=coll,
        model_flops=cost.flops,
        hw=hw,
    )
    row.flops = rl.hlo_flops
    row.hbm_bytes = rl.hlo_bytes
    row.coll_bytes = rl.coll_bytes
    row.compute_s = rl.compute_s
    row.memory_s = rl.memory_s
    row.collective_s = rl.collective_s
    row.dominant = rl.dominant
    row.kernel_candidate = rl.dominant == "memory"


def explain_flow(
    compiled: Any,
    workers: Any,
    metrics: MetricsContext,
    hw: Hardware = HW_V5E,
) -> ExplainReport:
    """Build the per-stage cost report for one compiled flow.

    ``compiled`` is a ``CompiledFlow`` (its *fused* spec's node ids are the
    keys the data-plane metrics were recorded under); ``metrics`` is the
    live ``MetricsContext`` of the algorithm's iterator — run a few
    ``train()`` steps first if you want the wall-time columns populated.
    """
    # Pull-based publishers (the serving tier's router probes) only write on
    # save(); run them so the join below sees current serving gauges even if
    # no train() result was pulled since the last request.
    getattr(metrics, "run_probes", lambda: None)()
    spec = compiled.spec
    rows: List[StageCost] = []
    for node in spec.nodes.values():
        if node.kind == "for_each":
            label = " | ".join(s.label for s in node.params.get("stages", ()))
        else:
            label = node.label
        row = StageCost(node_id=node.id, label=label, kind=node.kind)

        # Live join (always available, even when lowering fails).
        moved = metrics.counters.get(BYTES_MOVED_PREFIX + node.id)
        if moved:
            row.bytes_moved = int(moved)
        occ = metrics.gauges.get(QUEUE_OCCUPANCY_PREFIX + node.id)
        if occ is not None:
            row.queue_occupancy = float(occ)
        # Serving-tier join: the router probe publishes under
        # inference/<node-id>/ (see InferenceRouter.metrics_probe).
        serve = f"inference/{node.id}/"
        row.credit_stalls = int(metrics.counters.get(serve + "credit_stalls", 0))
        row.credit_stall_time_s = float(
            metrics.gauges.get(serve + "credit_stall_time_s", 0.0)
        )
        reps = metrics.gauges.get(serve + "replicas")
        if reps is not None:
            row.serve_replicas = float(reps)
            row.serve_occupancy_mean = metrics.gauges.get(serve + "occupancy_mean")
            row.serve_admission_p99_s = metrics.gauges.get(
                serve + "admission_wait_p99_s"
            )
        # Wall-time join, most specific key first: the per-node gather timer
        # (recorded by gather_sync under this node's id), then the canonical
        # operator timers (``sample`` from the low-level ports, ``learn``
        # from TrainOneStep).
        timer_keys: List[str] = [GATHER_TIMER_PREFIX + node.id]
        if node.kind == "rollouts":
            timer_keys.append(SAMPLE_TIMER)
        elif _has_train_stage(node):
            timer_keys = [LEARN_ON_BATCH_TIMER]
        for timer_key in timer_keys:
            if timer_key in metrics.timers:
                t = metrics.timers[timer_key]
                row.wall_s_total = t.total
                row.wall_s_mean = t.mean
                row.calls = t.count
                break

        # Static attribution for nodes carrying a jitted program.
        try:
            if node.kind == "rollouts":
                _attribute_static(row, _lower_rollout_hlo(workers), hw)
            elif _has_train_stage(node):
                _attribute_static(row, _lower_learn_hlo(workers), hw)
        except Exception as exc:  # degrade, never fail the whole report
            row.note = f"static cost unavailable: {exc!r}"
        rows.append(row)
    return ExplainReport(plan=spec.name, hw=hw, rows=rows)
