"""FlowSpec: a declarative dataflow-graph IR for RL execution plans.

The paper argues RL algorithms *are* dataflow graphs (§2), yet the eager
plan functions in ``repro.core.plans`` only materialize that graph implicitly
inside chained iterators: the topology is gone by the time the plan returns,
and side effects (learner-thread start) fire at build time.  ``FlowSpec``
makes the graph a first-class value, following MSRL's split between the
algorithm's *fragmented dataflow graph* and its execution mapping:

  * **build**    — plan builders assemble a ``FlowSpec``: typed operator
    nodes (sources, transformations, sequencing, concurrency) connected by
    stream edges, plus *deferred resources* (learner threads) that are only
    instantiated/started at run time.
  * **optimize** — graph passes rewrite the spec (``repro.flow.compile``
    fuses adjacent ``for_each`` stages into one stage closure).
  * **lower**    — ``spec.compile()`` maps nodes onto the existing
    ``LocalIterator``/``ParallelIterator``/``Concurrently`` runtime.
  * **run**      — pulling from the compiled iterator drives the graph;
    resources start lazily on the first pull and stop with the flow.

``to_dot()`` renders the graph in Graphviz DOT — the paper's Figures 9–12
reproduced from live plans instead of hand-drawn.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

__all__ = ["FlowSpec", "Stream", "Node", "StageSpec", "ResourceRef", "HostSpec", "pure"]

# Edge endpoint: (producer node id, output port).  Port > 0 only for
# multi-output nodes (duplicate).
EdgeRef = Tuple[str, int]


def pure(fn: Callable) -> Callable:
    """Mark a callable as never returning ``NextValueNotReady``.

    The stage-fusion pass elides the sentinel check after pure stages when
    composing a fused chain; unmarked callables keep the check (safe default).
    """
    fn.flow_pure = True  # type: ignore[attr-defined]
    return fn


def is_pure(fn: Callable) -> bool:
    return bool(getattr(fn, "flow_pure", False))


@dataclass(frozen=True)
class StageSpec:
    """One transformation inside a ``for_each`` node.

    ``ctx=True`` means ``fn`` is a factory ``fn(runtime) -> callable`` run at
    compile time — the hook for stages that need a deferred resource (e.g.
    IMPALA's broadcast gate reading the learner thread's dirty bit).
    """

    fn: Callable
    label: str
    ctx: bool = False


@dataclass(frozen=True)
class Node:
    id: str
    kind: str
    inputs: Tuple[EdgeRef, ...]
    params: Dict[str, Any]
    label: str
    parallel: bool  # True -> output stream is a ParallelIterator
    num_outputs: int = 1
    # Resource/failure annotations (executor runtime): e.g.
    # {"failure_policy": "drop_shard", "resources": {"num_cpus": 1}}.
    # ``compile()`` lowers failure policies onto the node's source actors.
    annotations: Dict[str, Any] = field(default_factory=dict)


@dataclass(frozen=True)
class HostSpec:
    """A placement target for dataflow fragments (MSRL: one fragment per
    host, same IR, different placement).

    ``address=None`` means *driver-managed*: ``compile()`` launches a local
    ``RemoteHost`` process on this box and owns its lifecycle (the localhost
    two-fragment test topology).  A concrete ``"host:port"`` address points
    at an externally-run host on another machine — the driver only connects.
    """

    name: str
    address: Optional[str] = None


@dataclass(frozen=True)
class ResourceSpec:
    """A deferred side-effectful runtime object (today: learner threads).

    Declared in the graph, instantiated at compile time, *started* only when
    the flow is first pulled, stopped and joined on ``stop()``.
    """

    name: str
    kind: str
    params: Dict[str, Any] = field(default_factory=dict)


class ResourceRef:
    """Builder-side handle to a declared resource."""

    def __init__(self, spec: "FlowSpec", name: str):
        self.spec = spec
        self.name = name

    def __repr__(self) -> str:  # pragma: no cover
        return f"ResourceRef({self.name})"


def _fn_label(fn: Any) -> str:
    return getattr(fn, "__name__", type(fn).__name__)


class Stream:
    """A builder handle to one output edge of a node (fluent API)."""

    def __init__(self, spec: "FlowSpec", node_id: str, port: int = 0, parallel: bool = False):
        self.spec = spec
        self.node_id = node_id
        self.port = port
        self.parallel = parallel

    @property
    def ref(self) -> EdgeRef:
        return (self.node_id, self.port)

    @property
    def node(self) -> "Node":
        return self.spec.nodes[self.node_id]

    def annotate(self, **annotations: Any) -> "Stream":
        """Attach resource/failure/backpressure annotations to the node.

        Recognized by ``compile()``: ``failure_policy`` ("raise" | "restart"
        | "drop_shard") is applied to the node's source actors at lowering
        time; ``overflow_policy`` ("block" | "drop_newest" | "drop_oldest")
        overrides an enqueue node's queue policy; ``credits`` (int) caps a
        gather_async node's in-flight window; ``num_learners``/``microbatch``
        (ints, see ``learners()``/``microbatch()``) lower a train stage onto
        a sharded SPMD learner group; ``vector``/``inference``/
        ``inference_credits`` (rollouts/par_gradients nodes) configure the
        vectorized rollout engine and decoupled batched inference;
        ``host`` (a name declared via ``declare_host``) places a source
        node's actor pool on a remote dataflow fragment (see ``host()``).
        Other keys (e.g.
        ``resources={"num_cpus": 1}``) are carried as placement metadata for
        schedulers/introspection.
        """
        import dataclasses

        node = self.spec.nodes[self.node_id]
        self.spec.nodes[self.node_id] = dataclasses.replace(
            node, annotations={**node.annotations, **annotations}
        )
        return self

    def learners(self, n: int) -> "Stream":
        """Lower this node's train stage onto ``n`` data-parallel learner
        devices (SPMD learner group).

        Sugar for ``annotate(num_learners=n)``: at lowering time
        ``compile()`` configures any TrainOneStep-like stage of the node to
        run its update on an ``n``-device mesh, with batch columns sharded
        at the transport boundary.  Typically chained directly on the
        TrainOneStep ``for_each`` node::

            rollouts.for_each(ConcatBatches(4096))
                    .for_each(TrainOneStep(workers)).learners(4).microbatch(2)
        """
        if n < 1:
            raise ValueError(f"learners() needs n >= 1 (got {n})")
        return self.annotate(num_learners=int(n))

    def microbatch(self, k: int) -> "Stream":
        """Accumulate gradients over ``k`` microbatch slices per update
        (sugar for ``annotate(microbatch=k)``; see ``learners()``)."""
        if k < 1:
            raise ValueError(f"microbatch() needs k >= 1 (got {k})")
        return self.annotate(microbatch=int(k))

    def host(self, name: str) -> "Stream":
        """Place this source node's actor pool on the named fragment host.

        Sugar for ``annotate(host=name)``.  The host must be declared via
        ``spec.declare_host(name)``; at lowering time the partitioner
        (``flow.compile``) re-homes the node's actors onto that host's
        ``RemoteBackend``, so the node's output stream crosses the host
        boundary over the socket transport while everything unannotated
        stays on the driver fragment::

            spec.declare_host("rollout-box")
            rollouts = spec.rollouts(workers, mode="bulk_sync").host("rollout-box")
        """
        if not isinstance(name, str) or not name:
            raise ValueError(f"host() needs a non-empty host name (got {name!r})")
        return self.annotate(host=name)

    # ----------------------------------------------------- transformations
    def for_each(self, fn: Callable, label: Optional[str] = None) -> "Stream":
        """Transformation stage.  On parallel streams the callable runs on the
        source actor (and is cloned per shard at lowering, as today)."""
        stage = StageSpec(fn=fn, label=label or _fn_label(fn))
        node = self.spec._add(
            "for_each", (self.ref,), {"stages": (stage,)}, stage.label, self.parallel
        )
        return Stream(self.spec, node.id, 0, self.parallel)

    def for_each_ctx(self, factory: Callable, label: str) -> "Stream":
        """Like ``for_each`` but ``factory(runtime)`` builds the callable at
        compile time, with access to deferred resources."""
        stage = StageSpec(fn=factory, label=label, ctx=True)
        node = self.spec._add(
            "for_each", (self.ref,), {"stages": (stage,)}, label, self.parallel
        )
        return Stream(self.spec, node.id, 0, self.parallel)

    def filter(self, predicate: Callable[[Any], bool]) -> "Stream":
        self._require_local("filter")
        node = self.spec._add(
            "filter", (self.ref,), {"predicate": predicate},
            f"Filter({_fn_label(predicate)})", False,
        )
        return Stream(self.spec, node.id)

    def zip_with_source_actor(self) -> "Stream":
        self._require_local("zip_with_source_actor")
        node = self.spec._add("zip_source_actor", (self.ref,), {}, "ZipWithSourceActor", False)
        return Stream(self.spec, node.id)

    # --------------------------------------------------------- sequencing
    def gather_async(self, num_async: int = 1, credits: Optional[int] = None) -> "Stream":
        """Async sequencing; ``credits`` caps total in-flight items across
        shards (credit-based backpressure; default ``num_async * shards``).
        Also settable post-hoc via ``.annotate(credits=N)``."""
        self._require_parallel("gather_async")
        node = self.spec._add(
            "gather_async", (self.ref,), {"num_async": num_async, "credits": credits},
            f"GatherAsync(num_async={num_async})", False,
        )
        return Stream(self.spec, node.id)

    def gather_sync(self) -> "Stream":
        self._require_parallel("gather_sync")
        node = self.spec._add("gather_sync", (self.ref,), {}, "GatherSync", False)
        return Stream(self.spec, node.id)

    def batch_across_shards(self) -> "Stream":
        self._require_parallel("batch_across_shards")
        node = self.spec._add("batch_across_shards", (self.ref,), {}, "BatchAcrossShards", False)
        return Stream(self.spec, node.id)

    # -------------------------------------------------------- concurrency
    def duplicate(self, n: int) -> List["Stream"]:
        """Split the stream into ``n`` buffered copies (paper Fig 8, split)."""
        self._require_local("duplicate")
        node = self.spec._add(
            "duplicate", (self.ref,), {"n": n}, f"Duplicate({n})", False, num_outputs=n
        )
        return [Stream(self.spec, node.id, port=i) for i in range(n)]

    def enqueue(
        self,
        resource: ResourceRef,
        block: bool = True,
        policy: Optional[str] = None,
    ) -> "Stream":
        """Push items into a deferred resource's in-queue (learner feed).

        ``policy`` is the overflow policy at the queue boundary — ``block``
        (lossless, backpressures the producing sub-flow), ``drop_newest``
        (lossy Ape-X feed, drops counted in ``num_samples_dropped``), or
        ``drop_oldest`` (bounded staleness).  ``block=True/False`` remains
        as shorthand for block/drop_newest; an ``overflow_policy``
        annotation set via ``.annotate()`` wins over both at lowering time.
        """
        self._require_local("enqueue")
        if policy is not None:
            from repro.core.transport import OverflowPolicy

            OverflowPolicy.validate(policy)
        node = self.spec._add(
            "enqueue", (self.ref,),
            {"resource": resource.name, "block": block, "policy": policy},
            f"Enqueue({resource.name}.inqueue)", False,
        )
        return Stream(self.spec, node.id)

    # -------------------------------------------------------------- sinks
    def report(self, workers: Any = None, interval: int = 1) -> "Stream":
        """Standard metrics-reporting sink (result-dict stream)."""
        self._require_local("report")
        node = self.spec._add(
            "report", (self.ref,), {"workers": workers, "interval": interval},
            "ReportMetrics", False,
        )
        return Stream(self.spec, node.id)

    # ------------------------------------------------------------ helpers
    def _require_parallel(self, op: str) -> None:
        if not self.parallel:
            raise TypeError(f"{op}() requires a parallel stream (got local)")

    def _require_local(self, op: str) -> None:
        if self.parallel:
            raise TypeError(
                f"{op}() requires a local stream; sequence the parallel stream "
                "first (gather_sync/gather_async/batch_across_shards)"
            )

    def __repr__(self) -> str:  # pragma: no cover
        kind = "ParStream" if self.parallel else "Stream"
        return f"{kind}({self.node_id}:{self.port})"


class FlowSpec:
    """The declarative dataflow graph: nodes + stream edges + resources."""

    def __init__(self, name: str = "flow"):
        self.name = name
        self.nodes: Dict[str, Node] = {}
        self.resources: Dict[str, ResourceSpec] = {}
        self.hosts: Dict[str, HostSpec] = {}
        self.output: Optional[EdgeRef] = None
        self._ids = itertools.count()

    # ------------------------------------------------------- construction
    def _add(
        self,
        kind: str,
        inputs: Tuple[EdgeRef, ...],
        params: Dict[str, Any],
        label: str,
        parallel: bool,
        num_outputs: int = 1,
        annotations: Optional[Dict[str, Any]] = None,
    ) -> Node:
        for nid, port in inputs:
            if nid not in self.nodes:
                raise ValueError(f"unknown input node {nid!r}")
            if not (0 <= port < self.nodes[nid].num_outputs):
                raise ValueError(f"invalid port {port} for node {nid!r}")
        node = Node(
            id=f"n{next(self._ids)}_{kind}",
            kind=kind,
            inputs=tuple(inputs),
            params=dict(params),
            label=label,
            parallel=parallel,
            num_outputs=num_outputs,
            annotations=dict(annotations or {}),
        )
        self.nodes[node.id] = node
        return node

    # ------------------------------------------------------------ hosts
    def declare_host(self, name: str, address: Optional[str] = None) -> HostSpec:
        """Declare a placement host for dataflow fragments.

        ``address=None`` -> driver-managed: ``compile()`` launches a local
        ``RemoteHost`` process and tears it down with the flow.  Pass
        ``"host:port"`` to target an externally-run ``RemoteHost`` (started
        on another machine via ``repro.core.remote.start_local_host`` or an
        equivalent entrypoint).  Source nodes opt in with ``.host(name)``.
        """
        if not isinstance(name, str) or not name:
            raise ValueError(f"declare_host() needs a non-empty name (got {name!r})")
        if name in self.hosts:
            raise ValueError(f"duplicate host {name!r}")
        spec = HostSpec(name, address)
        self.hosts[name] = spec
        return spec

    # ------------------------------------------------------------ sources
    @staticmethod
    def _source_annotations(
        failure_policy: Optional[str],
        resources: Optional[Dict[str, Any]],
        host: Optional[str] = None,
    ) -> Dict[str, Any]:
        ann: Dict[str, Any] = {}
        if failure_policy is not None:
            from repro.core.executor import FailurePolicy

            ann["failure_policy"] = FailurePolicy.validate(failure_policy)
        if resources is not None:
            ann["resources"] = dict(resources)
        if host is not None:
            if not isinstance(host, str) or not host:
                raise ValueError(f"host= needs a non-empty host name (got {host!r})")
            ann["host"] = host
        return ann

    @staticmethod
    def _vector_annotations(
        vector: Optional[int],
        inference: Optional[str],
        inference_credits: Optional[int],
        inference_replicas: Optional[int] = None,
        inference_routing: Optional[str] = None,
        decode: Optional[str] = None,
    ) -> Dict[str, Any]:
        ann: Dict[str, Any] = {}
        if vector is not None:
            if int(vector) < 1:
                raise ValueError(f"vector= needs >= 1 lanes (got {vector})")
            ann["vector"] = int(vector)
        if decode is not None:
            if decode not in ("forward", "cache"):
                raise ValueError(
                    f"unknown decode mode {decode!r} (want 'forward'|'cache')"
                )
            ann["decode"] = decode
        if inference is not None:
            if inference not in ("local", "server"):
                raise ValueError(
                    f"unknown inference mode {inference!r} (want 'local'|'server')"
                )
            ann["inference"] = inference
        if inference_credits is not None:
            if int(inference_credits) < 1:
                raise ValueError(
                    f"inference_credits= must be >= 1 (got {inference_credits})"
                )
            ann["inference_credits"] = int(inference_credits)
        if inference_replicas is not None:
            if int(inference_replicas) < 1:
                raise ValueError(
                    f"inference_replicas= must be >= 1 (got {inference_replicas})"
                )
            ann["inference_replicas"] = int(inference_replicas)
        if inference_routing is not None:
            if inference_routing not in ("auto", "least_loaded", "sticky"):
                raise ValueError(
                    f"unknown inference routing {inference_routing!r} "
                    "(want 'auto'|'least_loaded'|'sticky')"
                )
            ann["inference_routing"] = inference_routing
        return ann

    def rollouts(
        self,
        workers: Any,
        mode: str = "bulk_sync",
        num_async: int = 1,
        credits: Optional[int] = None,
        failure_policy: Optional[str] = None,
        resources: Optional[Dict[str, Any]] = None,
        vector: Optional[int] = None,
        inference: Optional[str] = None,
        inference_credits: Optional[int] = None,
        inference_replicas: Optional[int] = None,
        inference_routing: Optional[str] = None,
        decode: Optional[str] = None,
        host: Optional[str] = None,
    ) -> Stream:
        """Experience stream from the rollout workers (paper Fig 5).

        ``failure_policy`` annotates the node; ``compile()`` lowers it onto
        the rollout actors so gather loops restart/drop/raise per-worker.
        ``credits`` (async mode) caps the total in-flight sample window —
        credit-based backpressure at the source.

        Vectorized rollout engine (carried as node annotations, lowered by
        ``compile()``): ``vector=N`` resizes each worker's ``VectorEnv`` to
        N synchronized lanes with one batched policy dispatch per step;
        ``inference='server'`` additionally decouples acting onto a shared
        ``InferenceActor`` (batched requests over the executor transport,
        ``inference_credits`` bounding requests in flight across shards —
        default ``2 × num_workers``).  ``inference_replicas=N`` serves from
        N replicas behind an ``InferenceRouter`` with per-replica health +
        weight-version tracking; ``inference_routing`` picks the dispatch
        policy (``'auto'`` — sticky iff the policy is stateful —
        ``'least_loaded'``, or ``'sticky'`` lane->replica pinning).  Server
        inference requires thread-backend rollout workers; others fall back
        to local with a warning.  ``decode='cache'`` routes local acting
        through the stateful-policy protocol so per-lane model state (an
        LM's KV cache) rides the rollout scan — one ``decode_step`` per
        token instead of a full forward; policies without the protocol fall
        back to ``'forward'``.
        """
        if mode not in ("raw", "bulk_sync", "async"):
            raise ValueError(f"unknown rollout mode {mode!r}")
        if credits is not None and mode != "async":
            raise ValueError(
                f"credits= requires mode='async' (got mode={mode!r}); other "
                "rollout modes have no in-flight pipeline to bound"
            )
        annotations = self._source_annotations(failure_policy, resources, host)
        annotations.update(
            self._vector_annotations(
                vector, inference, inference_credits,
                inference_replicas, inference_routing, decode,
            )
        )
        node = self._add(
            "rollouts", (),
            {"workers": workers, "mode": mode, "num_async": num_async, "credits": credits},
            f"ParallelRollouts({mode})", parallel=(mode == "raw"),
            annotations=annotations,
        )
        return Stream(self, node.id, parallel=(mode == "raw"))

    def replay(
        self,
        actors: Any,
        num_async: int = 4,
        credits: Optional[int] = None,
        failure_policy: Optional[str] = None,
        resources: Optional[Dict[str, Any]] = None,
        host: Optional[str] = None,
    ) -> Stream:
        """Replayed-batch stream from replay-buffer actors (Ape-X §5.2).

        ``credits`` caps the replay gather's total in-flight window (also
        settable post-hoc via ``.annotate(credits=N)``)."""
        node = self._add(
            "replay", (),
            {"actors": actors, "num_async": num_async, "credits": credits},
            "Replay", False,
            annotations=self._source_annotations(failure_policy, resources, host),
        )
        return Stream(self, node.id)

    def par_gradients(
        self,
        workers: Any,
        failure_policy: Optional[str] = None,
        resources: Optional[Dict[str, Any]] = None,
        vector: Optional[int] = None,
        inference: Optional[str] = None,
        inference_credits: Optional[int] = None,
        inference_replicas: Optional[int] = None,
        inference_routing: Optional[str] = None,
        decode: Optional[str] = None,
        host: Optional[str] = None,
    ) -> Stream:
        """ParIter[(grads, info)]: sample + grad on each worker (A3C/A2C).

        ``vector=``/``inference=``/``decode=`` annotate the vectorized
        rollout engine exactly as on ``rollouts()`` (the gradient workers
        sample through the same engine)."""
        annotations = self._source_annotations(failure_policy, resources, host)
        annotations.update(
            self._vector_annotations(
                vector, inference, inference_credits,
                inference_replicas, inference_routing, decode,
            )
        )
        node = self._add(
            "par_gradients", (), {"workers": workers}, "ComputeGradients", True,
            annotations=annotations,
        )
        return Stream(self, node.id, parallel=True)

    def par_source(
        self,
        pool: Any,
        pull_fn: Callable,
        name: str = "ParSource",
        failure_policy: Optional[str] = None,
        resources: Optional[Dict[str, Any]] = None,
        host: Optional[str] = None,
    ) -> Stream:
        """Generic parallel source over an actor pool (MAML inner loop, LM
        data pipelines)."""
        node = self._add(
            "par_source", (), {"pool": pool, "pull_fn": pull_fn}, name, True,
            annotations=self._source_annotations(failure_policy, resources, host),
        )
        return Stream(self, node.id, parallel=True)

    def from_items(self, items: Sequence[Any], repeat: bool = False) -> Stream:
        """Local stream over in-memory items (tests, micro-benchmarks)."""
        node = self._add("from_items", (), {"items": list(items), "repeat": repeat}, "FromItems", False)
        return Stream(self, node.id)

    def dequeue(self, resource: ResourceRef) -> Stream:
        """Stream popped from a deferred resource's out-queue."""
        node = self._add(
            "dequeue", (), {"resource": resource.name},
            f"Dequeue({resource.name}.outqueue)", False,
        )
        return Stream(self, node.id)

    # ---------------------------------------------------------- resources
    def learner_thread(self, workers: Any, name: str = "learner", **params: Any) -> ResourceRef:
        """Declare a learner thread fed/drained by enqueue/dequeue nodes.

        Nothing is constructed or started here — instantiation happens at
        compile time, ``Thread.start()`` on the first pull of the compiled
        flow, ``stop()`` + join when the flow stops.
        """
        if name in self.resources:
            raise ValueError(f"duplicate resource {name!r}")
        self.resources[name] = ResourceSpec(name, "learner_thread", {"workers": workers, **params})
        return ResourceRef(self, name)

    # -------------------------------------------------------- concurrency
    def concurrently(
        self,
        streams: Sequence[Stream],
        mode: str = "round_robin",
        output_indexes: Optional[Sequence[int]] = None,
        round_robin_weights: Optional[Sequence[Union[int, str]]] = None,
    ) -> Stream:
        """Union concurrent sub-flows (paper Fig 8); emit ``output_indexes``."""
        if mode not in ("round_robin", "async"):
            raise ValueError(f"unknown mode {mode!r}")
        if not streams:
            raise ValueError("concurrently() needs at least one stream")
        for s in streams:
            s._require_local("concurrently")
        out_idx = list(output_indexes) if output_indexes is not None else list(range(len(streams)))
        for i in out_idx:
            if not (0 <= i < len(streams)):
                raise ValueError(f"output index {i} out of range")
        if round_robin_weights is not None and len(round_robin_weights) != len(streams):
            raise ValueError("round_robin_weights must match #streams")
        node = self._add(
            "concurrently",
            tuple(s.ref for s in streams),
            {
                "mode": mode,
                "output_indexes": out_idx,
                "round_robin_weights": list(round_robin_weights) if round_robin_weights else None,
            },
            f"Concurrently({mode})",
            False,
        )
        return Stream(self, node.id)

    def set_output(self, stream: Stream) -> None:
        stream._require_local("set_output")
        self.output = stream.ref

    # --------------------------------------------------------- validation
    def validate(self) -> None:
        if self.output is None:
            raise ValueError(f"flow {self.name!r}: no output set (call set_output)")
        consumed: Dict[EdgeRef, int] = {}
        for node in self.nodes.values():
            for ref in node.inputs:
                consumed[ref] = consumed.get(ref, 0) + 1
        consumed[self.output] = consumed.get(self.output, 0) + 1
        for ref, n in consumed.items():
            if n > 1:
                raise ValueError(
                    f"flow {self.name!r}: edge {ref} consumed {n} times; "
                    "use duplicate() to split a stream"
                )
        for name in self._referenced_resources():
            if name not in self.resources:
                raise ValueError(f"flow {self.name!r}: undeclared resource {name!r}")

    def check(self, rules: Any = None) -> List[Any]:
        """Static analysis (flowcheck): run the rule set, return diagnostics.

        Unlike ``validate()`` — which raises on the three structural
        invariants lowering cannot survive — ``check()`` never raises on
        account of the graph: it returns the full ``Diagnostic`` list
        (credit deadlocks, unbounded queues, annotations that cannot lower,
        ... — see ``docs/flowcheck.md``), sorted errors-first.  Gate on it
        with ``compile(strict=True)`` or ``scripts/flowcheck.py``.
        """
        from repro.flow.analysis.engine import analyze

        return analyze(self, rules=rules)

    def _referenced_resources(self) -> List[str]:
        return [
            n.params["resource"] for n in self.nodes.values() if n.kind in ("enqueue", "dequeue")
        ]

    # ------------------------------------------------------ introspection
    def consumers(self, node_id: str) -> int:
        """How many edges read from ``node_id`` (any port), incl. the output."""
        n = sum(1 for node in self.nodes.values() for ref in node.inputs if ref[0] == node_id)
        if self.output is not None and self.output[0] == node_id:
            n += 1
        return n

    def replace_nodes(self, nodes: Dict[str, Node]) -> "FlowSpec":
        """Structural copy with a rewritten node table (optimization passes)."""
        out = FlowSpec(self.name)
        out.nodes = dict(nodes)
        out.resources = dict(self.resources)
        out.hosts = dict(self.hosts)
        out.output = self.output
        out._ids = self._ids
        return out

    def compile(self, fuse: bool = True, strict: bool = False) -> Any:
        """Lower onto the iterator runtime; see ``repro.flow.compile``.

        ``strict=True`` runs ``check()`` first and refuses to build anything
        when the graph carries error-severity diagnostics."""
        from repro.flow.compile import CompiledFlow

        return CompiledFlow(self, fuse=fuse, strict=strict)

    # -------------------------------------------------------------- DOT
    def to_dot(self, metrics: Any = None) -> str:
        """Render the graph as Graphviz DOT (paper Figures 9–12).

        Stream edges are solid; edges into/out of deferred resources are
        dotted; branches merged by an async union are dashed pink (the
        paper's asynchronous-dependency arrows).

        With a ``MetricsContext`` (``Algorithm.to_dot(with_metrics=True)``
        passes the live one), data-plane edges gain labels: bytes moved out
        of each sequencing/enqueue node (``bytes_moved/<node>`` counters,
        keyed by node id at lowering) and current queue occupancy on
        resource edges — the paper's Fig 13 data plane, readable off the
        graph.
        """

        def esc(s: str) -> str:
            return s.replace("\\", "\\\\").replace('"', '\\"')

        counters = metrics.counters if metrics is not None else {}
        gauges = metrics.gauges if metrics is not None else {}

        def _human_bytes(n: float) -> str:
            for unit in ("B", "KB", "MB", "GB", "TB"):
                if n < 1024 or unit == "TB":
                    return f"{n:.0f}{unit}" if unit == "B" else f"{n:.1f}{unit}"
                n /= 1024.0
            return f"{n:.1f}TB"

        def _edge_metric_label(src_node_id: str) -> Optional[str]:
            moved = counters.get(f"bytes_moved/{src_node_id}")
            if moved:
                return _human_bytes(float(moved))
            return None

        lines = [
            f'digraph "{esc(self.name)}" {{',
            "  rankdir=LR;",
            '  node [shape=box, fontname="Helvetica", fontsize=11];',
        ]
        for res in self.resources.values():
            lines.append(
                f'  "{esc(res.name)}" [shape=ellipse, style=filled, '
                f'fillcolor=lightgrey, label="LearnerThread({esc(res.name)})"];'
            )
        # Nodes grouped by placement fragment: host-annotated nodes render
        # inside a dashed cluster per declared host (MSRL's per-host
        # dataflow-fragment picture); everything else is the driver fragment.
        by_host: Dict[Optional[str], List[str]] = {}
        for node in self.nodes.values():
            if node.kind == "for_each":
                label = "\\n".join(esc(s.label) for s in node.params["stages"])
            else:
                label = esc(node.label)
            if node.annotations:
                ann = ", ".join(f"{k}={v}" for k, v in sorted(node.annotations.items()))
                label = f"{label}\\n[{esc(ann)}]"
            shape = ""
            if node.kind == "concurrently":
                shape = ", shape=hexagon"
            elif node.kind in ("duplicate",):
                shape = ", shape=trapezium"
            elif node.parallel or node.kind in ("rollouts", "replay", "par_gradients", "par_source"):
                shape = ", style=rounded"
            host = node.annotations.get("host") if self.hosts else None
            by_host.setdefault(host if host in self.hosts else None, []).append(
                f'"{node.id}" [label="{label}"{shape}];'
            )
        lines.extend(f"  {line}" for line in by_host.get(None, []))
        for i, host_name in enumerate(sorted(h for h in by_host if h is not None)):
            addr = self.hosts[host_name].address or "driver-managed"
            lines.append(f'  subgraph "cluster_host_{i}" {{')
            lines.append(f'    label="fragment: {esc(host_name)} ({esc(addr)})";')
            lines.append("    style=dashed;")
            lines.extend(f"    {line}" for line in by_host[host_name])
            lines.append("  }")
        for node in self.nodes.values():
            async_union = node.kind == "concurrently" and node.params.get("mode") == "async"
            for i, (src, port) in enumerate(node.inputs):
                attrs = []
                if async_union and i not in node.params["output_indexes"]:
                    attrs.append("style=dashed")
                    attrs.append("color=deeppink")
                elif async_union:
                    attrs.append("color=deeppink")
                if node.kind == "concurrently":
                    label = str(i)
                    moved = _edge_metric_label(src)
                    if moved:
                        label = f"{i}: {moved}"
                    attrs.append(f'label="{esc(label)}"')
                else:
                    moved = _edge_metric_label(src)
                    if moved:
                        attrs.append(f'label="{esc(moved)}"')
                a = f" [{', '.join(attrs)}]" if attrs else ""
                lines.append(f'  "{src}" -> "{node.id}"{a};')
            if node.kind == "enqueue":
                attrs = ["style=dotted"]
                occ = gauges.get(f"queue_occupancy/{node.id}")
                moved = _edge_metric_label(node.id)
                parts = [p for p in (moved, f"q={occ:.0f}" if occ is not None else None) if p]
                if parts:
                    attrs.append(f'label="{esc(" ".join(parts))}"')
                lines.append(
                    f'  "{node.id}" -> "{node.params["resource"]}" [{", ".join(attrs)}];'
                )
            if node.kind == "dequeue":
                attrs = ["style=dotted"]
                occ = gauges.get(f"queue_occupancy/{node.id}")
                if occ is not None:
                    attrs.append(f'label="q={occ:.0f}"')
                lines.append(
                    f'  "{node.params["resource"]}" -> "{node.id}" [{", ".join(attrs)}];'
                )
        if self.output is not None:
            lines.append(f'  "__out" [shape=plaintext, label="results"];')
            lines.append(f'  "{self.output[0]}" -> "__out";')
        lines.append("}")
        return "\n".join(lines)

    def __repr__(self) -> str:  # pragma: no cover
        return f"FlowSpec({self.name!r}, nodes={len(self.nodes)}, resources={list(self.resources)})"
