"""Pallas TPU kernels for fused advantage estimation (GAE / V-trace).

The reverse-time recurrences in ``repro.rl.advantages`` are sequential in T
but embarrassingly parallel in the batch dimension.  The ``lax.scan``
references materialize the ``next_values``/``deltas`` intermediates in HBM
and dispatch one tiny elementwise op per time step; these kernels instead
grid over batch blocks and keep the whole [T, block_b] column panel resident
in VMEM: the delta computation, the reverse recurrence, and the value-target
epilogue fuse into a single pass, so HBM traffic is exactly the four input
streams plus the two outputs.

Layout: all inputs are time-major [T, B] (the same layout the scan
references take), ``last_value`` is [B].  The wrappers flatten arbitrary
trailing dims into B, pad B up to the lane-aligned block size (padded rows
are independent garbage, sliced off on return), and leave T unpadded — T is
the sublane dim and the boundary row (bootstrap ``last_value``) is handled
in-kernel, never by padding.

On CPU (this container) the kernels run under ``interpret=True`` and are
parity-tested against the scan references to 1e-5
(``tests/test_kernel_advantages.py``); the dispatch layer
(``repro.kernels.ops.fused_gae`` / ``fused_vtrace``) selects the scan
reference on CPU and the Pallas kernel on TPU.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gae_pallas", "vtrace_pallas"]

_BLOCK_B = 128  # lane dimension of one batch panel


def _reverse_scan(deltas: jax.Array, decay: jax.Array, T: int) -> jax.Array:
    """acc_t = delta_t + decay_t * acc_{t+1}, returned as the full [T, Bb]
    array.  Runs as a ``fori_loop`` over VMEM-resident panels (the rwkv6
    kernel idiom: dynamic row slices against register/VMEM arrays)."""

    def step(i, carry_out):
        carry, out = carry_out
        t = T - 1 - i
        d_t = jax.lax.dynamic_slice_in_dim(deltas, t, 1, 0)[0]
        k_t = jax.lax.dynamic_slice_in_dim(decay, t, 1, 0)[0]
        acc = d_t + k_t * carry
        out = jax.lax.dynamic_update_slice(out, acc[None], (t, 0))
        return acc, out

    carry0 = jnp.zeros(deltas.shape[1:], deltas.dtype)
    _, out = jax.lax.fori_loop(0, T, step, (carry0, jnp.zeros_like(deltas)))
    return out


def _gae_kernel(r_ref, v_ref, d_ref, last_ref, adv_ref, ret_ref, *, gamma, lam, T):
    r = r_ref[...].astype(jnp.float32)  # [T, Bb]
    v = v_ref[...].astype(jnp.float32)
    nd = 1.0 - d_ref[...].astype(jnp.float32)
    last = last_ref[...].astype(jnp.float32)  # [1, Bb]

    nv = jnp.concatenate([v[1:], last], axis=0)  # bootstrap boundary in-kernel
    deltas = r + gamma * nd * nv - v
    adv = _reverse_scan(deltas, gamma * lam * nd, T)
    adv_ref[...] = adv.astype(adv_ref.dtype)
    ret_ref[...] = (adv + v).astype(ret_ref.dtype)


def _vtrace_kernel(
    blp_ref, tlp_ref, r_ref, v_ref, d_ref, last_ref, vs_ref, pg_ref,
    *, gamma, rho_clip, c_clip, T,
):
    blp = blp_ref[...].astype(jnp.float32)
    tlp = tlp_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    last = last_ref[...].astype(jnp.float32)  # [1, Bb]

    rhos = jnp.exp(tlp - blp)
    clipped_rhos = jnp.minimum(rho_clip, rhos)
    cs = jnp.minimum(c_clip, rhos)
    discounts = gamma * (1.0 - d)
    nv = jnp.concatenate([v[1:], last], axis=0)
    deltas = clipped_rhos * (r + discounts * nv - v)
    vs = _reverse_scan(deltas, discounts * cs, T) + v
    next_vs = jnp.concatenate([vs[1:], last], axis=0)
    pg_adv = clipped_rhos * (r + discounts * next_vs - v)
    vs_ref[...] = vs.astype(vs_ref.dtype)
    pg_ref[...] = pg_adv.astype(pg_ref.dtype)


def _flatten_tm(x: jax.Array) -> jax.Array:
    """[T, ...] -> [T, B] (B = product of trailing dims; B=1 when none)."""
    T = x.shape[0]
    return x.reshape(T, -1) if x.ndim != 1 else x.reshape(T, 1)


def _pad_b(x: jax.Array, block: int) -> jax.Array:
    B = x.shape[1]
    pad = (-B) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x


def _panel_call(kernel, inputs, T, B, dtype, num_outputs, interpret, block_b):
    """Shared pallas_call plumbing: grid over lane-aligned batch panels."""
    block_b = min(block_b, max(B, 1))
    padded = [_pad_b(x, block_b) for x in inputs]
    Bp = padded[0].shape[1]
    nb = Bp // block_b
    spec_tb = pl.BlockSpec((T, block_b), lambda b: (0, b))
    spec_last = pl.BlockSpec((1, block_b), lambda b: (0, b))
    outs = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[spec_tb] * (len(inputs) - 1) + [spec_last],
        out_specs=[spec_tb] * num_outputs,
        out_shape=[jax.ShapeDtypeStruct((T, Bp), dtype)] * num_outputs,
        interpret=interpret,
    )(*padded)
    return [o[:, :B] for o in outs]


def gae_pallas(
    rewards: jax.Array,
    values: jax.Array,
    dones: jax.Array,
    last_value: jax.Array,
    gamma: float = 0.99,
    lam: float = 0.95,
    block_b: int = _BLOCK_B,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused GAE; same contract as ``repro.rl.advantages.gae``."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    T = rewards.shape[0]
    shape, dtype = rewards.shape, rewards.dtype
    r, v, d = map(_flatten_tm, (rewards, values, dones.astype(rewards.dtype)))
    last = last_value.reshape(1, -1).astype(dtype)
    B = r.shape[1]
    kernel = functools.partial(_gae_kernel, gamma=gamma, lam=lam, T=T)
    adv, ret = _panel_call(kernel, [r, v, d, last], T, B, dtype, 2, interpret, block_b)
    return adv.reshape(shape), ret.reshape(shape)


def vtrace_pallas(
    behaviour_logp: jax.Array,
    target_logp: jax.Array,
    rewards: jax.Array,
    values: jax.Array,
    dones: jax.Array,
    last_value: jax.Array,
    gamma: float = 0.99,
    rho_clip: float = 1.0,
    c_clip: float = 1.0,
    block_b: int = _BLOCK_B,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused V-trace; same contract as ``repro.rl.advantages.vtrace``."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    T = rewards.shape[0]
    shape, dtype = rewards.shape, rewards.dtype
    blp, tlp, r, v, d = map(
        _flatten_tm,
        (behaviour_logp, target_logp, rewards, values, dones.astype(rewards.dtype)),
    )
    last = last_value.reshape(1, -1).astype(dtype)
    B = r.shape[1]
    kernel = functools.partial(
        _vtrace_kernel, gamma=gamma, rho_clip=rho_clip, c_clip=c_clip, T=T
    )
    vs, pg = _panel_call(
        kernel, [blp, tlp, r, v, d, last], T, B, dtype, 2, interpret, block_b
    )
    return vs.reshape(shape), pg.reshape(shape)
