"""RWKV6 WKV recurrence Pallas TPU kernel.

The recurrence

    o_t = r_t . (S_{t-1} + u * k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t

is sequential in t, but the [N, N] per-head state never needs to leave VMEM:
the kernel walks time chunks on the innermost (sequential) grid dimension,
carrying S in VMEM scratch, so HBM traffic is O(T*N) for the r/k/v/w/o
streams instead of O(T*N^2) for materialized states.  This is the TPU-native
restatement of the CUDA wkv kernels shipped with RWKV (DESIGN.md §5).

Grid: (B, H, T // chunk); within a chunk a fori_loop runs the exact
step-by-step recurrence on VREG-resident [N] rows.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rwkv6_pallas"]


def _wkv_kernel(
    r_ref,  # [1, 1, chunk, N]
    k_ref,
    v_ref,
    w_ref,
    u_ref,  # [1, N]
    o_ref,  # [1, 1, chunk, N]
    s_out_ref,  # [1, 1, N, N]
    state_scr,  # [N, N] f32
    *,
    chunk: int,
    num_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, 0].astype(jnp.float32)  # [chunk, N]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # [N]

    def step(t, carry):
        S, out = carry
        kv = k[t][:, None] * v[t][None, :]          # [N, N]
        o_t = (r[t][:, None] * (S + u[:, None] * kv)).sum(axis=0)  # [N]
        S = w[t][:, None] * S + kv
        out = jax.lax.dynamic_update_slice(out, o_t[None, :], (t, 0))
        return S, out

    S0 = state_scr[...]
    out0 = jnp.zeros((chunk, r.shape[-1]), jnp.float32)
    S, out = jax.lax.fori_loop(0, chunk, step, (S0, out0))
    state_scr[...] = S
    o_ref[0, 0] = out.astype(o_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _write_state():
        s_out_ref[0, 0] = state_scr[...]


def rwkv6_pallas(
    r: jax.Array,  # [B, T, H, N]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decay in (0, 1]
    u: jax.Array,  # [H, N]
    state: Optional[jax.Array] = None,
    chunk: int = 64,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    B, T, H, N = r.shape
    if state is not None:
        # The kernel's VMEM state scratch is zero-initialized on the first
        # chunk; a nonzero initial state would need an extra input stream.
        # Checked *before* any compute — callers needing stateful resume go
        # through ``ops.rwkv6``, which routes them to the exact reference.
        raise NotImplementedError("rwkv6_pallas starts from zero state")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    chunk = min(chunk, T)
    assert T % chunk == 0, "pad T to chunk multiple"
    nc = T // chunk
    tm = lambda x: x.transpose(0, 2, 1, 3)  # [B, H, T, N]

    kernel = functools.partial(_wkv_kernel, chunk=chunk, num_chunks=nc)
    out, s_out = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, N), lambda b, h, ci: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, N), r.dtype),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(tm(r), tm(k), tm(v), tm(w), u)
    out = out.transpose(0, 2, 1, 3)
    return out, s_out
