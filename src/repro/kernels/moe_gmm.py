"""Grouped matmul (MoE expert FFN) Pallas TPU kernel.

After sort-by-expert dispatch, tokens form contiguous per-expert groups.
Each (block_m x D) row tile belongs to exactly one expert (groups are padded
to block_m multiples, as in MegaBlocks); the expert id per tile is computed
on the host and passed as a scalar-prefetch argument so the weight BlockSpec
index map can select w[eid] — no gather of weight matrices through HBM.

Grid: (num_row_tiles, F // block_n).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["moe_gmm_pallas"]


def _gmm_kernel(eid_ref, x_ref, w_ref, o_ref):
    # x: [block_m, D]; w: [1, D, block_n] (expert slice); o: [block_m, block_n]
    o_ref[...] = jax.lax.dot_general(
        x_ref[...].astype(jnp.float32),
        w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def moe_gmm_pallas(
    x: jax.Array,            # [T, D] rows sorted/padded by expert
    w: jax.Array,            # [E, D, F]
    group_sizes: jax.Array,  # [E] rows per expert (sum == T, block_m-aligned)
    block_m: int = 128,
    block_n: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    T, D = x.shape
    E, _, F = w.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_m = min(block_m, T)
    block_n = min(block_n, F)
    assert T % block_m == 0 and F % block_n == 0
    nm, nn = T // block_m, F // block_n

    # Expert id per row tile (host-side; groups padded to block_m multiples).
    ends = jnp.cumsum(group_sizes)
    tile_starts = jnp.arange(nm, dtype=jnp.int32) * block_m
    eids = jnp.sum(tile_starts[:, None] >= ends[None, :], axis=-1).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nm, nn),
        in_specs=[
            pl.BlockSpec((block_m, D), lambda mi, ni, eids: (mi, 0)),
            pl.BlockSpec((1, D, block_n), lambda mi, ni, eids: (eids[mi], 0, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, eids: (mi, ni)),
    )
    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, F), x.dtype),
        interpret=interpret,
    )(eids, x, w)
