"""Flash attention (prefill) Pallas TPU kernel.

Online-softmax tiling: grid (B, H, num_q_blocks, num_k_blocks) with the K
dimension innermost (sequential on TPU), carrying the running max / sum /
accumulator in VMEM scratch.  BlockSpecs stream one (block_q x D) Q tile and
(block_k x D) K/V tiles through VMEM; D and block sizes are MXU-aligned
(multiples of 128 for the matmul dims).  GQA is expressed in the K/V index
maps (query head h reads kv head h // group).

Validated against kernels/ref.py oracles in interpret mode (CPU container);
on TPU the same code runs compiled.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # [1, 1, block_q, D]
    k_ref,  # [1, 1, block_k, D]
    v_ref,  # [1, 1, block_k, D]
    o_ref,  # [1, 1, block_q, D]
    m_scr,  # [block_q]   running max
    l_scr,  # [block_q]   running sum
    acc_scr,  # [block_q, D] accumulator
    *,
    causal: bool,
    window: int,
    scale: float,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
    q_offset: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= k_pos > q_pos - window

    # Skip fully-masked K blocks (beyond the causal frontier / window).
    block_needed = jnp.logical_or(not causal, ki * block_k <= q_offset + (qi + 1) * block_q - 1)
    if window:
        block_needed = jnp.logical_and(
            block_needed, (ki + 1) * block_k - 1 > q_offset + qi * block_q - window
        )

    @pl.when(block_needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [block_q, block_k]
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Sk, KV, D]
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, "pad seq to block multiples"
    nq, nk = Sq // block_q, Sk // block_k

    # [B, H, S, D] layout so the last two dims tile the MXU.
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        window=window,
        scale=1.0 / math.sqrt(D),
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
        q_offset=q_offset,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
