# Pallas TPU kernels for the framework's compute hot-spots, with pure-jnp
# oracles (ref.py) and a backend-dispatching wrapper layer (ops.py).
