"""Dispatch layer for compute hot-spots: Pallas TPU kernels with jnp fallback.

On TPU the Pallas implementations run (``pl.pallas_call`` with VMEM
BlockSpecs); on CPU (this container, incl. the 512-device dry-run) the
pure-jnp references run — identical math, so tests and the dry-run roofline
are faithful to the computation while kernels are validated separately in
``interpret=True`` mode (tests/test_kernels_*.py).

Set ``repro.kernels.ops.FORCE_MODE`` to 'pallas' | 'ref' | None (auto).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

FORCE_MODE: Optional[str] = None  # None -> auto by backend

__all__ = [
    "flash_attention",
    "decode_attention",
    "rwkv6",
    "moe_gmm",
    "fused_gae",
    "fused_vtrace",
    "fused_ppo_loss",
    "use_pallas",
]


def use_pallas() -> bool:
    if FORCE_MODE == "pallas":
        return True
    if FORCE_MODE == "ref":
        return False
    return jax.default_backend() == "tpu"


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    if use_pallas():
        from repro.kernels.flash_attention import flash_attention_pallas

        return flash_attention_pallas(q, k, v, causal=causal, window=window, q_offset=q_offset)
    return _ref.chunked_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)


def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, valid: jax.Array
) -> jax.Array:
    """Single-token attention against a KV cache.

    q: [B,1,H,D]; caches: [B,W,KV,D]; valid: [W] (shared) or [B,W]
    (per-sequence occupancy, for ragged prompt lengths in a co-batched
    decode). Rows with no valid slot return zeros.
    """
    if use_pallas():
        from repro.kernels.decode_attention import decode_attention_pallas

        return decode_attention_pallas(q, k_cache, v_cache, valid)
    return _ref.decode_attention_ref(q, k_cache, v_cache, valid)


def rwkv6(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: Optional[jax.Array] = None,
    chunk: int = 64,
):
    # The Pallas kernel always starts from zero state (it raises on a
    # nonzero ``state``); stateful callers (decode resume, chunked prefill
    # continuation) route to the reference recurrence, which carries
    # [B,H,N,N] state exactly — a fallback, never a crash.
    if use_pallas() and state is None:
        from repro.kernels.rwkv6 import rwkv6_pallas

        return rwkv6_pallas(r, k, v, w, u, state=None, chunk=chunk)
    # jnp fallback: exact sequential recurrence, chunk-rematted (the TPU win
    # of the Pallas kernel is keeping the [N,N] state in VMEM across the
    # time loop).
    return _ref.rwkv6_ref(r, k, v, w, u, state=state, chunk=chunk)


def moe_gmm(
    x: jax.Array,
    w: jax.Array,
    group_sizes: jax.Array,
    block_m: int = 128,
    block_n: int = 128,
) -> jax.Array:
    # block_m must divide every per-expert group (tiles may not straddle a
    # group boundary — the kernel picks one expert id per row tile); callers
    # with small groups pass the group size itself (see models/moe.py).
    if use_pallas():
        from repro.kernels.moe_gmm import moe_gmm_pallas

        return moe_gmm_pallas(x, w, group_sizes, block_m=block_m, block_n=block_n)
    return _ref.moe_gmm_ref(x, w, group_sizes)


def fused_ppo_loss(
    logits: jax.Array,          # [B, A]
    values: jax.Array,          # [B]
    actions: jax.Array,         # [B] int
    behaviour_logp: jax.Array,  # [B]
    advantages: jax.Array,      # [B]
    returns: jax.Array,         # [B]
    clip_eps: float = 0.2,
    vf_coef: float = 0.5,
    ent_coef: float = 0.01,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """PPO clipped-surrogate loss downstream of ``logits_value``: Pallas-
    fused per-row terms on TPU (differentiable — the kernel carries a
    hand-written Pallas backward via ``jax.custom_vjp``), the bit-identical
    jnp math of the historical ``rl/policy.py`` loss on CPU.

    Returns ``(loss, aux)`` with the same aux dict the in-policy loss
    produced: ``{"pg_loss", "vf_loss", "entropy", "kl"}``.
    """
    if use_pallas():
        from repro.kernels.surrogate import ppo_surrogate_pallas

        pg_i, vf_i, ent_i, kl_i = ppo_surrogate_pallas(
            logits, values, actions, behaviour_logp, advantages, returns,
            clip_eps=clip_eps,
        )
    else:
        pg_i, vf_i, ent_i, kl_i = _ref.ppo_surrogate_ref(
            logits, values, actions, behaviour_logp, advantages, returns,
            clip_eps=clip_eps,
        )
    pg = jnp.mean(pg_i)
    vf = jnp.mean(vf_i)
    ent = jnp.mean(ent_i)
    kl = jnp.mean(kl_i)
    loss = pg + vf_coef * vf - ent_coef * ent
    return loss, {"pg_loss": pg, "vf_loss": vf, "entropy": ent, "kl": kl}


# The advantage-estimation oracles live with the RL numerics
# (``repro.rl.advantages``); imported lazily so ``repro.rl`` package init
# (which imports workers that import this module) never re-enters a
# partially-initialized ``repro.kernels.ops``.
def fused_gae(
    rewards: jax.Array,
    values: jax.Array,
    dones: jax.Array,
    last_value: jax.Array,
    gamma: float = 0.99,
    lam: float = 0.95,
):
    """GAE over time-major [T, ...]: Pallas-fused on TPU, lax.scan on CPU."""
    if use_pallas():
        from repro.kernels.advantages import gae_pallas

        return gae_pallas(rewards, values, dones, last_value, gamma=gamma, lam=lam)
    from repro.rl.advantages import gae

    return gae(rewards, values, dones, last_value, gamma=gamma, lam=lam)


def fused_vtrace(
    behaviour_logp: jax.Array,
    target_logp: jax.Array,
    rewards: jax.Array,
    values: jax.Array,
    dones: jax.Array,
    last_value: jax.Array,
    gamma: float = 0.99,
    rho_clip: float = 1.0,
    c_clip: float = 1.0,
):
    """V-trace over time-major [T, ...]: Pallas-fused on TPU, lax.scan on CPU."""
    if use_pallas():
        from repro.kernels.advantages import vtrace_pallas

        return vtrace_pallas(
            behaviour_logp, target_logp, rewards, values, dones, last_value,
            gamma=gamma, rho_clip=rho_clip, c_clip=c_clip,
        )
    from repro.rl.advantages import vtrace

    return vtrace(
        behaviour_logp, target_logp, rewards, values, dones, last_value,
        gamma=gamma, rho_clip=rho_clip, c_clip=c_clip,
    )
