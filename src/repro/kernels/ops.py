"""Dispatch layer for compute hot-spots: Pallas TPU kernels with jnp fallback.

On TPU the Pallas implementations run (``pl.pallas_call`` with VMEM
BlockSpecs); on CPU (this container, incl. the 512-device dry-run) the
pure-jnp references run — identical math, so tests and the dry-run roofline
are faithful to the computation while kernels are validated separately in
``interpret=True`` mode (tests/test_kernels_*.py).

Set ``repro.kernels.ops.FORCE_MODE`` to 'pallas' | 'ref' | None (auto).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

FORCE_MODE: Optional[str] = None  # None -> auto by backend

__all__ = [
    "flash_attention",
    "decode_attention",
    "rwkv6",
    "moe_gmm",
    "fused_gae",
    "fused_vtrace",
    "use_pallas",
]


def use_pallas() -> bool:
    if FORCE_MODE == "pallas":
        return True
    if FORCE_MODE == "ref":
        return False
    return jax.default_backend() == "tpu"


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    if use_pallas():
        from repro.kernels.flash_attention import flash_attention_pallas

        return flash_attention_pallas(q, k, v, causal=causal, window=window, q_offset=q_offset)
    return _ref.chunked_attention(q, k, v, causal=causal, window=window, q_offset=q_offset)


def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, valid: jax.Array
) -> jax.Array:
    if use_pallas():
        from repro.kernels.decode_attention import decode_attention_pallas

        return decode_attention_pallas(q, k_cache, v_cache, valid)
    return _ref.decode_attention_ref(q, k_cache, v_cache, valid)


def rwkv6(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: Optional[jax.Array] = None,
    chunk: int = 64,
):
    if use_pallas():
        from repro.kernels.rwkv6 import rwkv6_pallas

        return rwkv6_pallas(r, k, v, w, u, state=state, chunk=chunk)
    # jnp fallback: exact sequential recurrence, chunk-rematted (the TPU win
    # of the Pallas kernel is keeping the [N,N] state in VMEM across the
    # time loop).
    return _ref.rwkv6_ref(r, k, v, w, u, state=state, chunk=chunk)


def moe_gmm(x: jax.Array, w: jax.Array, group_sizes: jax.Array) -> jax.Array:
    if use_pallas():
        from repro.kernels.moe_gmm import moe_gmm_pallas

        return moe_gmm_pallas(x, w, group_sizes)
    return _ref.moe_gmm_ref(x, w, group_sizes)


# The advantage-estimation oracles live with the RL numerics
# (``repro.rl.advantages``); imported lazily so ``repro.rl`` package init
# (which imports workers that import this module) never re-enters a
# partially-initialized ``repro.kernels.ops``.
def fused_gae(
    rewards: jax.Array,
    values: jax.Array,
    dones: jax.Array,
    last_value: jax.Array,
    gamma: float = 0.99,
    lam: float = 0.95,
):
    """GAE over time-major [T, ...]: Pallas-fused on TPU, lax.scan on CPU."""
    if use_pallas():
        from repro.kernels.advantages import gae_pallas

        return gae_pallas(rewards, values, dones, last_value, gamma=gamma, lam=lam)
    from repro.rl.advantages import gae

    return gae(rewards, values, dones, last_value, gamma=gamma, lam=lam)


def fused_vtrace(
    behaviour_logp: jax.Array,
    target_logp: jax.Array,
    rewards: jax.Array,
    values: jax.Array,
    dones: jax.Array,
    last_value: jax.Array,
    gamma: float = 0.99,
    rho_clip: float = 1.0,
    c_clip: float = 1.0,
):
    """V-trace over time-major [T, ...]: Pallas-fused on TPU, lax.scan on CPU."""
    if use_pallas():
        from repro.kernels.advantages import vtrace_pallas

        return vtrace_pallas(
            behaviour_logp, target_logp, rewards, values, dones, last_value,
            gamma=gamma, rho_clip=rho_clip, c_clip=c_clip,
        )
    from repro.rl.advantages import vtrace

    return vtrace(
        behaviour_logp, target_logp, rewards, values, dones, last_value,
        gamma=gamma, rho_clip=rho_clip, c_clip=c_clip,
    )
