"""Single-token decode attention Pallas TPU kernel.

One query token per sequence against a long KV cache: the compute is tiny,
the HBM traffic (streaming the cache) dominates — so the kernel's job is to
stream [block_w x D] K/V tiles through VMEM exactly once while carrying the
online-softmax state for the whole q-head group in VMEM scratch.

Grid: (B, KV, num_w_blocks) with the cache-window dim innermost/sequential.
The g = H/KV query heads of one group form the [g, D] matmul tile (padded to
the 8-row VREG sublane when g < 8 by the surrounding reshape).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_pallas"]

NEG_INF = -1e30


def _decode_kernel(
    q_ref,      # [1, 1, g, D]
    k_ref,      # [1, block_w, 1, D]
    v_ref,      # [1, block_w, 1, D]
    valid_ref,  # [1, block_w]  (per-sequence row of the [B, W] mask)
    o_ref,      # [1, 1, g, D]
    m_scr,      # [g]
    l_scr,      # [g]
    acc_scr,    # [g, D]
    *,
    scale: float,
    num_w_blocks: int,
):
    wi = pl.program_id(2)

    @pl.when(wi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # [g, D]
    k = k_ref[0, :, 0].astype(jnp.float32)       # [block_w, D]
    v = v_ref[0, :, 0].astype(jnp.float32)
    vmask = valid_ref[0][None, :]                # [1, block_w]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                     # [g, block_w]
    s = jnp.where(vmask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    # Mask the probabilities, not just the scores: in an all-invalid block
    # every score is NEG_INF, so exp(s - m_new) would be a uniform 1.0 and
    # the row normalizer l would count phantom mass (the empty-cache bug).
    p = jnp.where(vmask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(wi == num_w_blocks - 1)
    def _finalize():
        # l == 0 iff no cache slot was valid: attention over an empty cache
        # is defined as zeros, not a uniform average of garbage.
        l = l_scr[...]
        o = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        o_ref[0, 0] = jnp.where((l > 0.0)[:, None], o, 0.0).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,        # [B, 1, H, D]
    k_cache: jax.Array,  # [B, W, KV, D]
    v_cache: jax.Array,
    valid: jax.Array,    # [W] or [B, W] bool (per-sequence occupancy)
    block_w: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    B, _, H, D = q.shape
    W, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    if valid.ndim == 1:
        valid = jnp.broadcast_to(valid[None], (B, W))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_w = min(block_w, W)
    assert W % block_w == 0, "pad cache window to block multiple"
    nw = W // block_w

    qg = q.reshape(B, KV, g, D)  # group per kv head
    kernel = functools.partial(
        _decode_kernel, scale=1.0 / math.sqrt(D), num_w_blocks=nw
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nw),
        in_specs=[
            pl.BlockSpec((1, 1, g, D), lambda b, h, wi: (b, h, 0, 0)),
            pl.BlockSpec((1, block_w, 1, D), lambda b, h, wi: (b, wi, h, 0)),
            pl.BlockSpec((1, block_w, 1, D), lambda b, h, wi: (b, wi, h, 0)),
            pl.BlockSpec((1, block_w), lambda b, h, wi: (b, wi)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, D), lambda b, h, wi: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k_cache, v_cache, valid)
    return out.reshape(B, 1, H, D)
