"""Pure-jnp oracles for every Pallas kernel (correctness ground truth).

- ``naive_attention``   : O(S^2)-memory reference (small shapes, tests)
- ``chunked_attention`` : memory-bounded prefill oracle (same math, chunked)
- ``decode_attention_ref``: single-token attention against a KV cache
- ``rwkv6_ref``         : step-by-step WKV recurrence (data-dependent decay)
- ``moe_gmm_ref``       : grouped matmul over per-expert token groups
- ``ppo_surrogate_ref`` : per-row PPO surrogate terms (ratio/clip/min/
                          entropy/value error) — the fused-loss oracle
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "naive_attention",
    "chunked_attention",
    "decode_attention_ref",
    "rwkv6_ref",
    "moe_gmm_ref",
    "ppo_surrogate_ref",
]


def naive_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    """Full-materialization reference. q:[B,Sq,H,D], k/v:[B,Sk,KV,D]."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    qg = q.reshape(B, Sq, KV, g, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(B, Sq, H, v.shape[-1])


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Memory-bounded attention: peak score buffer [B, H, chunk, Sk]."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    scale = 1.0 / math.sqrt(D)
    orig_Sq = Sq
    if Sq % chunk:
        pad = chunk - Sq % chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Sq = q.shape[1]
    n_chunks = Sq // chunk
    qc = q.reshape(B, n_chunks, chunk, H, D).swapaxes(0, 1)  # [n, B, chunk, H, D]
    k_pos = jnp.arange(Sk)

    # Remat each chunk: the backward recomputes the [B,H,chunk,Sk] score
    # block instead of storing it (otherwise scan residuals reassemble the
    # full S^2 attention matrix).
    @jax.checkpoint
    def one_chunk(args):
        ci, qi = args  # qi: [B, chunk, H, D]
        qg = qi.reshape(B, chunk, KV, g, D)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32)
        scores = scores * scale
        q_pos = q_offset + ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((chunk, Sk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
        return out.reshape(B, chunk, H, v.shape[-1])

    out = jax.lax.map(one_chunk, (jnp.arange(n_chunks), qc))
    out = out.swapaxes(0, 1).reshape(B, Sq, H, v.shape[-1])
    return out[:, :orig_Sq]


def decode_attention_ref(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, valid: jax.Array
) -> jax.Array:
    """q: [B,1,H,D]; caches: [B,W,KV,D]; valid: [W] or [B,W] bool. -> [B,1,H,D]."""
    B, _, H, D = q.shape
    W, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    if valid.ndim == 1:
        valid = jnp.broadcast_to(valid[None], (B, W))
    qg = q.reshape(B, KV, g, D)
    scores = jnp.einsum("bhgd,bwhd->bhgw", qg, k_cache, preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(D)
    vmask = valid[:, None, None, :]  # [B, 1, 1, W]
    scores = jnp.where(vmask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    # Softmax over an all-invalid row is uniform over the -1e30 scores;
    # re-masking makes the empty-cache output exactly zero instead.
    p = jnp.where(vmask, p, 0.0).astype(v_cache.dtype)
    out = jnp.einsum("bhgw,bwhd->bhgd", p, v_cache)
    return out.reshape(B, 1, H, D)


def rwkv6_ref(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    state: Optional[jax.Array] = None,
    chunk: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """RWKV6 WKV recurrence, step-by-step (the oracle for the chunked kernel).

    r,k,v: [B,T,H,N]; w: [B,T,H,N] per-step decay in (0,1); u: [H,N] bonus.
    state: [B,H,N,N] (key x value). Returns (out [B,T,H,N], final state).

        o_t = r_t . (S_{t-1} + u * k_t^T v_t)
        S_t = diag(w_t) S_{t-1} + k_t^T v_t
    """
    B, T, H, N = r.shape
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # [B,H,N]
        kv = k_t[..., :, None] * v_t[..., None, :]  # [B,H,N,N]
        o = jnp.einsum("bhn,bhnm->bhm", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, o

    tm = lambda x: x.swapaxes(0, 1).astype(jnp.float32)  # [T,B,H,N]
    xs = (tm(r), tm(k), tm(v), tm(w))
    if chunk:
        from repro.models.scan_utils import chunked_scan

        state, out = chunked_scan(step, state, xs, chunk=chunk)
    else:
        state, out = jax.lax.scan(step, state, xs)
    return out.swapaxes(0, 1).astype(r.dtype), state


def ppo_surrogate_ref(
    logits: jax.Array,          # [B, A]
    values: jax.Array,          # [B]
    actions: jax.Array,         # [B] int
    behaviour_logp: jax.Array,  # [B]
    advantages: jax.Array,      # [B]
    returns: jax.Array,         # [B]
    clip_eps: float = 0.2,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-row PPO surrogate terms, op-for-op the ``rl/policy.py`` PPO loss
    downstream of ``logits_value`` (the CPU path of ``ops.fused_ppo_loss``
    is bit-identical to the historical in-policy loss).  Returns
    (pg_i, vf_i, ent_i, kl_i), each [B]; batch means + coefficient
    combination happen in the dispatcher, shared with the kernel path."""
    logp_all = jax.nn.log_softmax(logits)
    logp = jnp.take_along_axis(
        logp_all, actions.astype(jnp.int32)[:, None], axis=-1
    )[:, 0]
    entropy = -jnp.sum(jnp.exp(logp_all) * logp_all, axis=-1)
    ratio = jnp.exp(logp - behaviour_logp)
    unclipped = ratio * advantages
    clipped = jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * advantages
    pg = -jnp.minimum(unclipped, clipped)
    vf = jnp.square(values - returns)
    kl = behaviour_logp - logp
    return pg, vf, entropy, kl


def moe_gmm_ref(x: jax.Array, w: jax.Array, group_sizes: jax.Array) -> jax.Array:
    """Grouped matmul oracle: rows of x are grouped by expert (sorted order);
    group_sizes: [E] rows per expert; w: [E, D, F].  Returns [T, F].

    Equivalent dense form: each row multiplied by its group's weight.
    """
    T = x.shape[0]
    ends = jnp.cumsum(group_sizes)
    row = jnp.arange(T)
    # expert id per row from group sizes
    eid = jnp.sum(row[:, None] >= ends[None, :], axis=-1)
    return jnp.einsum("td,tdf->tf", x, w[eid])
