"""Pallas TPU kernel for the fused PPO surrogate loss.

The PPO learn step's elementwise hot loop — log-softmax, action gather,
ratio = exp(logp - logp_old), the clipped-surrogate min, the value-function
square error, and the entropy bonus — is a chain of small XLA ops that each
stream the [B]-row batch through HBM.  This kernel fuses the whole chain
into one pass over lane-aligned batch panels: logits live as an [A, block_b]
panel (A = num_actions on the sublane dim, batch on the lanes), every
intermediate stays in VMEM/VREGs, and HBM traffic is exactly the six input
streams plus the four per-row output terms.

The kernel emits *per-row* terms (pg_i, vf_i, ent_i, kl_i); the batch-mean
reductions and the ``pg + vf_coef*vf - ent_coef*ent`` combination happen in
the dispatch wrapper (``repro.kernels.ops.fused_ppo_loss``) so padding rows
are sliced off before any reduction and the scalar epilogue is shared
bit-for-bit with the CPU reference path.

``pallas_call`` has no transpose rule, but the surrogate loss *must* be
differentiable (it is the training objective), so the op is wrapped in
``jax.custom_vjp`` with a hand-written backward that is itself a Pallas
kernel over the same panels.  The backward mirrors JAX's subgradient
conventions exactly — ``lax.min``/``max`` split ties 0.5/0.5 (the
"balanced_eq" rule), which matters here because ``min(ratio*adv,
clip(ratio)*adv)`` ties *identically* whenever the ratio is inside the clip
band — so gradients match ``jax.grad`` of the jnp oracle to float rounding
(parity-tested to 1e-5 in ``tests/test_kernel_surrogate.py``).

On CPU (this container) the kernels run under ``interpret=True``; the
dispatch layer selects the jnp reference on CPU and this kernel on TPU.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ppo_surrogate_pallas"]

_BLOCK_B = 128  # lane dimension of one batch panel


def _softmax_terms(logits, onehot):
    """Shared fwd/bwd recompute: (logp_all, p, logp, entropy) from an
    [A, Bb] logits panel.  Same max-shift as ``jax.nn.log_softmax``."""
    m = jnp.max(logits, axis=0, keepdims=True)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m), axis=0, keepdims=True))
    logp_all = logits - lse  # [A, Bb]
    p = jnp.exp(logp_all)
    logp = jnp.sum(onehot * logp_all, axis=0, keepdims=True)  # [1, Bb]
    entropy = -jnp.sum(p * logp_all, axis=0, keepdims=True)
    return logp_all, p, logp, entropy


def _fwd_kernel(
    logits_ref, onehot_ref, v_ref, blp_ref, adv_ref, ret_ref,
    pg_ref, vf_ref, ent_ref, kl_ref, *, clip_eps,
):
    logits = logits_ref[...].astype(jnp.float32)  # [A, Bb]
    onehot = onehot_ref[...].astype(jnp.float32)
    values = v_ref[...].astype(jnp.float32)  # [1, Bb]
    blp = blp_ref[...].astype(jnp.float32)
    adv = adv_ref[...].astype(jnp.float32)
    ret = ret_ref[...].astype(jnp.float32)

    _, _, logp, entropy = _softmax_terms(logits, onehot)
    ratio = jnp.exp(logp - blp)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    pg_ref[...] = (-jnp.minimum(unclipped, clipped)).astype(pg_ref.dtype)
    vf_ref[...] = jnp.square(values - ret).astype(vf_ref.dtype)
    ent_ref[...] = entropy.astype(ent_ref.dtype)
    kl_ref[...] = (blp - logp).astype(kl_ref.dtype)


def _balanced(x, z, y):
    """d/dx of min/max(x, y) evaluated at result z, matching JAX's
    ``_balanced_eq`` JVP rule: full gradient off-tie, 0.5 on a tie."""
    return jnp.where(x == z, jnp.where(y == z, 0.5, 1.0), 0.0)


def _bwd_kernel(
    logits_ref, onehot_ref, v_ref, blp_ref, adv_ref, ret_ref,
    gpg_ref, gvf_ref, gent_ref, gkl_ref,
    dlogits_ref, donehot_ref, dv_ref, dblp_ref, dadv_ref, dret_ref,
    *, clip_eps,
):
    logits = logits_ref[...].astype(jnp.float32)
    onehot = onehot_ref[...].astype(jnp.float32)
    values = v_ref[...].astype(jnp.float32)
    blp = blp_ref[...].astype(jnp.float32)
    adv = adv_ref[...].astype(jnp.float32)
    ret = ret_ref[...].astype(jnp.float32)
    gpg = gpg_ref[...].astype(jnp.float32)
    gvf = gvf_ref[...].astype(jnp.float32)
    gent = gent_ref[...].astype(jnp.float32)
    gkl = gkl_ref[...].astype(jnp.float32)

    logp_all, p, logp, _ = _softmax_terms(logits, onehot)
    ratio = jnp.exp(logp - blp)
    lo, hi = 1.0 - clip_eps, 1.0 + clip_eps
    mx = jnp.maximum(ratio, lo)
    rc = jnp.minimum(mx, hi)  # == clip(ratio, lo, hi)
    u = ratio * adv
    c = rc * adv
    mn = jnp.minimum(u, c)

    du = _balanced(u, mn, c)
    dc = _balanced(c, mn, u)
    # d clip/d ratio through max-then-min, each with the balanced tie rule.
    dcl = _balanced(ratio, mx, jnp.full_like(ratio, lo)) * _balanced(
        mx, rc, jnp.full_like(ratio, hi)
    )
    g_ratio = -gpg * (du * adv + dc * adv * dcl)
    g_logp = g_ratio * ratio - gkl

    # Cotangent into logp_all: the action gather plus the entropy term
    # dH/dlp_j = -p_j (lp_j + 1); then the log-softmax VJP t - p * sum(t).
    t = g_logp * onehot - gent * p * (logp_all + 1.0)
    dlogits = t - p * jnp.sum(t, axis=0, keepdims=True)

    dlogits_ref[...] = dlogits.astype(dlogits_ref.dtype)
    donehot_ref[...] = (g_logp * logp_all).astype(donehot_ref.dtype)
    dv_ref[...] = (gvf * 2.0 * (values - ret)).astype(dv_ref.dtype)
    dblp_ref[...] = (-g_ratio * ratio + gkl).astype(dblp_ref.dtype)
    dadv_ref[...] = (-gpg * (du * ratio + dc * rc)).astype(dadv_ref.dtype)
    dret_ref[...] = (-gvf * 2.0 * (values - ret)).astype(dret_ref.dtype)


def _pad_b(x: jax.Array, block: int) -> jax.Array:
    pad = (-x.shape[1]) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x


def _panel_call(kernel, inputs, out_rows, B, dtype, interpret, block_b):
    """Grid over lane-aligned batch panels; inputs/outputs are [rows_i, B]
    with per-array row counts (A for logits panels, 1 for flat rows)."""
    block_b = min(block_b, max(B, 1))
    padded = [_pad_b(x, block_b) for x in inputs]
    Bp = padded[0].shape[1]
    nb = Bp // block_b

    def _spec(rows: int) -> pl.BlockSpec:
        return pl.BlockSpec((rows, block_b), lambda b: (0, b))

    outs = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[_spec(x.shape[0]) for x in padded],
        out_specs=[_spec(r) for r in out_rows],
        out_shape=[jax.ShapeDtypeStruct((r, Bp), dtype) for r in out_rows],
        interpret=interpret,
    )(*padded)
    return [o[:, :B] for o in outs]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _surrogate_terms(clip_eps, block_b, interpret, logits_t, onehot_t, values, blp, adv, ret):
    """Per-row surrogate terms (pg_i, vf_i, ent_i, kl_i), each [B].

    logits_t/onehot_t are [A, B] (batch on lanes); the rest are [B].
    """
    A, B = logits_t.shape
    rows = [values[None, :], blp[None, :], adv[None, :], ret[None, :]]
    kernel = functools.partial(_fwd_kernel, clip_eps=clip_eps)
    outs = _panel_call(
        kernel, [logits_t, onehot_t] + rows, [1, 1, 1, 1],
        B, logits_t.dtype, interpret, block_b,
    )
    return tuple(o[0] for o in outs)


def _surrogate_terms_fwd(clip_eps, block_b, interpret, logits_t, onehot_t, values, blp, adv, ret):
    out = _surrogate_terms(
        clip_eps, block_b, interpret, logits_t, onehot_t, values, blp, adv, ret
    )
    return out, (logits_t, onehot_t, values, blp, adv, ret)


def _surrogate_terms_bwd(clip_eps, block_b, interpret, res, g):
    logits_t, onehot_t, values, blp, adv, ret = res
    gpg, gvf, gent, gkl = g
    A, B = logits_t.shape
    rows = [values, blp, adv, ret, gpg, gvf, gent, gkl]
    kernel = functools.partial(_bwd_kernel, clip_eps=clip_eps)
    outs = _panel_call(
        kernel,
        [logits_t, onehot_t] + [x[None, :] for x in rows],
        [A, A, 1, 1, 1, 1],
        B, logits_t.dtype, interpret, block_b,
    )
    dlogits_t, donehot_t = outs[0], outs[1]
    dv, dblp, dadv, dret = (o[0] for o in outs[2:])
    return dlogits_t, donehot_t, dv, dblp, dadv, dret


_surrogate_terms.defvjp(_surrogate_terms_fwd, _surrogate_terms_bwd)


def ppo_surrogate_pallas(
    logits: jax.Array,          # [B, A]
    values: jax.Array,          # [B]
    actions: jax.Array,         # [B] int
    behaviour_logp: jax.Array,  # [B]
    advantages: jax.Array,      # [B]
    returns: jax.Array,         # [B]
    clip_eps: float = 0.2,
    block_b: int = _BLOCK_B,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused per-row PPO surrogate terms; same math as the jnp reference
    (``repro.kernels.ref.ppo_surrogate_ref``).  Returns (pg, vf, ent, kl),
    each [B]; differentiable via a hand-written Pallas backward."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    # The action gather becomes a one-hot contraction inside the kernel;
    # built outside so the custom_vjp surface is all-float (the int actions
    # would otherwise need a float0 cotangent).
    onehot = jax.nn.one_hot(
        actions.astype(jnp.int32), logits.shape[-1], dtype=logits.dtype
    )
    return _surrogate_terms(
        float(clip_eps), int(block_b), bool(interpret),
        logits.T, onehot.T, values, behaviour_logp, advantages, returns,
    )
