"""Deterministic synthetic token pipeline.

Produces per-host shards of the global batch (standard multi-host input
pipeline contract: each host feeds its slice; the mesh assembles the global
array).  Deterministic in (seed, step, host) so restarts are reproducible —
consistent with the paper's weak-durability stance (§3): on failure we
restart from the checkpointed step and regenerate identical data.
"""

from __future__ import annotations

from typing import Any, Dict, Iterator

import numpy as np

from repro.configs.base import InputShape, ModelConfig

__all__ = ["TokenPipeline", "make_batch"]


def make_batch(
    cfg: ModelConfig,
    shape: InputShape,
    seed: int = 0,
    step: int = 0,
    host_id: int = 0,
    num_hosts: int = 1,
    dtype: Any = np.int32,
) -> Dict[str, np.ndarray]:
    """One deterministic batch shard for (cfg, shape, step, host)."""
    if shape.global_batch % num_hosts:
        raise ValueError(f"global_batch {shape.global_batch} % hosts {num_hosts} != 0")
    b = shape.global_batch // num_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, host_id, abs(hash(cfg.name)) % 2**31])
    )
    s = shape.seq_len
    out: Dict[str, np.ndarray] = {}
    if shape.kind == "decode":
        tok_s = 1
    else:
        tok_s = s
    if cfg.modality == "audio":
        tokens = rng.integers(0, cfg.vocab_size, (b, tok_s, cfg.num_codebooks), dtype=dtype)
    elif cfg.modality == "vlm" and shape.kind != "decode":
        text_s = tok_s - cfg.num_media_tokens
        tokens = rng.integers(0, cfg.vocab_size, (b, text_s), dtype=dtype)
        out["media_emb"] = rng.standard_normal(
            (b, cfg.num_media_tokens, cfg.d_model), dtype=np.float32
        )
    else:
        tokens = rng.integers(0, cfg.vocab_size, (b, tok_s), dtype=dtype)
    out["tokens"] = tokens
    if shape.kind == "train":
        # Next-token labels: shift by one within the same synthetic stream.
        labels = np.roll(tokens, -1, axis=1).astype(dtype)
        if cfg.modality != "audio":
            labels[:, -1] = -100  # mask the wrapped position
        out["labels"] = labels
    return out


class TokenPipeline:
    """Iterator of batch shards; integrates with the dataflow layer as a
    creation operator (each rollout/data actor owns one pipeline shard)."""

    def __init__(
        self,
        cfg: ModelConfig,
        shape: InputShape,
        seed: int = 0,
        host_id: int = 0,
        num_hosts: int = 1,
    ):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.step = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = make_batch(
            self.cfg, self.shape, self.seed, self.step, self.host_id, self.num_hosts
        )
        self.step += 1
        return batch

    # Worker-protocol alias so an ActorPool of pipelines feeds ParallelIterator.
    def sample(self) -> Dict[str, np.ndarray]:
        return next(self)
