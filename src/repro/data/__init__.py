from repro.data.pipeline import TokenPipeline, make_batch

__all__ = ["TokenPipeline", "make_batch"]
