"""Trip-count-aware HLO cost model.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, but our models
compile as ``lax.scan`` over blocks (and SSM time scans), so raw numbers
undercount by the trip count.  This module parses the optimized HLO text and
walks the call graph (entry -> fusions/whiles/conditionals), multiplying
while bodies by their trip count (extracted from the loop-condition compare
constant).

Counted per computation:
  * dot FLOPs:   2 * prod(result_dims) * prod(lhs contracting dims)
  * collective bytes: result-buffer sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute

The numbers are for the *per-device* partitioned program (SPMD module);
multiply by chip count for global totals where needed.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-gather-start", "all-gather",
    "all-reduce-start", "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute-start", "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _split_operands(arglist: str) -> List[str]:
    """Split an HLO operand list on top-level commas only.

    Operands may be typed (``f32[64,64]{1,0} %gte.4``), so commas inside
    ``[]``/``{}`` must not split.
    """
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in arglist:
        if ch in "[{(":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        parts.append(tail)
    return parts


def _operand_name(operand: str) -> str:
    """'f32[64,64]{1,0} %get-tuple-element.4' -> 'get-tuple-element.4'."""
    return operand.split()[-1].lstrip("%") if operand.split() else ""


def _operand_names(arglist: str) -> List[str]:
    return [_operand_name(o) for o in _split_operands(arglist)]


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _first_shape(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",")] if dims else []


@dataclass
class _Instr:
    name: str
    rhs: str
    shape: Optional[Tuple[str, List[int]]]


@dataclass
class HloCost:
    flops: float = 0.0
    coll_bytes: float = 0.0
    hbm_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    unknown_trip_counts: int = 0

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            self.flops * k,
            self.coll_bytes * k,
            self.hbm_bytes * k,
            {kk: v * k for kk, v in self.coll_by_kind.items()},
            self.unknown_trip_counts,
        )

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.coll_bytes += other.coll_bytes
        self.hbm_bytes += other.hbm_bytes
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        self.unknown_trip_counts += other.unknown_trip_counts


def _parse_computations(hlo: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    current: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if current is None:
            # computation header: '%name (args) -> type {' or 'ENTRY %name ...{'
            if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
                m = re.search(r"%?([\w.\-]+)\s*\(", stripped)
                if m:
                    current = m.group(1)
                    comps[current] = []
            continue
        if stripped == "}":
            current = None
            continue
        m = _INSTR_RE.match(stripped)
        if m:
            name, rhs = m.groups()
            comps[current].append(_Instr(name, rhs, _first_shape(rhs)))
    return comps


def _dot_flops(instr: _Instr, shapes: Dict[str, Tuple[str, List[int]]]) -> float:
    # result elems * 2 * prod(lhs contracting dims)
    if instr.shape is None:
        return 0.0
    res_elems = _shape_elems(",".join(map(str, instr.shape[1])))
    mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rhs)
    ops = re.search(r"\bdot\(([^)]*)\)", instr.rhs)
    if not ops:
        return 0.0
    operands = _split_operands(ops.group(1))
    contract = 1
    if mdims and operands:
        # Typed operands carry their shape inline; otherwise resolve by name.
        lhs = _first_shape(operands[0]) or shapes.get(_operand_name(operands[0]))
        if lhs:
            for d in mdims.group(1).split(","):
                if d:
                    contract *= lhs[1][int(d)]
    return 2.0 * res_elems * contract


def _trip_count(cond_instrs: List[_Instr]) -> Optional[int]:
    # The scan condition is 'lt(iter, C)'; find the compare and its constant.
    consts: Dict[str, int] = {}
    for ins in cond_instrs:
        mc = _CONST_RE.search(ins.rhs)
        if mc and ins.shape and ins.shape[0] in ("s32", "u32", "s64", "u64"):
            consts[ins.name] = int(mc.group(1))
    for ins in cond_instrs:
        if " compare(" in ins.rhs or ins.rhs.startswith("compare("):
            ops = re.search(r"compare\(([^)]*)\)", ins.rhs)
            if ops:
                names = [o.strip().lstrip("%") for o in ops.group(1).split(",")]
                for n in names:
                    if n in consts:
                        return consts[n]
    if len(consts) == 1:
        return next(iter(consts.values()))
    return None


def _param_access_bytes(comp: str, comps: Dict[str, List[_Instr]]) -> Dict[int, float]:
    """Per-parameter effective bytes for a fusion body: parameters consumed
    ONLY through dynamic-slice/gather are charged at slice size (a scan body
    dynamic-slicing one step of a [T, ...] stack reads step bytes, not the
    stack) — otherwise full size (caller charges it)."""
    out: Dict[int, float] = {}
    instrs = comps.get(comp, [])
    shapes = {i.name: i.shape for i in instrs if i.shape is not None}
    pidx: Dict[str, int] = {}
    for ins in instrs:
        m = re.search(r"parameter\((\d+)\)", ins.rhs)
        if m:
            pidx[ins.name] = int(m.group(1))
    for pname, i in pidx.items():
        slice_bytes = 0.0
        other_use = False
        for ins in instrs:
            ops_m = re.search(r"\b([a-z\-]+)\(([^)]*)\)", ins.rhs)
            if not ops_m:
                continue
            opnames = _operand_names(ops_m.group(2))
            if pname not in opnames:
                continue
            kind = ops_m.group(1)
            if kind in ("dynamic-slice", "gather", "slice") and opnames[0] == pname:
                if ins.shape is not None:
                    slice_bytes += _shape_elems(",".join(map(str, ins.shape[1]))) * _DTYPE_BYTES.get(ins.shape[0], 4)
            elif kind == "dynamic-update-slice" and opnames[0] == pname:
                # in-place update: charge the update region (2nd operand)
                upd = shapes.get(opnames[1]) if len(opnames) > 1 else None
                if upd is not None:
                    slice_bytes += 2 * _shape_elems(",".join(map(str, upd[1]))) * _DTYPE_BYTES.get(upd[0], 4)
            else:
                other_use = True
        if not other_use and slice_bytes > 0:
            out[i] = slice_bytes
    return out


_PARAM_EFF_CACHE: Dict[str, Dict[int, float]] = {}


def _cost_of(
    comp: str,
    comps: Dict[str, List[_Instr]],
    cache: Dict[str, HloCost],
    stack: Tuple[str, ...] = (),
) -> HloCost:
    if comp in cache:
        return cache[comp]
    if comp in stack or comp not in comps:
        return HloCost()
    out = HloCost()
    instrs = comps[comp]
    shapes = {i.name: i.shape for i in instrs if i.shape is not None}
    _param_eff_cache: Dict[str, Dict[int, float]] = _PARAM_EFF_CACHE

    def _size(shp) -> float:
        return _shape_elems(",".join(map(str, shp[1]))) * _DTYPE_BYTES.get(shp[0], 4)

    # Slice-like ops touch only the slice-sized region, not the full operand
    # (a scan body dynamic-slicing one step from a [T, ...] stack reads
    # step-bytes per iteration, and DUS writes in place on TPU).  Counting
    # operands at full size multiplied by trip counts overstates scan-model
    # HBM traffic by ~1000x.
    _SLICE_LIKE = (" dynamic-slice(", " gather(", " slice(")
    _DUS_LIKE = (" dynamic-update-slice(", " scatter(")

    def _site_bytes(ins: _Instr) -> float:
        """HBM traffic at a (fusion/op) call site."""
        res = _size(ins.shape) if ins.shape is not None else 0.0
        if any(k in f" {ins.rhs}" for k in _SLICE_LIKE):
            return 2.0 * res  # read slice + write result
        if any(k in f" {ins.rhs}" for k in _DUS_LIKE):
            # update region read+write; update operand is the smallest input
            ops_m = re.search(r"\b[a-z\-]+\(([^)]*)\)", ins.rhs)
            upd = res
            if ops_m:
                sizes = [
                    _size(shapes[o])
                    for o in _operand_names(ops_m.group(1))
                    if o in shapes
                ]
                if sizes:
                    upd = min(sizes)
            return 2.0 * upd
        total = res
        # Fusions: charge parameters at their effective (slice-aware) bytes.
        eff: Dict[int, float] = {}
        mcal = _CALLS_RE.search(ins.rhs)
        if mcal and " fusion(" in ins.rhs:
            eff = _param_eff_cache.setdefault(
                mcal.group(1), _param_access_bytes(mcal.group(1), comps)
            )
        ops_m = re.search(r"\b[a-z\-]+\(([^)]*)\)", ins.rhs)
        if ops_m:
            for oi, o in enumerate(_split_operands(ops_m.group(1))):
                shp = shapes.get(_operand_name(o)) or _first_shape(o)
                if shp is not None:
                    total += eff.get(oi, _size(shp)) if eff else _size(shp)
        return total

    _FREE = (" parameter(", " constant(", " get-tuple-element(", " tuple(", " bitcast(")
    for ins in instrs:
        rhs = ins.rhs
        if " dot(" in rhs or rhs.startswith("dot("):
            out.flops += _dot_flops(ins, shapes)
        for kind in _COLLECTIVES:
            if f" {kind}(" in rhs:
                base = kind.replace("-start", "")
                sz = 0
                for sm in _SHAPE_RE.finditer(rhs.split(kind + "(")[0]):
                    sz += _shape_elems(sm.group(2)) * _DTYPE_BYTES.get(sm.group(1), 4)
                out.coll_bytes += sz
                out.coll_by_kind[base] = out.coll_by_kind.get(base, 0.0) + sz
                break
        if not any(f in f" {rhs}" for f in _FREE) and " while(" not in rhs and " conditional(" not in rhs:
            out.hbm_bytes += _site_bytes(ins)
        if " while(" in rhs:
            mb, mc = _BODY_RE.search(rhs), _COND_RE.search(rhs)
            if mb:
                body_cost = _cost_of(mb.group(1), comps, cache, stack + (comp,))
                trips = _trip_count(comps.get(mc.group(1), [])) if mc else None
                if trips is None:
                    trips = 1
                    out.unknown_trip_counts += 1
                out.add(body_cost.scaled(trips))
            continue
        if " conditional(" in rhs:
            mbr = _BRANCHES_RE.search(rhs)
            if mbr:
                branch_costs = [
                    _cost_of(b.strip().lstrip("%"), comps, cache, stack + (comp,))
                    for b in mbr.group(1).split(",")
                ]
                if branch_costs:
                    worst = max(branch_costs, key=lambda c: c.flops + c.coll_bytes)
                    out.add(worst)
            continue
        mcalls = _CALLS_RE.search(rhs)
        if mcalls and (" fusion(" in rhs or " call(" in rhs or " custom-call(" in rhs):
            sub = _cost_of(mcalls.group(1), comps, cache, stack + (comp,))
            # bytes counted at the call site already; recurse compute/comm only
            out.add(HloCost(sub.flops, sub.coll_bytes, 0.0, sub.coll_by_kind, sub.unknown_trip_counts))
    cache[comp] = out
    return out


def analyze_hlo(hlo_text: str) -> HloCost:
    _PARAM_EFF_CACHE.clear()
    comps = _parse_computations(hlo_text)
    entry = None
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    cache: Dict[str, HloCost] = {}
    return _cost_of(entry, comps, cache)
