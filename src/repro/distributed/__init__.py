from repro.distributed.sharding import (
    DEFAULT_RULES,
    AxisRules,
    axis_rules_context,
    get_axis_rules,
    logical_spec,
    shard,
)

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "axis_rules_context",
    "get_axis_rules",
    "logical_spec",
    "shard",
]
