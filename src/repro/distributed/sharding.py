"""Logical-axis sharding: one rules table maps model dims to mesh axes.

Model code annotates tensors with *logical* axis names ("batch", "seq",
"heads", "d_ff", "experts", ...).  A rules table resolves logical names to
mesh axes (or None = replicated).  The same model code therefore runs on a
single CPU device (empty rules), a 256-chip pod, or a 512-chip 2-pod mesh —
only the rules change.  This is the SPMD half of DESIGN.md §3.

Default production rules (v5e 16×16 per pod):

    batch   -> ('pod', 'data')   # data parallel across pods and data axis
    fsdp    -> 'data'            # param/optimizer-state FSDP dim
    vocab   -> 'model'
    heads   -> 'model'           # tensor parallel attention
    kv_heads-> 'model'
    d_ff    -> 'model'           # tensor parallel MLP
    experts -> 'model'           # expert parallel MoE
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "axis_rules_context",
    "get_axis_rules",
    "logical_spec",
    "make_data_mesh",
    "shard",
]

MeshAxes = Union[None, str, Tuple[str, ...]]


def _prod(it) -> int:
    out = 1
    for x in it:
        out *= x
    return out


class AxisRules:
    def __init__(self, rules: Dict[str, MeshAxes], mesh: Optional[Mesh] = None):
        self.rules = dict(rules)
        self.mesh = mesh

    def resolve(
        self,
        logical: Sequence[Optional[str]],
        shape: Optional[Sequence[int]] = None,
    ) -> P:
        """Map a tuple of logical dim names to a PartitionSpec.

        Drops mesh axes that are not present in the bound mesh (so the same
        rules serve ('data','model') and ('pod','data','model') meshes) and —
        when ``shape`` is given — axes that do not divide the dim evenly
        (e.g. 40 heads on a 16-way model axis), avoiding GSPMD's padded
        uneven sharding and its involuntary full rematerializations.
        """
        mesh_axes = (
            dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
            if self.mesh is not None
            else None
        )
        used: set = set()
        out = []
        for i, name in enumerate(logical):
            axes = self.rules.get(name) if name else None
            if axes is None:
                out.append(None)
                continue
            if isinstance(axes, str):
                axes = (axes,)
            keep = []
            dim = shape[i] if shape is not None else None
            for a in axes:
                if mesh_axes is not None and a not in mesh_axes:
                    continue
                if a in used:
                    continue
                if dim is not None and mesh_axes is not None:
                    if dim % (mesh_axes[a] * _prod(mesh_axes[x] for x in keep)):
                        continue
                keep.append(a)
            used.update(keep)
            if not keep:
                out.append(None)
            elif len(keep) == 1:
                out.append(keep[0])
            else:
                out.append(tuple(keep))
        return P(*out)


DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "fsdp": "data",
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "d_ff": "model",
    "experts": "model",
    "seq": None,
    "d_model": None,
    "head_dim": None,
    "state": None,
    # Decode KV-cache context dim: sharded over 'model' (context parallelism)
    # so long caches fit regardless of kv-head divisibility.
    "window": "model",
}

def make_data_mesh(num_devices: int = 0) -> Mesh:
    """A 1-D ``('data',)`` mesh over the first ``num_devices`` devices.

    The mesh shape pure data parallelism wants (sharded learner groups,
    eval fan-out): one axis, batch dim sharded over it, everything else
    replicated.  ``num_devices <= 0`` takes every visible device; asking
    for more than are visible raises rather than silently shrinking —
    callers that want clamp-with-warning semantics (``ShardedLearnerGroup``)
    decide that policy themselves.  Simulate an N-device CPU mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    import numpy as np

    devices = jax.devices()
    n = num_devices if num_devices > 0 else len(devices)
    if n > len(devices):
        raise ValueError(
            f"make_data_mesh({num_devices}): only {len(devices)} devices "
            "visible (XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "simulates more on CPU)"
        )
    return Mesh(np.asarray(devices[:n]), ("data",))


_ctx = threading.local()


def get_axis_rules() -> Optional[AxisRules]:
    return getattr(_ctx, "rules", None)


@contextlib.contextmanager
def axis_rules_context(rules: AxisRules):
    prev = getattr(_ctx, "rules", None)
    _ctx.rules = rules
    try:
        yield rules
    finally:
        _ctx.rules = prev


def logical_spec(*logical: Optional[str]) -> P:
    """Resolve logical names to a PartitionSpec under the active rules."""
    rules = get_axis_rules()
    if rules is None:
        return P(*([None] * len(logical)))
    return rules.resolve(logical)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate an intermediate with a logical sharding constraint.

    No-op when no rules/mesh are active (single-device smoke tests).
    """
    rules = get_axis_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = rules.resolve(logical, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
