"""PartitionSpec derivation for params / optimizer state / caches / batches.

One rules table keyed by parameter leaf name (the last dict key in the tree
path).  Stacked block params (leading ``num_blocks`` dim from the scan) get a
``None`` prepended.  Specs resolve through the active ``AxisRules`` so the
same derivation serves the (data, model) and (pod, data, model) meshes.

Sharding strategy (DESIGN.md §6): tensor parallel on 'model' (heads / d_ff /
experts / vocab), FSDP on 'data' for the d_model dim of weight matrices and
optimizer moments, batch on ('pod','data').
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import AxisRules

PyTree = Any

__all__ = [
    "param_specs",
    "cache_specs",
    "batch_specs",
    "opt_state_specs",
    "tree_shardings",
]

# logical dims per param name (base ndim, logical names)
_PARAM_RULES = {
    # attention / projections: [d_model, out] -> fsdp x tensor
    "wq": ("fsdp", "heads"),
    "wk": ("fsdp", "kv_heads"),
    "wv": ("fsdp", "kv_heads"),
    "wr": ("fsdp", "heads"),
    "wg": ("fsdp", "heads"),
    "wo": ("heads", "fsdp"),
    "bq": ("heads",),
    "bk": ("kv_heads",),
    "bv": ("kv_heads",),
    "q_norm": (None,),
    "k_norm": (None,),
    # MLA
    "w_dkv": ("fsdp", None),
    "w_uk": (None, "heads", None),
    "w_uv": (None, "heads", None),
    # MLP (2D dense / 3D per-expert)
    "up": ("fsdp", "d_ff"),
    "gate": ("fsdp", "d_ff"),
    "down": ("d_ff", "fsdp"),
    "shared_up": ("fsdp", "d_ff"),
    "shared_gate": ("fsdp", "d_ff"),
    "shared_down": ("d_ff", "fsdp"),
    "router": ("fsdp", None),
    # SSM: mamba
    "in_proj": ("fsdp", "d_ff"),
    "conv_w": (None, "d_ff"),
    "conv_b": ("d_ff",),
    "x_proj": ("d_ff", None),
    "dt_proj": (None, "d_ff"),
    "dt_bias": ("d_ff",),
    "A_log": ("d_ff", None),
    "D": ("d_ff",),
    "out_proj": ("d_ff", "fsdp"),
    # SSM: rwkv6
    "decay_w0": (None,),
    "decay_w1": ("fsdp", None),
    "decay_w2": (None, "fsdp"),
    "bonus_u": ("heads", None),
    "mix": (None, None),
    "ln_out": (None,),
    # embeddings / head / norms
    "lm_head": ("fsdp", "vocab"),
    "final_norm": (None,),
    "norm1": (None,),
    "norm2": (None,),
}

_MOE_3D = {"up": ("experts", "fsdp", None), "gate": ("experts", "fsdp", None),
           "down": ("experts", None, "fsdp")}

_CACHE_RULES = {
    "k": ("batch", "window", "kv_heads", None),
    "v": ("batch", "window", "kv_heads", None),
    "k_q": ("batch", "window", "kv_heads", None),
    "k_s": ("batch", "window", "kv_heads", None),
    "v_q": ("batch", "window", "kv_heads", None),
    "v_s": ("batch", "window", "kv_heads", None),
    "c": ("batch", "window", None),       # MLA latent cache
    "k_rope": ("batch", "window", None),
    "wkv": ("batch", "heads", None, None),
    "x_prev": ("batch", None),
    "h": ("batch", "d_ff", None),
    "conv": ("batch", None, "d_ff"),
    "pos": (),
}


def _leaf_name(path: Tuple[Any, ...]) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def _is_stacked(path: Tuple[Any, ...]) -> bool:
    return any(hasattr(p, "key") and str(p.key) == "blocks" for p in path)


def _resolve(
    rules: AxisRules,
    logical: Sequence[Optional[str]],
    stacked: bool,
    shape: Optional[Sequence[int]] = None,
) -> P:
    if shape is not None and stacked:
        shape = shape[1:]
    spec = rules.resolve(list(logical), shape=shape)
    if stacked:
        spec = P(None, *spec)
    return spec


def param_specs(params_shape: PyTree, rules: AxisRules) -> PyTree:
    """PartitionSpec pytree matching a params (shape) pytree."""

    def spec_for(path, leaf):
        name = _leaf_name(path)
        stacked = _is_stacked(path)
        ndim = leaf.ndim - (1 if stacked else 0)
        if name == "embed":
            logical = ("vocab", "fsdp") if ndim == 2 else (None, "vocab", "fsdp")
        elif name in ("up", "gate", "down") and ndim == 3:
            logical = _MOE_3D[name]
        elif name in _PARAM_RULES:
            logical = _PARAM_RULES[name]
        else:
            logical = (None,) * ndim
        if len(logical) != ndim:
            raise ValueError(f"spec rank mismatch for {name}: {logical} vs ndim {ndim}")
        return _resolve(rules, logical, stacked, shape=leaf.shape)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def cache_specs(cache_shape: PyTree, rules: AxisRules) -> PyTree:
    def spec_for(path, leaf):
        name = _leaf_name(path)
        stacked = _is_stacked(path)
        ndim = leaf.ndim - (1 if stacked else 0)
        logical = _CACHE_RULES.get(name, (None,) * ndim)
        if len(logical) != ndim:
            logical = (None,) * ndim
        return _resolve(rules, logical, stacked, shape=leaf.shape)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def batch_specs(batch_shape: PyTree, rules: AxisRules) -> PyTree:
    def spec_for(path, leaf):
        logical = ("batch",) + (None,) * (leaf.ndim - 1)
        return rules.resolve(list(logical), shape=leaf.shape)

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


def opt_state_specs(opt_state_shape: PyTree, pspecs: PyTree, rules: AxisRules) -> PyTree:
    """Optimizer state specs: moments mirror param specs; counters replicate.

    Works for AdamState/SgdState NamedTuples whose mu/nu fields share the
    param tree structure.
    """
    param_treedef = jax.tree_util.tree_structure(pspecs)

    def map_field(field_shape):
        try:
            if jax.tree_util.tree_structure(field_shape) == param_treedef:
                return pspecs
        except Exception:
            pass
        return jax.tree_util.tree_map(lambda l: P(), field_shape)

    if hasattr(opt_state_shape, "_fields"):  # NamedTuple
        return type(opt_state_shape)(
            *[
                map_field(getattr(opt_state_shape, f)) if getattr(opt_state_shape, f) is not None else None
                for f in opt_state_shape._fields
            ]
        )
    return jax.tree_util.tree_map(lambda l: P(), opt_state_shape)


def tree_shardings(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
