"""HLO analysis: collective bytes + roofline terms from a compiled artifact.

``collective_bytes`` parses the (compiled, SPMD-partitioned) HLO text and
sums the result-buffer sizes of every collective op — the §Roofline
collective term numerator.  ``roofline`` combines it with
``compiled.cost_analysis()`` into the three roofline terms for TPU v5e.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

__all__ = ["collective_bytes", "roofline", "Roofline", "HW_V5E"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.:  %all-gather.3 = bf16[2,1024,128]{2,1,0} all-gather(...)
_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s(" + "|".join(_COLLECTIVES) + r")\("
)
# tuple-result collectives:  (bf16[...], bf16[...]) all-reduce(
_RE_TUPLE = re.compile(
    r"=\s*\(([^)]+)\)\s*(" + "|".join(_COLLECTIVES) + r")\("
)
_RE_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Bytes moved per collective kind (result-buffer sizes)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            continue
        mt = _RE_TUPLE.search(line)
        if mt:
            shapes, kind = mt.groups()
            for sm in _RE_SHAPE.finditer(shapes):
                out[kind] += _shape_bytes(*sm.groups())
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float        # per chip, bf16
    hbm_bw: float            # bytes/s per chip
    ici_bw: float            # bytes/s per link per chip


HW_V5E = Hardware("tpu-v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    bytes_per_device: Optional[float] = None

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def row(self) -> Dict[str, Any]:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
            "bytes_per_device": self.bytes_per_device,
        }


def roofline(
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    coll: Dict[str, int],
    model_flops: float,
    hw: Hardware = HW_V5E,
    bytes_per_device: Optional[float] = None,
) -> Roofline:
    """cost: compiled.cost_analysis(); coll: collective_bytes() output.

    NOTE: cost_analysis flops/bytes are *global* (whole-program, all shards);
    divide by chips for per-chip time.  collective bytes likewise summed over
    the program; ICI time uses per-chip link bandwidth.
    """
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll.get("total", 0))
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=byts,
        coll_bytes=cbytes,
        compute_s=flops / (chips * hw.peak_flops),
        memory_s=byts / (chips * hw.hbm_bw),
        collective_s=cbytes / (chips * hw.ici_bw),
        model_flops=model_flops,
        bytes_per_device=bytes_per_device,
    )
