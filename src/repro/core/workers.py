"""WorkerSet: the local + remote rollout-worker group used by plans.

Mirrors RLlib's WorkerSet: one *local* worker (driver-side; owns the canonical
policy used by TrainOneStep/ApplyGradients) plus N *remote* workers (virtual
actors) that sample in parallel.  The protocol any worker target must satisfy:

    sample() -> SampleBatch
    get_weights() -> pytree
    set_weights(weights) -> None
    compute_gradients(batch) -> (grads, info)
    apply_gradients(grads) -> info
    learn_on_batch(batch) -> info
"""

from __future__ import annotations

from typing import Any, Callable, List, Optional

from repro.core.actor import ActorPool, VirtualActor

__all__ = ["WorkerSet"]


class WorkerSet:
    def __init__(self, local_worker: Any, remote_workers: ActorPool):
        self._local = local_worker
        self._remote = remote_workers

    @classmethod
    def create(
        cls, worker_factory: Callable[[int], Any], num_workers: int
    ) -> "WorkerSet":
        """Build a local worker (index 0) and ``num_workers`` remote actors."""
        local = worker_factory(0)
        remote = ActorPool.from_targets(
            [worker_factory(i + 1) for i in range(num_workers)], name="rollout_workers"
        )
        return cls(local, remote)

    def local_worker(self) -> Any:
        return self._local

    def remote_workers(self) -> ActorPool:
        return self._remote

    def sync_weights(self) -> None:
        """Broadcast local weights to all remote workers (global barrier)."""
        weights = self._local.get_weights()
        for f in self._remote.broadcast("set_weights", weights):
            f.result()

    def stop(self) -> None:
        self._remote.stop()
