"""WorkerSet: the local + remote rollout-worker group used by plans.

Mirrors RLlib's WorkerSet: one *local* worker (driver-side; owns the canonical
policy used by TrainOneStep/ApplyGradients) plus N *remote* workers (virtual
actors) that sample in parallel.  The protocol any worker target must satisfy:

    sample() -> SampleBatch
    get_weights() -> pytree
    set_weights(weights) -> None
    compute_gradients(batch) -> (grads, info)
    apply_gradients(grads) -> info
    learn_on_batch(batch) -> info

Fault tolerance / elasticity (executor runtime):

  * ``create(..., backend="process", max_restarts=2, failure_policy="drop_shard")``
    builds supervised workers on any execution backend; the factory is kept
    so workers can be rebuilt.
  * ``sync_weights`` skips dead workers instead of poisoning the caller.
  * ``add_workers``/``remove_workers`` resize the group mid-training (the
    pool version bump makes pool-aware gather loops pick up the change).
  * ``recover`` restarts dead workers in place (factory rebuild) or replaces
    them with fresh actors, then re-broadcasts the canonical weights.
"""

from __future__ import annotations

import functools
import logging
from typing import Any, Callable, Dict, List, Optional

from repro.core.actor import ActorPool, VirtualActor
from repro.core.executor import FailurePolicy

__all__ = ["WorkerSet"]

logger = logging.getLogger(__name__)


class WorkerSet:
    def __init__(
        self,
        local_worker: Any,
        remote_workers: ActorPool,
        worker_factory: Optional[Callable[[int], Any]] = None,
        actor_kwargs: Optional[Dict[str, Any]] = None,
    ):
        self._local = local_worker
        self._remote = remote_workers
        self._factory = worker_factory
        self._actor_kwargs = dict(actor_kwargs or {})
        self._next_index = len(remote_workers) + 1
        # Extra consumers of weight broadcasts beyond the rollout actors —
        # e.g. decoupled InferenceActors serving this set's policy.
        self._weight_sinks: List[Callable[[Any], None]] = []

    @classmethod
    def create(
        cls,
        worker_factory: Callable[[int], Any],
        num_workers: int,
        *,
        backend: Any = None,
        transport: Any = None,
        max_restarts: int = 0,
        backoff_base: float = 0.05,
        backoff_cap: float = 2.0,
        failure_policy: str = FailurePolicy.RAISE,
        restart_window_s: Optional[float] = None,
    ) -> "WorkerSet":
        """Build a local worker (index 0) and ``num_workers`` remote actors.

        ``backend`` selects the execution vehicle ("thread" | "process" | an
        ``ExecutionBackend``); supervision kwargs configure restart budget,
        backoff, and the failure policy gather operators honor.  For the
        process backend ``worker_factory`` must be picklable (module-level).

        ``transport`` selects the process data plane ("shm" | "pickle" | a
        ``Transport`` instance; see ``core.transport``) when ``backend`` is
        given as a string; thread backends ignore it (already zero-copy).
        """
        if transport is not None:
            if not isinstance(backend, str):
                # backend=None would silently build ThreadBackend and drop
                # the transport — reject both that and instance backends.
                raise ValueError(
                    'transport= requires a backend name (e.g. backend="process"); '
                    "for a backend instance, configure its transport directly"
                )
            from repro.core.executor import BACKENDS

            if backend not in BACKENDS:
                raise ValueError(f"unknown backend {backend!r}; known: {sorted(BACKENDS)}")
            backend = BACKENDS[backend](transport=transport)
        local = worker_factory(0)
        actor_kwargs = dict(
            backend=backend,
            max_restarts=max_restarts,
            backoff_base=backoff_base,
            backoff_cap=backoff_cap,
            failure_policy=failure_policy,
            restart_window_s=restart_window_s,
        )
        actors = [
            cls._make_actor(worker_factory, i + 1, actor_kwargs)
            for i in range(num_workers)
        ]
        pool = ActorPool(actors, name="rollout_workers")
        return cls(local, pool, worker_factory, actor_kwargs)

    @staticmethod
    def _make_actor(
        factory: Callable[[int], Any], index: int, actor_kwargs: Dict[str, Any]
    ) -> VirtualActor:
        actor = VirtualActor(
            factory=functools.partial(factory, index),
            name=f"rollout-{index}",
            **actor_kwargs,
        )
        actor.worker_index = index  # type: ignore[attr-defined]
        return actor

    def local_worker(self) -> Any:
        return self._local

    def remote_workers(self) -> ActorPool:
        return self._remote

    def healthy_workers(self) -> List[VirtualActor]:
        return self._remote.alive_actors()

    def num_healthy_workers(self) -> int:
        return len(self.healthy_workers())

    def sync_weights(self) -> None:
        """Broadcast local weights to all live remote workers.

        Dead workers are skipped, and failures on workers whose policy
        absorbs faults (restart/drop_shard) are logged so one lost rollout
        worker cannot poison a TrainOneStep weight broadcast.  Workers under
        the default RAISE policy keep the legacy global-barrier semantics:
        their failure propagates to the driver.
        """
        weights = self._local.get_weights()
        futures = []
        for actor in self._remote:
            if not getattr(actor, "alive", True):
                continue
            try:
                futures.append((actor, actor.call("set_weights", weights)))
            except RuntimeError:
                continue  # stopped between the alive check and the call
        for actor, f in futures:
            try:
                f.result()
            except Exception as exc:
                policy = getattr(actor, "failure_policy", FailurePolicy.RAISE)
                if policy == FailurePolicy.RAISE and getattr(actor, "alive", True):
                    raise
                logger.warning("sync_weights: worker %s failed: %s", actor.name, repr(exc))
        for sink in self._weight_sinks:
            try:
                sink(weights)
            except Exception as exc:
                # Sinks heal themselves (InferenceClient.recover); a dead
                # server must not poison a rollout-worker broadcast.
                logger.warning("sync_weights: weight sink failed: %s", repr(exc))

    def add_weight_sink(self, sink: Callable[[Any], None]) -> None:
        """Register an extra weight-broadcast consumer (e.g. the decoupled
        inference server's ``InferenceClient.sync_weights``)."""
        self._weight_sinks.append(sink)

    def remove_weight_sink(self, sink: Callable[[Any], None]) -> None:
        """Unregister a weight sink (no-op if absent).  Flows that register
        a sink for a resource they own must remove it on stop — a shared
        WorkerSet outlives any one compiled flow."""
        try:
            self._weight_sinks.remove(sink)
        except ValueError:
            pass

    # ------------------------------------------------------------- elastic
    def add_workers(self, num_workers: int) -> List[VirtualActor]:
        """Grow the remote group mid-training; new workers get the canonical
        weights and join pool-aware gather loops via the version bump."""
        if self._factory is None:
            raise RuntimeError("WorkerSet has no factory; build it with WorkerSet.create")
        added = []
        weights = self._local.get_weights()
        for _ in range(num_workers):
            actor = self._make_actor(self._factory, self._next_index, self._actor_kwargs)
            self._next_index += 1
            actor.call("set_weights", weights)
            self._remote.add(actor)
            added.append(actor)
        return added

    def remove_workers(self, num_workers: int = 1) -> List[str]:
        """Shrink the remote group from the tail (at least one must remain)."""
        if num_workers >= len(self._remote):
            raise ValueError(
                f"cannot remove {num_workers} of {len(self._remote)} workers; "
                "at least one remote worker must remain"
            )
        removed = []
        for _ in range(num_workers):
            actor = self._remote[len(self._remote) - 1]
            self._remote.remove(actor, stop=True)
            removed.append(actor.name)
        return removed

    def recover(self) -> Dict[str, List[str]]:
        """Heal the group: restart dead workers in place (factory rebuild),
        or replace them with fresh actors when in-place restart fails, then
        re-broadcast the canonical weights.  Returns what was done."""
        report: Dict[str, List[str]] = {"restarted": [], "replaced": [], "failed": []}
        for actor in list(self._remote):
            if getattr(actor, "alive", True):
                continue
            try:
                actor.restart(timeout=5.0)
                report["restarted"].append(actor.name)
                continue
            except Exception as exc:
                logger.warning("recover: in-place restart of %s failed: %s", actor.name, repr(exc))
            if self._factory is None:
                report["failed"].append(actor.name)
                continue
            index = getattr(actor, "worker_index", self._next_index)
            if index == self._next_index:
                self._next_index += 1
            replacement = self._make_actor(self._factory, index, self._actor_kwargs)
            self._remote.replace(actor, replacement, stop_old=True)
            report["replaced"].append(replacement.name)
        if report["restarted"] or report["replaced"]:
            self.sync_weights()
        return report

    def stop(self) -> None:
        self._remote.stop()
