"""Pluggable execution backends + actor supervision (the executor runtime).

The paper's dataflow shards run on Ray actors and inherit Ray's fault
tolerance for free.  Our virtual actors were thread-only: one worker
exception poisoned the whole flow.  This module makes the execution vehicle
pluggable (MSRL: dataflow fragments must be remappable across heterogeneous
backends) and supervised (SRL: scaling hinges on decoupled, restartable
worker groups):

  * ``ThreadBackend``  — a mailbox thread per actor, target lives in-process
    (the original semantics; JAX releases the GIL inside compiled code so
    device compute still overlaps).
  * ``ProcessBackend`` — the target is built *inside a child process* from a
    pickled factory ("picklable-target transport"); method calls are RPCs
    over a pipe.  ``apply()`` still works with arbitrary closures: the
    closure runs driver-side against a proxy whose method calls round-trip
    to the child, so only method arguments/results must be picklable.
    *Result payloads* cross a pluggable data plane (``core.transport``):
    by default large ``SampleBatch`` columns move through shared-memory
    ring segments (header-only pipe messages, refcounted reclaim) instead
    of being pickled — pass ``ProcessBackend(transport="pickle")`` for the
    pipe baseline.
  * ``SupervisorSpec`` — ``max_restarts`` with exponential backoff, plus a
    ``FailurePolicy`` (restart / drop_shard / raise) that the gather
    operators in ``core.iterators`` and ``WorkerSet`` honor: a dead rollout
    worker shrinks the shard set instead of poisoning the stream.

``VirtualActor`` (``core.actor``) keeps its public API and delegates the
execution locus to a backend *cell*; everything above the actor layer is
backend-agnostic.
"""

from __future__ import annotations

import itertools
import logging
import multiprocessing
import os
import pickle
import weakref
from abc import ABC, abstractmethod
from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.core.transport import Transport, resolve_transport

__all__ = [
    "ActorError",
    "ActorDiedError",
    "FailurePolicy",
    "SupervisorSpec",
    "ExecutionBackend",
    "ThreadBackend",
    "ProcessBackend",
    "BACKENDS",
    "resolve_backend",
]

_logger = logging.getLogger(__name__)

_cell_seq = itertools.count()


class ActorError(RuntimeError):
    """A failure attributable to a (virtual) actor's execution vehicle."""


class ActorDiedError(ActorError):
    """The actor's execution vehicle is gone (process exit, restart budget
    exhausted, explicit ``kill()``).  Gather operators treat this as a shard
    loss, never as a recoverable item failure."""


class FailurePolicy:
    """What the *consumers* of an actor do when one of its calls fails.

    RAISE      -> propagate to the driver (legacy behaviour, default).
    RESTART    -> the supervisor restarts the target (factory rebuild with
                  exponential backoff); the failed item is skipped and the
                  shard stays in the set.  Once the restart budget is
                  exhausted the actor dies and the shard is dropped.
    DROP_SHARD -> remove the shard from the iterator's active set on first
                  failure; the stream continues with the survivors.
    """

    RAISE = "raise"
    RESTART = "restart"
    DROP_SHARD = "drop_shard"
    ALL = frozenset((RAISE, RESTART, DROP_SHARD))

    @classmethod
    def validate(cls, policy: str) -> str:
        if policy not in cls.ALL:
            raise ValueError(
                f"unknown failure policy {policy!r}; expected one of {sorted(cls.ALL)}"
            )
        return policy


@dataclass(frozen=True)
class SupervisorSpec:
    """Restart budget + backoff schedule + consumer-facing failure policy.

    ``max_restarts`` on its own is a *lifetime* budget: a long-lived actor
    that crashes occasionally exhausts it and dies permanently even after
    hours of health between failures.  ``restart_window_s`` fixes that — an
    actor that stays healthy for a full window gets its prior-restart
    counter (and with it the backoff exponent) forgiven, so the budget only
    bounds *crash loops*, not total failures over the actor's life.
    ``None`` keeps the legacy lifetime-budget semantics.
    """

    max_restarts: int = 0
    backoff_base: float = 0.05
    backoff_cap: float = 2.0
    failure_policy: str = FailurePolicy.RAISE
    restart_window_s: Optional[float] = None

    def __post_init__(self) -> None:
        FailurePolicy.validate(self.failure_policy)
        if self.max_restarts < 0:
            raise ValueError("max_restarts must be >= 0")
        if self.backoff_base < 0 or self.backoff_cap < 0:
            raise ValueError("backoff must be >= 0")
        if self.restart_window_s is not None and self.restart_window_s <= 0:
            raise ValueError("restart_window_s must be > 0 (or None for a lifetime budget)")

    def backoff(self, n_prior_restarts: int) -> float:
        return min(self.backoff_base * (2.0 ** n_prior_restarts), self.backoff_cap)


# --------------------------------------------------------------------------
# Cells: the execution locus behind one actor
# --------------------------------------------------------------------------
class Cell(ABC):
    """Owns the target object (or a proxy to it) for one actor."""

    @property
    @abstractmethod
    def target(self) -> Any:
        """The object method calls are dispatched onto (real or proxy)."""

    @property
    @abstractmethod
    def alive(self) -> bool:
        """Whether the execution vehicle can still run calls."""

    @abstractmethod
    def restart(self) -> None:
        """Rebuild the target from its factory (fresh state)."""

    @abstractmethod
    def stop(self) -> None:
        """Graceful shutdown of the vehicle (idempotent)."""

    @abstractmethod
    def kill(self) -> None:
        """Forceful shutdown (process terminate; best-effort for threads)."""


class ThreadCell(Cell):
    """Target lives in-process; the actor's mailbox thread calls it directly."""

    def __init__(self, factory: Optional[Callable[[], Any]] = None, target: Any = None):
        self._factory = factory
        self._target = target if target is not None else factory()  # type: ignore[misc]

    @property
    def target(self) -> Any:
        return self._target

    @property
    def alive(self) -> bool:
        return True

    def restart(self) -> None:
        if self._factory is None:
            raise ActorError("thread cell has no factory; target is not restartable")
        self._target = self._factory()

    def stop(self) -> None:
        pass

    def kill(self) -> None:
        # Threads cannot be preempted; the actor layer marks itself dead and
        # fails queued work.  A call already executing cannot be interrupted.
        pass


def _serve(conn: Any, payload: bytes, transport_payload: bytes) -> None:
    """Child-process loop: build the target from its pickled factory, then
    execute (method, args, kwargs, released_segments) requests until
    shutdown/EOF.  Results cross back through the cell's transport: the
    shared-memory transport replaces large numpy payloads with header-only
    refs; the pipe carries everything else verbatim."""
    spec, prefix = pickle.loads(transport_payload)
    encoder = spec.server_endpoint(prefix)
    try:
        target = pickle.loads(payload)()
    except BaseException as exc:  # construction failure: report and exit
        try:
            conn.send((False, ActorError(f"target construction failed: {exc!r}")))
        except Exception:
            pass
        encoder.close()
        return
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            encoder.close()
            return
        if msg is None:
            encoder.close()
            conn.close()
            return
        method, args, kwargs, released = msg
        encoder.reclaim(released)
        try:
            result = getattr(target, method)(*args, **kwargs)
        except BaseException as exc:
            try:
                conn.send((False, exc))
            except Exception:  # unpicklable exception: degrade to a summary
                conn.send((False, ActorError(f"{type(exc).__name__}: {exc}")))
            continue
        try:
            wire = encoder.encode(result)
        except Exception as exc:
            # An encode failure is a per-message problem (allocation race,
            # OOM): report it like any call failure, keep serving.
            conn.send((False, ActorError(f"transport encode failed for {method}(): {exc!r}")))
            continue
        try:
            conn.send((True, wire))
        except Exception as exc:
            encoder.rollback(wire)  # consumer will never release these refs
            conn.send((False, ActorError(f"unpicklable result from {method}(): {exc}")))


class _Proxy:
    """Driver-side stand-in for a process-hosted target.

    Attribute access returns RPC stubs, so ``apply(lambda t: t.sample())``
    works unchanged: the closure runs on the driver's mailbox thread and
    every method call round-trips to the child process.
    """

    __slots__ = ("_cell",)

    def __init__(self, cell: "ProcessCell"):
        object.__setattr__(self, "_cell", cell)

    def __getattr__(self, name: str) -> Any:
        cell = object.__getattribute__(self, "_cell")

        def _stub(*args: Any, **kwargs: Any) -> Any:
            return cell.rpc(name, args, kwargs)

        _stub.__name__ = name
        return _stub

    def __repr__(self) -> str:  # pragma: no cover
        return f"_Proxy({object.__getattribute__(self, '_cell')!r})"


class _ReturnTarget:
    """Picklable factory wrapper for a pre-built (picklable) target object."""

    def __init__(self, target: Any):
        self.target = target

    def __call__(self) -> Any:
        return self.target


class ProcessCell(Cell):
    """Target lives in a child process; calls are pipe RPCs.

    The factory (or the target itself) is pickled eagerly — the
    "picklable-target transport" contract — so a cell that constructs at all
    can always be restarted, under any multiprocessing start method.
    """

    def __init__(
        self,
        factory: Optional[Callable[[], Any]] = None,
        target: Any = None,
        start_method: Optional[str] = None,
        transport: Any = None,
    ):
        payload = factory if factory is not None else _ReturnTarget(target)
        self._payload = pickle.dumps(payload)
        self._transport: Transport = resolve_transport(transport)
        self._prefix_base = f"rfl{os.getpid()}x{next(_cell_seq)}"
        self._generation = 0
        self._decoder: Any = None
        if start_method is None:
            # Default to fork where available: ~10ms per worker vs ~1s for
            # forkserver/spawn (measured; the chaos suites restart workers
            # constantly).  Fork-with-threads is a known CPython hazard, but
            # the child here only unpickles the factory and serves numpy
            # calls — it never touches the driver's JAX/logging state.  Pass
            # ``ProcessBackend(start_method="forkserver"|"spawn")`` for
            # drivers where that tradeoff goes the other way.
            start_method = "fork" if "fork" in multiprocessing.get_all_start_methods() else "spawn"
        self._ctx = multiprocessing.get_context(start_method)
        self._proc: Any = None
        self._conn: Any = None
        self._proxy = _Proxy(self)
        # Last-resort segment sweep: a cell abandoned without stop()/kill()
        # (test aborted mid-stream, driver crash path) still reclaims its
        # shared-memory names at GC/interpreter exit.  Normal shutdown paths
        # make this a no-op.
        self._finalizer = weakref.finalize(
            self, ProcessCell._sweep_prefix, self._prefix_base
        )
        self._spawn()

    @staticmethod
    def _sweep_prefix(prefix_base: str) -> None:
        from repro.core.transport import _unlink_by_name, list_segments

        for name in list_segments(prefix_base):
            _unlink_by_name(name)

    def _spawn(self) -> None:
        parent, child = self._ctx.Pipe()
        self._conn = parent
        # A fresh name generation per spawn: segments of a killed child are
        # swept by the driver, and the replacement child can never collide
        # with a name the sweep missed.
        self._generation += 1
        prefix = f"{self._prefix_base}g{self._generation}"
        self._decoder = self._transport.client_endpoint(prefix)
        self._proc = self._ctx.Process(
            target=_serve,
            args=(child, self._payload, pickle.dumps((self._transport, prefix))),
            daemon=True,
            name="actor-cell",
        )
        self._proc.start()
        child.close()

    # ------------------------------------------------------------------ rpc
    def rpc(self, method: str, args: tuple, kwargs: dict) -> Any:
        if not self.alive:
            raise self._death_error(method)
        try:
            self._conn.send((method, args, kwargs, self._decoder.drain_releases()))
            ok, payload = self._conn.recv()
        except (EOFError, OSError, BrokenPipeError):
            raise self._death_error(method) from None
        if ok:
            return self._decoder.decode(payload)
        raise payload

    def _death_error(self, method: str) -> ActorDiedError:
        """Build the death error, draining any buffered report from the
        child first — a target whose constructor raised sends the real
        exception into the pipe before exiting, and that beats a generic
        'process is dead'."""
        buffered: Optional[BaseException] = None
        try:
            if self._conn.poll(0.05):
                ok, payload = self._conn.recv()
                if not ok and isinstance(payload, BaseException):
                    buffered = payload
        except (EOFError, OSError, BrokenPipeError, ValueError):
            pass
        err = ActorDiedError(
            f"process cell died during {method}() (exitcode={self._exitcode()})"
            + (f": {buffered}" if buffered is not None else "")
        )
        err.__cause__ = buffered
        return err

    def _exitcode(self) -> Any:
        return self._proc.exitcode if self._proc is not None else None

    # ------------------------------------------------------------ lifecycle
    @property
    def target(self) -> Any:
        return self._proxy

    @property
    def alive(self) -> bool:
        return self._proc is not None and self._proc.is_alive()

    def restart(self) -> None:
        self.kill()
        self._spawn()

    def stop(self) -> None:
        if self._proc is None:
            return
        try:
            if self._proc.is_alive():
                self._conn.send(None)
                self._proc.join(timeout=1.0)
        except (OSError, BrokenPipeError, ValueError):
            pass
        self.kill()

    def kill(self) -> None:
        if self._proc is None:
            return
        if self._proc.is_alive():
            self._proc.terminate()
            self._proc.join(timeout=2.0)
        try:
            self._conn.close()
        except Exception:
            pass
        # Sweep this generation's shared-memory segments: the child is gone
        # (or never cleaned up after terminate), so reclaim is ours now.
        if self._decoder is not None:
            self._decoder.close(unlink=True)


# --------------------------------------------------------------------------
# Backends
# --------------------------------------------------------------------------
class ExecutionBackend(ABC):
    """Factory for cells: where an actor's target executes."""

    name: str = "abstract"

    @abstractmethod
    def make_cell(
        self, factory: Optional[Callable[[], Any]] = None, target: Any = None
    ) -> Cell:
        ...


class ThreadBackend(ExecutionBackend):
    name = "thread"

    def __init__(self, transport: Any = None):
        # Thread cells share the driver's address space: every payload is
        # already zero-copy.  The kwarg exists so backend-matrix code can
        # parametrize (backend, transport) uniformly.
        self.transport = transport

    def make_cell(
        self, factory: Optional[Callable[[], Any]] = None, target: Any = None
    ) -> Cell:
        return ThreadCell(factory=factory, target=target)


class ProcessBackend(ExecutionBackend):
    name = "process"

    def __init__(self, start_method: Optional[str] = None, transport: Any = None):
        self.start_method = start_method
        self.transport = resolve_transport(transport)

    def make_cell(
        self, factory: Optional[Callable[[], Any]] = None, target: Any = None
    ) -> Cell:
        return ProcessCell(
            factory=factory,
            target=target,
            start_method=self.start_method,
            transport=self.transport,
        )


BACKENDS = {"thread": ThreadBackend, "process": ProcessBackend}


def resolve_backend(backend: Any) -> ExecutionBackend:
    """None -> ThreadBackend; str -> registry lookup; instance passthrough."""
    if backend is None:
        return ThreadBackend()
    if isinstance(backend, ExecutionBackend):
        return backend
    if isinstance(backend, str):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; known: {sorted(BACKENDS)}")
        return BACKENDS[backend]()
    raise TypeError(f"backend must be None, str, or ExecutionBackend (got {backend!r})")
