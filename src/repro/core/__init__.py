"""RLlib Flow core: hybrid actor-dataflow programming model (paper §4).

Public API:

    from repro.core import (
        VirtualActor, ActorPool, WorkerSet,
        LocalIterator, ParallelIterator, NextValueNotReady,
        ParallelRollouts, Replay, TrainOneStep, ...,
        Concurrently, Enqueue, Dequeue,
        a3c_plan, ppo_plan, apex_plan, ...,
    )
"""

from repro.core.actor import (
    ActorHandle,
    ActorPool,
    VirtualActor,
    create_colocated,
    get,
    wait,
)
from repro.core.concurrency import Concurrently, Dequeue, Enqueue
from repro.core.executor import (
    ActorDiedError,
    ActorError,
    ExecutionBackend,
    FailurePolicy,
    ProcessBackend,
    SupervisorSpec,
    ThreadBackend,
    resolve_backend,
)
from repro.core.iterators import (
    LocalIterator,
    NextValueNotReady,
    ParallelIterator,
    from_actors,
    from_items,
    from_iterators,
)
from repro.core.learner_thread import LearnerThread
from repro.core.metrics import LatencyStat, MetricsContext, TimerStat, get_metrics
from repro.core.operators import (
    ApplyGradients,
    AverageGradients,
    ConcatBatches,
    ParallelRollouts,
    Replay,
    ReportMetrics,
    SelectExperiences,
    StandardizeFields,
    StandardMetricsReporting,
    StoreToReplayBuffer,
    TrainOneStep,
    UpdateReplayPriorities,
    UpdateTargetNetwork,
    UpdateWorkerWeights,
    par_compute_gradients,
)
from repro.core.plans import (
    a2c_plan,
    a3c_plan,
    apex_plan,
    appo_plan,
    dqn_plan,
    impala_plan,
    maml_plan,
    mbpo_plan,
    multi_agent_ppo_dqn_plan,
    ppo_plan,
    sac_plan,
)
from repro.core.remote import (
    LocalHostHandle,
    RemoteBackend,
    RemoteCell,
    start_local_host,
)
from repro.core.transport import (
    CreditPool,
    FrameDecoder,
    OverflowPolicy,
    PickleTransport,
    SharedMemoryTransport,
    SocketTransport,
    Transport,
    encode_frame,
    list_segments,
    resolve_transport,
)
from repro.core.workers import WorkerSet

__all__ = [k for k in dir() if not k.startswith("_")]
