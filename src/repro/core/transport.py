"""The data plane: pluggable inter-process transports + credit primitives.

The paper's Fig 13 throughput claims rest on the sample stream between
rollout/replay fragments and the learner moving at hardware speed.  Our
``ProcessBackend`` (PR 2) moved *control* off-driver but kept the data plane
at pickle speed: every ``SampleBatch`` was serialized column-by-column into
a pipe, copied through the kernel, and deserialized on the driver.  MSRL
makes the same observation for its fragment transport (data moves between
fragments over the fastest channel the placement allows), and SRL attributes
its scaling to a shared-memory sample stream between actor and learner
workers.  This module is that idea for the virtual-actor runtime:

  * ``PickleTransport``       — the baseline: payloads ride the RPC pipe
    verbatim (pickled by ``multiprocessing.Connection``).
  * ``SharedMemoryTransport`` — ``SampleBatch`` numpy columns are written
    once into ``multiprocessing.shared_memory`` **ring segments** by the
    producing process; the pipe carries a *header-only* control message
    (segment name + column dtype/shape/offset table).  The consumer maps the
    segment and builds zero-copy numpy views.  Reclaim is **refcounted**:
    every decoded batch holds a lease on its segment, and only when the last
    view dies is the segment name queued back to the producer (piggybacked
    on the next RPC), which marks the slot free for reuse.  Non-array
    payloads — and batches below ``threshold`` bytes, where header overhead
    beats the copy saved — fall back to the pipe.

Mapping onto the paper's Fig 13 experiment: the "hand-written" baseline and
the dataflow version move identical bytes; what this transport changes is
the *number of copies per byte* (pipe: serialize + kernel copy in + kernel
copy out + deserialize; shm: one producer-side memcpy, zero consumer-side).
``benchmarks/bench_transport.py`` measures the resulting speedup, and the
BENCH_PR3 regression gate keeps it from silently regressing.

Credit-based backpressure lives here too (``CreditPool``): ``gather_async``
acquires a credit per dispatched-but-unconsumed item and releases it as the
consumer drains results (starved shards backfill FIFO); the queue operators
(``Enqueue``/learner queues) use their bounded queue capacity as the window
with an overflow policy.  Both replace open-loop buffering with a bounded,
observable window (credit stalls + occupancy are recorded into the shared
metrics context; see ``core.metrics``).

Segment lifecycle & crash safety: segment names are prefixed with a
per-cell, per-generation token (``rfl<pid>x<cell>g<gen>``).  The producer
unlinks its segments on graceful shutdown; the *consumer* side additionally
sweeps ``/dev/shm`` for its prefix on ``close()``/``kill()``, so a worker
killed mid-transfer (chaos suite) leaks nothing.  Both sides unregister
their mappings from the ``multiprocessing`` resource tracker because
lifetime is managed here, not at interpreter exit.
"""

from __future__ import annotations

import glob
import itertools
import os
import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "Transport",
    "PickleTransport",
    "SharedMemoryTransport",
    "SocketTransport",
    "TRANSPORTS",
    "resolve_transport",
    "CreditPool",
    "OverflowPolicy",
    "list_segments",
    "SANITIZER",
    "ShmLeaseViolation",
    "sanitize_enabled",
    "encode_frame",
    "FrameDecoder",
    "FrameError",
]

_SHM_ALIGN = 64  # column offsets aligned for safe dtype views + cache lines


_quiet_cls: Any = None


def _quiet_shm_class() -> Any:
    """A SharedMemory whose ``__del__`` cannot spew ``BufferError``.

    A mapping still referenced by numpy views at GC time must simply stay
    mapped (the views keep the memory alive); the stock ``__del__`` prints
    an ignored-exception traceback instead.  Lifetime is managed explicitly
    by ``_Attachment``/``ShmWriter`` — this only silences the destructor.
    """
    global _quiet_cls
    if _quiet_cls is None:
        from multiprocessing import shared_memory

        class _QuietSharedMemory(shared_memory.SharedMemory):
            def __del__(self):
                try:
                    super().__del__()
                except BufferError:
                    pass

            def unlink(self):
                with _tracker_untracked():
                    super().unlink()

        _quiet_cls = _QuietSharedMemory
    return _quiet_cls


# Resource-tracker silencing.  Segment lifetime is owned by this module
# (producer ring + consumer prefix sweep), and the tracker's process-exit
# cleanup actively fights that ownership: both create and attach register a
# name, every unlink unregisters it, and with a forked child and the driver
# both touching the same name the shared tracker's set goes unbalanced —
# yielding KeyError tracebacks and bogus "leaked shared_memory" warnings.
#
# The silencing is a THREAD-LOCAL flag honored by permanently-installed
# wrappers, never a patch-under-lock: ProcessCell forks children from other
# driver threads at arbitrary times, and a lock held across a fork would be
# inherited locked (owner thread gone) and deadlock the child's first
# shared-memory call.  A thread cannot fork while inside its own
# ``_tracker_untracked`` block, so the flag is fork-consistent by
# construction.
_tracker_silence = threading.local()
_tracker_patched = False
_patch_lock = threading.Lock()  # guards wrapper install only (no syscalls)


def _ensure_tracker_wrappers() -> None:
    global _tracker_patched
    if _tracker_patched:
        return
    with _patch_lock:
        if _tracker_patched:
            return
        from multiprocessing import resource_tracker

        orig_register = resource_tracker.register
        orig_unregister = resource_tracker.unregister

        def register(name: str, rtype: str) -> None:
            if rtype == "shared_memory" and getattr(_tracker_silence, "on", False):
                return
            orig_register(name, rtype)

        def unregister(name: str, rtype: str) -> None:
            if rtype == "shared_memory" and getattr(_tracker_silence, "on", False):
                return
            orig_unregister(name, rtype)

        resource_tracker.register = register
        resource_tracker.unregister = unregister
        _tracker_patched = True


if hasattr(os, "register_at_fork"):
    # Defensive: a fork racing the (brief) wrapper install must not leave
    # the child with a locked install lock.
    os.register_at_fork(after_in_child=lambda: globals().__setitem__("_patch_lock", threading.Lock()))


class _tracker_untracked:
    """Context manager: shared_memory calls on THIS thread skip the tracker."""

    def __enter__(self) -> "_tracker_untracked":
        _ensure_tracker_wrappers()
        self._prev = getattr(_tracker_silence, "on", False)
        _tracker_silence.on = True
        return self

    def __exit__(self, *exc: Any) -> None:
        _tracker_silence.on = self._prev


def _open_shm(name: str, create: bool = False, size: int = 0) -> Any:
    cls = _quiet_shm_class()
    with _tracker_untracked():
        if create:
            return cls(name=name, create=True, size=size)
        return cls(name=name)


def list_segments(prefix: str) -> List[str]:
    """Live /dev/shm segment names starting with ``prefix`` (leak checks)."""
    if not os.path.isdir("/dev/shm"):
        return []
    return sorted(os.path.basename(p) for p in glob.glob(f"/dev/shm/{prefix}*"))


def _unlink_by_name(name: str) -> None:
    """Destroy a segment by name, tolerating it being already gone.

    ``unlink()`` also unregisters the name from the resource tracker —
    together with the register both create and attach perform, the tracker's
    set stays balanced as long as each name is unlinked through here (or
    through the writer) at most effectively-once; a lost race just raises
    ``FileNotFoundError``, which is the success case.
    """
    try:
        seg = _open_shm(name)
        seg.close()
        seg.unlink()
    except FileNotFoundError:
        pass
    except Exception:
        pass


# --------------------------------------------------------------------------
# Dynamic analysis: the shm lease sanitizer (TRANSPORT_SANITIZE=1)
# --------------------------------------------------------------------------
def sanitize_enabled() -> bool:
    """True when the environment opts into lease sanitizing."""
    return os.environ.get("TRANSPORT_SANITIZE", "").lower() in ("1", "true", "on")


class ShmLeaseViolation(AssertionError):
    """A lease acquire/release invariant was broken (sanitizer finding)."""


class _LeaseSanitizer:
    """Process-wide ledger of shm segment lease acquire/release pairs.

    The PR 3 reclaim protocol is refcounted: every decoded batch holds one
    reader-side lease on its mapped segment (``_Attachment.add_lease``),
    dropped exactly once when the last view dies (``_SegmentToken.__del__``),
    and the writer's per-segment ring refcount decrements once per released
    batch ref.  This sanitizer turns those invariants into a checker the
    test suite runs under ``TRANSPORT_SANITIZE=1``:

      * double-release — a lease dropped more often than acquired, or a
        writer ring ref released below zero / for a never-created segment;
      * leaked lease  — a lease still live at epoch end (one test), after
        the epoch's garbage is collected;
      * leaked segment — a ``/dev/shm`` entry under the runtime's prefix
        surviving epoch teardown.

    Scope: the ledger is per-process, so it audits every endpoint living in
    the driver (readers for worker->driver data, plus any writer built
    in-process by tests/benchmarks).  Writers inside forked children check
    their own ring refcounts but report to their own copy of the ledger,
    which no one collects — child-side violations surface indirectly, as
    driver-side leaks of the segments involved.

    Hooks are gated on ``self.enabled`` (a plain attribute read) so the
    default path stays free; ``begin_epoch``/``end_epoch`` are driven by the
    autouse fixture in ``tests/conftest.py``.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.enabled = False
        self._epoch = "<no epoch>"
        # id(attachment) -> [segment name, live lease count]; entries are
        # dropped at zero so id reuse after GC cannot corrupt the ledger.
        self._live: Dict[int, List[Any]] = {}
        self._violations: List[str] = []

    # ------------------------------------------------------------ lifecycle
    def begin_epoch(self, tag: str) -> None:
        """Start a fresh ledger (one epoch = one test)."""
        with self._lock:
            self._epoch = tag
            self._live.clear()
            self._violations.clear()
            self.enabled = True

    def end_epoch(self, prefix: str = "rfl") -> None:
        """Close the epoch: collect garbage, then fail on any violation.

        Two ``gc.collect`` passes let release tokens queued behind reference
        cycles die before the leak check; segments under ``prefix`` still in
        ``/dev/shm`` after that are leaks too (a stopped runtime sweeps its
        own prefix on close).
        """
        import gc

        if not self.enabled:
            return
        gc.collect()
        gc.collect()
        with self._lock:
            self.enabled = False
            problems = list(self._violations)
            problems += [
                f"leaked lease: segment {seg} still has {n} live lease(s)"
                for seg, n in self._live.values()
                if n > 0
            ]
            self._live.clear()
            self._violations.clear()
            epoch = self._epoch
        leftover = list_segments(prefix)
        problems += [f"leaked /dev/shm segment: {name}" for name in leftover]
        for name in leftover:  # clean up so one leak doesn't fail every test after
            _unlink_by_name(name)
        if problems:
            raise ShmLeaseViolation(
                f"shm lease sanitizer ({epoch}): {len(problems)} violation(s)\n"
                + "\n".join("  " + p for p in problems)
            )

    # ---------------------------------------------------------------- hooks
    def lease_acquired(self, att: Any, segment: str) -> None:
        with self._lock:
            entry = self._live.setdefault(id(att), [segment, 0])
            entry[1] += 1

    def lease_dropped(self, att: Any, segment: str) -> None:
        with self._lock:
            entry = self._live.get(id(att))
            if entry is None:
                self._violations.append(
                    f"double-release: lease on segment {segment} dropped "
                    "with no live lease outstanding"
                )
                return
            entry[1] -= 1
            if entry[1] <= 0:
                del self._live[id(att)]

    def violation(self, message: str) -> None:
        with self._lock:
            self._violations.append(message)


SANITIZER = _LeaseSanitizer()


# --------------------------------------------------------------------------
# Overflow policies (shared by Enqueue / learner queues)
# --------------------------------------------------------------------------
class OverflowPolicy:
    """What a bounded producer does when its window/queue is full.

    BLOCK       -> wait for a credit/slot, recording stall time.
    DROP_NEWEST -> reject the incoming item (count it dropped).
    DROP_OLDEST -> evict the oldest buffered item to admit the new one.
    """

    BLOCK = "block"
    DROP_NEWEST = "drop_newest"
    DROP_OLDEST = "drop_oldest"
    ALL = frozenset((BLOCK, DROP_NEWEST, DROP_OLDEST))

    @classmethod
    def validate(cls, policy: str) -> str:
        if policy not in cls.ALL:
            raise ValueError(
                f"unknown overflow policy {policy!r}; expected one of {sorted(cls.ALL)}"
            )
        return policy


class CreditPool:
    """A bounded pool of in-flight credits (the backpressure primitive).

    Producers ``try_acquire()`` before dispatching an item and ``release()``
    when the consumer has taken it; a ``None`` capacity means unbounded
    (always grants).  Thread-safe; resizable mid-stream (elastic shards).
    """

    def __init__(self, capacity: Optional[int]):
        if capacity is not None and capacity < 1:
            raise ValueError("credit capacity must be >= 1 (or None for unbounded)")
        self._capacity = capacity
        self._outstanding = 0
        self._lock = threading.Lock()

    @property
    def capacity(self) -> Optional[int]:
        return self._capacity

    @property
    def outstanding(self) -> int:
        return self._outstanding

    def resize(self, capacity: Optional[int]) -> None:
        with self._lock:
            self._capacity = capacity

    def try_acquire(self, n: int = 1) -> bool:
        with self._lock:
            if self._capacity is not None and self._outstanding + n > self._capacity:
                return False
            self._outstanding += n
            return True

    def release(self, n: int = 1) -> None:
        with self._lock:
            self._outstanding = max(0, self._outstanding - n)


# --------------------------------------------------------------------------
# Wire format (header-only control messages)
# --------------------------------------------------------------------------
class _ColumnRef:
    """One column inside a segment: everything needed to rebuild the view."""

    __slots__ = ("key", "dtype", "shape", "offset", "nbytes")

    def __init__(self, key: str, dtype: str, shape: Tuple[int, ...], offset: int, nbytes: int):
        self.key = key
        self.dtype = dtype
        self.shape = shape
        self.offset = offset
        self.nbytes = nbytes

    def __getstate__(self):
        return (self.key, self.dtype, self.shape, self.offset, self.nbytes)

    def __setstate__(self, state):
        self.key, self.dtype, self.shape, self.offset, self.nbytes = state


class _ShmBatchRef:
    """Header standing in for one SampleBatch: segment name + column table."""

    __slots__ = ("segment", "columns", "nbytes", "created_at")

    def __init__(self, segment: str, columns: List[_ColumnRef], nbytes: int, created_at: Any):
        self.segment = segment
        self.columns = columns
        self.nbytes = nbytes
        self.created_at = created_at

    def __getstate__(self):
        return (self.segment, self.columns, self.nbytes, self.created_at)

    def __setstate__(self, state):
        self.segment, self.columns, self.nbytes, self.created_at = state


class _ShmMultiRef:
    """MultiAgentBatch header: per-policy batch refs (or inline fallbacks)."""

    __slots__ = ("policy_refs",)

    def __init__(self, policy_refs: Dict[str, Any]):
        self.policy_refs = policy_refs

    def __getstate__(self):
        return self.policy_refs

    def __setstate__(self, state):
        self.policy_refs = state


class _ShmPayload:
    """Top-level wire marker: ``tree`` contains at least one shm ref.

    ``retired`` carries segment names the writer destroyed since the last
    shm message (ring recycling), so the reader can drop its now-dead
    attachments instead of keeping the unlinked pages mapped forever.
    """

    __slots__ = ("tree", "retired")

    def __init__(self, tree: Any, retired: Tuple[str, ...] = ()):
        self.tree = tree
        self.retired = retired

    def __getstate__(self):
        return (self.tree, self.retired)

    def __setstate__(self, state):
        self.tree, self.retired = state


# --------------------------------------------------------------------------
# Reader-side lease plumbing (refcounted reclaim)
# --------------------------------------------------------------------------
class _Attachment:
    """One mapped segment on the consumer side, refcounted by live leases.

    The mapping must outlive every numpy view into it; it is closed only
    when the reader has discarded it *and* the last lease token has died —
    never while a view could still dereference the buffer.
    """

    __slots__ = ("shm", "live", "discarded", "lock", "raw")

    def __init__(self, shm: Any):
        self.shm = shm
        self.live = 0
        self.discarded = False
        self.lock = threading.Lock()
        # One buffer export per attachment; per-message decodes view this.
        self.raw = np.frombuffer(shm.buf, dtype=np.uint8)

    def add_lease(self) -> None:
        if SANITIZER.enabled:
            SANITIZER.lease_acquired(self, self.shm.name)
        with self.lock:
            self.live += 1

    def drop_lease(self) -> None:
        if SANITIZER.enabled:
            SANITIZER.lease_dropped(self, self.shm.name)
        with self.lock:
            self.live -= 1
            close_now = self.discarded and self.live <= 0
        if close_now:
            self._close()

    def discard(self) -> None:
        with self.lock:
            self.discarded = True
            close_now = self.live <= 0
        if close_now:
            self._close()

    def _close(self) -> None:
        self.raw = None  # release the cached buffer export first
        try:
            self.shm.close()
        except Exception:
            pass


class _SegmentToken:
    """Queues its segment name for reclaim when the last view dies.

    The token is attached to the bottom array of every decoded batch; numpy
    base chains keep it alive through arbitrary slicing, so reclaim can never
    race a reader still holding (a view of) the batch.  It also keeps the
    attachment mapped until that point.
    """

    __slots__ = ("segment", "releases", "attachment")

    def __init__(self, segment: str, releases: "deque", attachment: _Attachment):
        self.segment = segment
        self.releases = releases
        self.attachment = attachment

    def __del__(self):
        try:
            self.releases.append(self.segment)
            self.attachment.drop_lease()
        except Exception:
            pass


class _SegArray(np.ndarray):
    """ndarray subclass able to carry the segment token in its ``__dict__``."""


# --------------------------------------------------------------------------
# Endpoints
# --------------------------------------------------------------------------
class _Segment:
    __slots__ = ("shm", "name", "capacity", "refs", "raw")

    def __init__(self, shm: Any, name: str, capacity: int):
        self.shm = shm
        self.name = name
        self.capacity = capacity
        self.refs = 0  # in-flight batch refs the consumer has not released
        # Cached flat view for column writes: one buffer export per segment
        # lifetime instead of one per message.
        self.raw = np.frombuffer(shm.buf, dtype=np.uint8)


def _eligible_batch(batch: Any) -> bool:
    cols = getattr(batch, "_data", None)
    if not isinstance(cols, dict) or not cols:
        return False
    return all(
        isinstance(v, np.ndarray) and not v.dtype.hasobject for v in cols.values()
    )


def _align(n: int) -> int:
    return (n + _SHM_ALIGN - 1) & ~(_SHM_ALIGN - 1)


class ShmWriter:
    """Producer endpoint: owns the segment ring, encodes payloads.

    ``encode`` walks one RPC result (depth-limited through tuples/lists/
    dicts and ``MultiAgentBatch``), and when the eligible batches in it total
    at least ``threshold`` bytes, copies their columns into one free ring
    segment and substitutes header refs.  ``reclaim`` returns released
    segments to the free list; a full ring falls back to the pipe rather
    than block or grow without bound.
    """

    def __init__(
        self,
        prefix: str,
        threshold: int = 16 * 1024,
        min_segment: int = 1 << 20,
        max_segments: int = 16,
    ):
        self.prefix = prefix
        self.threshold = threshold
        self.min_segment = min_segment
        self.max_segments = max_segments
        self._segments: Dict[str, _Segment] = {}
        self._seq = itertools.count()
        self._retired: List[str] = []  # destroyed names the reader hasn't heard
        # All names ever destroyed: releases for these are in-flight races
        # (legitimate), anything else reaching reclaim() is a sanitizer
        # violation.  Bounded by segments_created, which the ring keeps small.
        self._destroyed: set = set()
        self.stats: Dict[str, int] = {
            "messages": 0,
            "shm_batches": 0,
            "bytes_shm": 0,
            "fallbacks": 0,
            "segments_created": 0,
        }

    # ------------------------------------------------------------ ring mgmt
    def _acquire(self, nbytes: int) -> Optional[_Segment]:
        free = [s for s in self._segments.values() if s.refs == 0]
        fitting = [s for s in free if s.capacity >= nbytes]
        if fitting:
            return min(fitting, key=lambda s: s.capacity)
        if len(self._segments) >= self.max_segments:
            # Recycle a too-small free segment into a bigger one if we can;
            # otherwise the ring is saturated -> pipe fallback (bounded).
            if not free:
                return None
            victim = max(free, key=lambda s: s.capacity)
            self._destroy(victim)
        return self._create(nbytes)

    def _create(self, nbytes: int) -> Optional[_Segment]:
        capacity = max(self.min_segment, 1 << max(12, int(nbytes - 1).bit_length()))
        name = f"{self.prefix}s{next(self._seq)}"
        try:
            shm = _open_shm(name, create=True, size=capacity)
        except FileExistsError:
            _unlink_by_name(name)
            try:
                shm = _open_shm(name, create=True, size=capacity)
            except Exception:
                return None
        except Exception:
            return None
        seg = _Segment(shm, name, capacity)
        self._segments[name] = seg
        self.stats["segments_created"] += 1
        return seg

    def _destroy(self, seg: _Segment) -> None:
        self._segments.pop(seg.name, None)
        self._retired.append(seg.name)
        self._destroyed.add(seg.name)
        seg.raw = None  # release the cached buffer export first
        try:
            seg.shm.close()
        except Exception:
            pass
        try:
            seg.shm.unlink()
        except Exception:
            pass

    def reclaim(self, names: List[str]) -> None:
        for n in names or ():
            seg = self._segments.get(n)
            if seg is not None and seg.refs > 0:
                seg.refs -= 1
            elif SANITIZER.enabled:
                # A release for a recycled segment is a legitimate race (the
                # ring destroyed it while the ref was in flight); anything
                # else is a refcount bug the silent ignore used to hide.
                if seg is not None:
                    SANITIZER.violation(
                        f"double-release: writer ring ref for segment {n} "
                        "released below zero"
                    )
                elif n not in self._destroyed:
                    SANITIZER.violation(
                        f"double-release: writer received a release for "
                        f"segment {n} it never created"
                    )

    def rollback(self, payload: Any) -> None:
        """Undo the refcounts of an encoded payload that never reached the
        consumer (pipe send failed): the consumer cannot release them.  The
        retirement notices ride again on the next message."""
        if not isinstance(payload, _ShmPayload):
            return
        self._retired.extend(payload.retired)
        refs: List[Any] = []
        _collect_refs(payload.tree, refs, 0)
        self.reclaim([r.segment for r in {id(r): r for r in refs}.values()])

    @property
    def num_segments(self) -> int:
        return len(self._segments)

    def segments_in_use(self) -> int:
        return sum(1 for s in self._segments.values() if s.refs > 0)

    # --------------------------------------------------------------- encode
    def encode(self, obj: Any) -> Any:
        self.stats["messages"] += 1
        collected: List[Any] = []
        _collect_batches(obj, collected, 0)
        # Dedup by identity: one ref (and one refcount) per distinct batch.
        batches = [b for b in {id(b): b for b in collected}.values() if _eligible_batch(b)]
        # Footprint must mirror the write loop exactly: offsets advance by
        # _align(col.nbytes) per column (offsets stay aligned), so the
        # per-COLUMN aligned sum is the capacity actually consumed.
        total = sum(
            _align(int(v.nbytes)) for b in batches for v in b._data.values()
        )
        if not batches or total < self.threshold:
            return obj
        seg = self._acquire(total)
        if seg is None:
            self.stats["fallbacks"] += 1
            return obj
        refs: Dict[int, _ShmBatchRef] = {}
        offset = 0
        for b in batches:
            cols: List[_ColumnRef] = []
            for k, v in b._data.items():
                v = np.ascontiguousarray(v)
                seg.raw[offset : offset + v.nbytes] = v.reshape(-1).view(np.uint8)
                cols.append(_ColumnRef(k, v.dtype.str, v.shape, offset, v.nbytes))
                offset = _align(offset + v.nbytes)
            refs[id(b)] = _ShmBatchRef(
                seg.name, cols, int(sum(c.nbytes for c in cols)),
                getattr(b, "created_at", None),
            )
        seg.refs += len(refs)
        self.stats["shm_batches"] += len(refs)
        self.stats["bytes_shm"] += total
        retired, self._retired = tuple(self._retired), []
        return _ShmPayload(_substitute(obj, refs, 0), retired)

    def close(self) -> None:
        for seg in list(self._segments.values()):
            self._destroy(seg)


def _collect_batches(obj: Any, out: List[Any], depth: int) -> None:
    if depth > 3:
        return
    if hasattr(obj, "_data") and hasattr(obj, "count"):  # SampleBatch-shaped
        out.append(obj)
        return
    pb = getattr(obj, "policy_batches", None)
    if isinstance(pb, dict):  # MultiAgentBatch
        for b in pb.values():
            _collect_batches(b, out, depth + 1)
        return
    if isinstance(obj, (tuple, list)):
        for x in obj:
            _collect_batches(x, out, depth + 1)
    elif isinstance(obj, dict):
        for x in obj.values():
            _collect_batches(x, out, depth + 1)


def _collect_refs(obj: Any, out: List[Any], depth: int) -> None:
    if depth > 4:
        return
    if isinstance(obj, _ShmBatchRef):
        out.append(obj)
    elif isinstance(obj, _ShmMultiRef):
        for v in obj.policy_refs.values():
            _collect_refs(v, out, depth + 1)
    elif isinstance(obj, (tuple, list)):
        for x in obj:
            _collect_refs(x, out, depth + 1)
    elif isinstance(obj, dict):
        for x in obj.values():
            _collect_refs(x, out, depth + 1)


def _substitute(obj: Any, refs: Dict[int, _ShmBatchRef], depth: int) -> Any:
    if depth > 3:
        return obj
    if id(obj) in refs:
        return refs[id(obj)]
    pb = getattr(obj, "policy_batches", None)
    if isinstance(pb, dict):
        return _ShmMultiRef(
            {k: _substitute(v, refs, depth + 1) for k, v in pb.items()}
        )
    if isinstance(obj, tuple):
        return tuple(_substitute(x, refs, depth + 1) for x in obj)
    if isinstance(obj, list):
        return [_substitute(x, refs, depth + 1) for x in obj]
    if isinstance(obj, dict):
        return {k: _substitute(v, refs, depth + 1) for k, v in obj.items()}
    return obj


class ShmReader:
    """Consumer endpoint: maps segments, decodes headers into zero-copy
    views, queues refcount releases, and sweeps segments on close."""

    def __init__(self, prefix: str):
        self.prefix = prefix
        self._attachments: Dict[str, Any] = {}  # name -> SharedMemory
        self._releases: "deque[str]" = deque()
        self.stats: Dict[str, int] = {"shm_batches": 0, "bytes_shm": 0}

    # --------------------------------------------------------------- decode
    def decode(self, payload: Any) -> Any:
        if not isinstance(payload, _ShmPayload):
            return payload
        for name in payload.retired:
            # The writer recycled this segment: drop our mapping (it closes
            # when the last outstanding lease dies).
            att = self._attachments.pop(name, None)
            if att is not None:
                att.discard()
        return self._decode_tree(payload.tree, 0, {})

    def _decode_tree(self, obj: Any, depth: int, memo: Dict[int, Any]) -> Any:
        if depth > 4:
            return obj
        if isinstance(obj, _ShmBatchRef):
            # Memoized by ref identity: a batch appearing twice in one
            # message decodes to one object with one release token, so the
            # writer's single refcount can never be released twice.
            if id(obj) not in memo:
                memo[id(obj)] = self._materialize(obj)
            return memo[id(obj)]
        if isinstance(obj, _ShmMultiRef):
            from repro.rl.sample_batch import MultiAgentBatch

            return MultiAgentBatch(
                {k: self._decode_tree(v, depth + 1, memo) for k, v in obj.policy_refs.items()}
            )
        if isinstance(obj, tuple):
            return tuple(self._decode_tree(x, depth + 1, memo) for x in obj)
        if isinstance(obj, list):
            return [self._decode_tree(x, depth + 1, memo) for x in obj]
        if isinstance(obj, dict):
            return {k: self._decode_tree(v, depth + 1, memo) for k, v in obj.items()}
        return obj

    def _attach(self, name: str) -> _Attachment:
        att = self._attachments.get(name)
        if att is None:
            att = _Attachment(_open_shm(name))
            self._attachments[name] = att
        return att

    def _materialize(self, ref: _ShmBatchRef) -> Any:
        from repro.rl.sample_batch import SampleBatch

        att = self._attach(ref.segment)
        att.add_lease()
        base = att.raw.view(_SegArray)
        base._token = _SegmentToken(ref.segment, self._releases, att)
        cols: Dict[str, np.ndarray] = {}
        for c in ref.columns:
            arr = (
                base[c.offset : c.offset + c.nbytes]
                .view(np.dtype(c.dtype))
                .reshape(c.shape)
            )
            # The segment is leased read-only to this consumer: an in-place
            # write would alias the ring slot, so surface it as an error.
            arr.flags.writeable = False
            cols[c.key] = arr
        batch = SampleBatch(cols)
        if ref.created_at is not None:
            batch.created_at = ref.created_at
        self.stats["shm_batches"] += 1
        self.stats["bytes_shm"] += ref.nbytes
        return batch

    # -------------------------------------------------------------- reclaim
    def drain_releases(self) -> List[str]:
        out: List[str] = []
        while True:
            try:
                out.append(self._releases.popleft())
            except IndexError:
                return out

    def close(self, unlink: bool = True) -> None:
        """Discard all mappings (each closes when its last lease dies); with
        ``unlink`` also sweep /dev/shm for this prefix, covering segments a
        killed producer never cleaned up.  Unlinking while leases are still
        mapped is safe on POSIX: the memory lives until the last view dies."""
        for att in self._attachments.values():
            att.discard()
        names = set(self._attachments)
        self._attachments.clear()
        if unlink:
            for name in names | set(list_segments(self.prefix)):
                _unlink_by_name(name)


class _IdentityEndpoint:
    """Pickle-pipe baseline: payloads pass through to the Connection."""

    prefix = ""
    stats: Dict[str, int] = {}

    def encode(self, obj: Any) -> Any:
        return obj

    def decode(self, obj: Any) -> Any:
        return obj

    def reclaim(self, names: List[str]) -> None:
        pass

    def rollback(self, payload: Any) -> None:
        pass

    def drain_releases(self) -> List[str]:
        return []

    def close(self, unlink: bool = True) -> None:
        pass


# --------------------------------------------------------------------------
# Length-prefixed frame codec (the inter-host wire; core.remote + chaos)
# --------------------------------------------------------------------------
_FRAME_HEADER = 8  # big-endian u64 body length
_FRAME_MAX = 1 << 32  # 4 GiB: anything larger is a corrupt/hostile header


class FrameError(RuntimeError):
    """The byte stream violated the framing protocol (corrupt header)."""


def encode_frame(obj: Any) -> bytes:
    """One message -> one length-prefixed frame (u64 big-endian + pickle).

    The frame is self-delimiting, so frames can be concatenated on a TCP
    stream and recovered by ``FrameDecoder`` regardless of how the kernel
    splits them into reads.
    """
    import pickle
    import struct

    body = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    if len(body) > _FRAME_MAX:
        raise FrameError(f"frame body of {len(body)} bytes exceeds protocol max")
    return struct.pack("!Q", len(body)) + body


class FrameDecoder:
    """Incremental frame parser: feed arbitrary chunks, get whole messages.

    TCP delivers a byte stream, not messages — one ``recv`` may carry half a
    header, three frames, or a header and part of a body.  ``feed`` buffers
    whatever arrives and yields each message exactly once, as soon as its
    last byte is in.  Pure function of the byte stream: no socket, no
    threads, so the round-trip property is testable byte-split by byte-split
    (``tests/test_transport_properties.py``).
    """

    def __init__(self) -> None:
        self._buf = bytearray()

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)

    def feed(self, chunk: bytes) -> List[Any]:
        import pickle
        import struct

        self._buf.extend(chunk)
        out: List[Any] = []
        while len(self._buf) >= _FRAME_HEADER:
            (size,) = struct.unpack_from("!Q", self._buf)
            if size > _FRAME_MAX:
                raise FrameError(f"frame header claims {size} bytes (max {_FRAME_MAX})")
            if len(self._buf) < _FRAME_HEADER + size:
                break
            body = bytes(self._buf[_FRAME_HEADER : _FRAME_HEADER + size])
            del self._buf[: _FRAME_HEADER + size]
            out.append(pickle.loads(body))
        return out


class _SocketBatchRef:
    """One SampleBatch flattened for the socket wire: column table + blob.

    Columns are packed contiguously (aligned like the shm layout) into one
    ``bytes`` blob so the frame pickles a single buffer instead of N arrays;
    ``created_at`` rides alongside so cross-fragment latency stamps survive
    the hop exactly as they do across the shm plane.
    """

    __slots__ = ("columns", "blob", "created_at")

    def __init__(self, columns: List[_ColumnRef], blob: bytes, created_at: Any):
        self.columns = columns
        self.blob = blob
        self.created_at = created_at

    def __getstate__(self):
        return (self.columns, self.blob, self.created_at)

    def __setstate__(self, state):
        self.columns, self.blob, self.created_at = state


class SocketWriter:
    """Producer endpoint for the socket plane: batches -> column-blob refs.

    Unlike ``ShmWriter`` there is no shared segment and no lease protocol —
    the bytes are copied onto the wire — so ``reclaim``/``rollback`` are
    no-ops and the endpoint is stateless beyond its stats.
    """

    def __init__(self, prefix: str = ""):
        self.prefix = prefix
        self.stats: Dict[str, int] = {"messages": 0, "socket_batches": 0, "bytes_socket": 0}

    def encode(self, obj: Any) -> Any:
        self.stats["messages"] += 1
        collected: List[Any] = []
        _collect_batches(obj, collected, 0)
        batches = [b for b in {id(b): b for b in collected}.values() if _eligible_batch(b)]
        if not batches:
            return obj
        refs: Dict[int, _SocketBatchRef] = {}
        for b in batches:
            cols: List[_ColumnRef] = []
            parts: List[bytes] = []
            offset = 0
            for k, v in b._data.items():
                v = np.ascontiguousarray(v)
                parts.append(v.tobytes())
                cols.append(_ColumnRef(k, v.dtype.str, v.shape, offset, v.nbytes))
                pad = _align(v.nbytes) - v.nbytes
                if pad:
                    parts.append(b"\x00" * pad)
                offset = _align(offset + v.nbytes)
            blob = b"".join(parts)
            refs[id(b)] = _SocketBatchRef(cols, blob, getattr(b, "created_at", None))
            self.stats["socket_batches"] += 1
            self.stats["bytes_socket"] += len(blob)
        return _substitute(obj, refs, 0)  # type: ignore[arg-type]

    def reclaim(self, names: List[str]) -> None:
        pass

    def rollback(self, payload: Any) -> None:
        pass

    def drain_releases(self) -> List[str]:
        return []

    def close(self, unlink: bool = True) -> None:
        pass


class SocketReader:
    """Consumer endpoint: rebuilds read-only column views over the blob."""

    def __init__(self, prefix: str = ""):
        self.prefix = prefix
        self.stats: Dict[str, int] = {"socket_batches": 0, "bytes_socket": 0}

    def decode(self, payload: Any) -> Any:
        return self._decode_tree(payload, 0, {})

    def _decode_tree(self, obj: Any, depth: int, memo: Dict[int, Any]) -> Any:
        if depth > 4:
            return obj
        if isinstance(obj, _SocketBatchRef):
            if id(obj) not in memo:
                memo[id(obj)] = self._materialize(obj)
            return memo[id(obj)]
        if isinstance(obj, _ShmMultiRef):
            from repro.rl.sample_batch import MultiAgentBatch

            return MultiAgentBatch(
                {k: self._decode_tree(v, depth + 1, memo) for k, v in obj.policy_refs.items()}
            )
        if isinstance(obj, tuple):
            return tuple(self._decode_tree(x, depth + 1, memo) for x in obj)
        if isinstance(obj, list):
            return [self._decode_tree(x, depth + 1, memo) for x in obj]
        if isinstance(obj, dict):
            return {k: self._decode_tree(v, depth + 1, memo) for k, v in obj.items()}
        return obj

    def _materialize(self, ref: _SocketBatchRef) -> Any:
        from repro.rl.sample_batch import SampleBatch

        base = np.frombuffer(ref.blob, dtype=np.uint8)  # bytes -> read-only view
        cols: Dict[str, np.ndarray] = {}
        for c in ref.columns:
            cols[c.key] = (
                base[c.offset : c.offset + c.nbytes]
                .view(np.dtype(c.dtype))
                .reshape(c.shape)
            )
        batch = SampleBatch(cols)
        if ref.created_at is not None:
            batch.created_at = ref.created_at
        self.stats["socket_batches"] += 1
        self.stats["bytes_socket"] += len(ref.blob)
        return batch

    def reclaim(self, names: List[str]) -> None:
        pass

    def rollback(self, payload: Any) -> None:
        pass

    def drain_releases(self) -> List[str]:
        return []

    def close(self, unlink: bool = True) -> None:
        pass


# --------------------------------------------------------------------------
# Transport specs (picklable configuration shipped into the child)
# --------------------------------------------------------------------------
class Transport:
    """Picklable spec describing how RPC payloads cross a process boundary.

    ``server_endpoint(prefix)`` is built in the producing (child) process,
    ``client_endpoint(prefix)`` in the consuming (driver) process; the pair
    shares only the name ``prefix`` and the control messages on the pipe.
    """

    name = "abstract"

    def server_endpoint(self, prefix: str) -> Any:
        raise NotImplementedError

    def client_endpoint(self, prefix: str) -> Any:
        raise NotImplementedError


class PickleTransport(Transport):
    """Baseline: every payload is pickled through the RPC pipe."""

    name = "pickle"

    def server_endpoint(self, prefix: str) -> _IdentityEndpoint:
        return _IdentityEndpoint()

    def client_endpoint(self, prefix: str) -> _IdentityEndpoint:
        return _IdentityEndpoint()


class SharedMemoryTransport(Transport):
    """Zero-copy data plane over ``multiprocessing.shared_memory`` rings."""

    name = "shm"

    def __init__(
        self,
        threshold: int = 16 * 1024,
        min_segment: int = 1 << 20,
        max_segments: int = 16,
    ):
        self.threshold = threshold
        self.min_segment = min_segment
        self.max_segments = max_segments

    def server_endpoint(self, prefix: str) -> ShmWriter:
        return ShmWriter(
            prefix,
            threshold=self.threshold,
            min_segment=self.min_segment,
            max_segments=self.max_segments,
        )

    def client_endpoint(self, prefix: str) -> ShmReader:
        return ShmReader(prefix)


class SocketTransport(Transport):
    """Inter-host data plane: payloads ride length-prefixed socket frames.

    The endpoint pair mirrors the shm transport's API (encode/decode/
    reclaim/rollback/drain_releases/close), so ``core.remote`` drives it
    exactly the way ``ProcessCell`` drives its transport — but the payload
    crosses a TCP stream (``encode_frame``/``FrameDecoder``), not a pipe,
    and batch columns travel as one contiguous blob per batch.  Shm refs
    must never reach this transport: a segment name is meaningless on
    another machine (the ``cross-host-placement`` flowcheck rule enforces
    the corresponding graph-level invariant).
    """

    name = "socket"

    def server_endpoint(self, prefix: str) -> SocketWriter:
        return SocketWriter(prefix)

    def client_endpoint(self, prefix: str) -> SocketReader:
        return SocketReader(prefix)


TRANSPORTS: Dict[str, Callable[[], Transport]] = {
    "pickle": PickleTransport,
    "shm": SharedMemoryTransport,
    "socket": SocketTransport,
}


def resolve_transport(transport: Any) -> Transport:
    """None -> SharedMemoryTransport (the fast default; it falls back to the
    pipe per-message); str -> registry lookup; instance passthrough."""
    if transport is None:
        return SharedMemoryTransport()
    if isinstance(transport, Transport):
        return transport
    if isinstance(transport, str):
        if transport not in TRANSPORTS:
            raise ValueError(
                f"unknown transport {transport!r}; known: {sorted(TRANSPORTS)}"
            )
        return TRANSPORTS[transport]()
    raise TypeError(f"transport must be None, str, or Transport (got {transport!r})")
