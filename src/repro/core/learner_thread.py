"""Learner thread: decouples gradient updates from the dataflow driver.

High-throughput plans (Ape-X, IMPALA) keep the learner busy on its own thread
fed by an in-queue; results (and replay priorities) surface on an out-queue.
This is exactly the paper's Listing A3 LearnerThread.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Optional, Tuple

from repro.core.metrics import LEARN_ON_BATCH_TIMER, TimerStat

__all__ = ["LearnerThread"]


class LearnerThread(threading.Thread):
    def __init__(
        self,
        local_worker: Any,
        in_queue_size: int = 16,
        out_queue_size: int = 64,
    ):
        super().__init__(name="learner", daemon=True)
        self.local_worker = local_worker
        self.inqueue: "queue.Queue[Any]" = queue.Queue(maxsize=in_queue_size)
        self.outqueue: "queue.Queue[Tuple[Any, Any, int]]" = queue.Queue(maxsize=out_queue_size)
        self.weights_updated = False
        self.stopped = False
        self.learn_timer = TimerStat()
        self.num_steps = 0

    def run(self) -> None:
        while not self.stopped:
            try:
                item = self.inqueue.get(timeout=0.1)
            except queue.Empty:
                continue
            # Items may be (batch, replay_actor) pairs or bare batches.
            if isinstance(item, tuple) and len(item) == 2:
                batch, source_actor = item
            else:
                batch, source_actor = item, None
            with self.learn_timer:
                info = self.local_worker.learn_on_batch(batch)
            self.weights_updated = True
            self.num_steps += 1
            try:
                self.outqueue.put((source_actor, batch, info), block=False)
            except queue.Full:
                pass  # metrics loss is tolerable (paper §3: weak consistency)

    def stop(self) -> None:
        self.stopped = True
