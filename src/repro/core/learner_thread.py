"""Learner thread: decouples gradient updates from the dataflow driver.

High-throughput plans (Ape-X, IMPALA) keep the learner busy on its own thread
fed by an in-queue; results (and replay priorities) surface on an out-queue.
This is exactly the paper's Listing A3 LearnerThread.

Data-plane instrumentation (ISSUE 3): when the flow runtime hands the thread
its shared ``MetricsContext`` (``FlowRuntime.ensure_started``), every batch
learned records

  * ``sample_to_learn_s``    — end-to-end latency from the batch's birth
    stamp (``SampleBatch.created_at``, monotonic and cross-process on one
    host) to the moment the learner picks it up;
  * ``learner_queue_wait_s`` — time spent waiting in the in-queue (stamped
    by ``Enqueue``);
  * ``queue_occupancy/learner_in|learner_out`` gauges.

The out-queue applies an overflow policy (``drop_newest`` keeps the paper's
lossy metrics behaviour; ``drop_oldest``/``block`` are available for flows
that treat learner info as load-bearing).
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Any, Optional, Tuple

from repro.core.metrics import (
    LEARNER_QUEUE_WAIT,
    QUEUE_OCCUPANCY_PREFIX,
    SAMPLE_TO_LEARN_LATENCY,
    MetricsContext,
    TimerStat,
)
from repro.core.transport import OverflowPolicy

__all__ = ["LearnerThread"]


class LearnerThread(threading.Thread):
    def __init__(
        self,
        local_worker: Any,
        in_queue_size: int = 16,
        out_queue_size: int = 64,
        out_policy: str = OverflowPolicy.DROP_NEWEST,
        num_learners: int = 0,
        microbatch: int = 0,
    ):
        super().__init__(name="learner", daemon=True)
        self.local_worker = local_worker
        # Sharded SPMD lowering (ISSUE 4): with num_learners/microbatch set,
        # updates run through a data-parallel learner group on a device
        # mesh instead of the worker's single-device learn_on_batch.
        # Declared in flow graphs via spec.learner_thread(workers,
        # num_learners=..., microbatch=...) (FlowRuntime passes params
        # through) and the worker stays the canonical weight owner.
        self.learner_group: Any = None
        if num_learners > 1 or microbatch > 1:
            if hasattr(local_worker, "_loss_for"):
                from repro.rl.learner_group import ShardedLearnerGroup

                self.learner_group = ShardedLearnerGroup(
                    local_worker, num_learners=num_learners, microbatch=microbatch
                )
            else:
                import logging

                logging.getLogger(__name__).warning(
                    "LearnerThread(num_learners=%d, microbatch=%d): worker %s "
                    "has no pure loss (_loss_for); falling back to its plain "
                    "single-device learn_on_batch",
                    num_learners, microbatch, type(local_worker).__name__,
                )
        self.inqueue: "queue.Queue[Any]" = queue.Queue(maxsize=in_queue_size)
        self.outqueue: "queue.Queue[Tuple[Any, Any, int]]" = queue.Queue(maxsize=out_queue_size)
        self.out_policy = OverflowPolicy.validate(out_policy)
        self.weights_updated = False
        self.stopped = False
        self.learn_timer = TimerStat()
        self.num_steps = 0
        self.num_out_dropped = 0
        # Shared metrics context of the owning flow; assigned by
        # FlowRuntime.ensure_started before start() (None = standalone use).
        self.metrics: Optional[MetricsContext] = None

    def run(self) -> None:
        while not self.stopped:
            try:
                item = self.inqueue.get(timeout=0.1)
            except queue.Empty:
                continue
            t_pickup = time.perf_counter()
            # Items may be (batch, replay_actor) pairs or bare batches.
            if isinstance(item, tuple) and len(item) == 2:
                batch, source_actor = item
            else:
                batch, source_actor = item, None
            self._record_latency(batch, t_pickup)
            learn = (
                self.learner_group.learn_on_batch
                if self.learner_group is not None
                else self.local_worker.learn_on_batch
            )
            with self.learn_timer:
                info = learn(batch)
            self.weights_updated = True
            self.num_steps += 1
            self._put_out((source_actor, batch, info))

    def _record_latency(self, batch: Any, t_pickup: float) -> None:
        if self.metrics is None:
            return
        created = getattr(batch, "created_at", None)
        if isinstance(created, float):
            self.metrics.latencies[SAMPLE_TO_LEARN_LATENCY].push(t_pickup - created)
        enqueued = getattr(batch, "_enqueued_at", None)
        if isinstance(enqueued, float):
            self.metrics.latencies[LEARNER_QUEUE_WAIT].push(t_pickup - enqueued)
        self.metrics.gauges[QUEUE_OCCUPANCY_PREFIX + "learner_in"] = self.inqueue.qsize()
        self.metrics.gauges[QUEUE_OCCUPANCY_PREFIX + "learner_out"] = self.outqueue.qsize()

    def _put_out(self, result: Tuple[Any, Any, Any]) -> None:
        if self.out_policy == OverflowPolicy.BLOCK:
            while not self.stopped:
                try:
                    self.outqueue.put(result, timeout=0.05)
                    return
                except queue.Full:
                    continue
            return
        try:
            self.outqueue.put(result, block=False)
            return
        except queue.Full:
            pass
        if self.out_policy == OverflowPolicy.DROP_OLDEST:
            while True:
                try:
                    self.outqueue.get_nowait()
                    self.num_out_dropped += 1
                except queue.Empty:
                    pass
                try:
                    self.outqueue.put(result, block=False)
                    return
                except queue.Full:
                    continue
        # DROP_NEWEST: metrics loss is tolerable (paper §3: weak consistency)
        self.num_out_dropped += 1

    def stop(self) -> None:
        self.stopped = True
