"""Concurrency operators: Concurrently (union), Enqueue/Dequeue (paper §4/5.2).

``Concurrently`` composes multiple dataflow fragments — the operator the paper
shows enabling Ape-X (store/replay/update sub-flows) and multi-agent PPO+DQN
composition that "end users could not do before without writing low-level
systems code".
"""

from __future__ import annotations

import queue
from typing import Any, List, Optional, Sequence, Union

from repro.core.iterators import LocalIterator, NextValueNotReady
from repro.core.metrics import NUM_SAMPLES_DROPPED, get_metrics

__all__ = ["Concurrently", "Enqueue", "Dequeue"]


def Concurrently(
    ops: Sequence[LocalIterator],
    mode: str = "round_robin",
    output_indexes: Optional[Sequence[int]] = None,
    round_robin_weights: Optional[Sequence[Union[int, str]]] = None,
) -> LocalIterator:
    """Execute dataflow fragments concurrently; emit from ``output_indexes``.

    mode='round_robin' -> deterministic interleave (optionally weighted — the
        rate-limiting facility for e.g. 1:4 store:replay ratios [Acme]).
    mode='async'       -> each fragment driven independently; items surface in
        completion order (maximum pipeline parallelism).
    """
    if not ops:
        raise ValueError("Concurrently needs at least one op")
    if mode not in ("round_robin", "async"):
        raise ValueError(f"unknown mode {mode!r}")
    out_idx = list(output_indexes) if output_indexes is not None else list(range(len(ops)))
    for i in out_idx:
        if not (0 <= i < len(ops)):
            raise ValueError(f"output index {i} out of range")

    # Tag items with their branch so we can filter after the union.
    tagged: List[LocalIterator] = [
        op.for_each(lambda item, _i=i: (_i, item)) for i, op in enumerate(ops)
    ]

    merged = tagged[0].union(
        *tagged[1:],
        deterministic=(mode == "round_robin"),
        round_robin_weights=round_robin_weights,
    )

    def _select(tagged_item: Any) -> Any:
        i, item = tagged_item
        return item if i in out_idx else NextValueNotReady()

    return merged.for_each(_select)


class Enqueue:
    """Push items into a bounded queue (e.g. a learner thread's in-queue).

    Returns the item (so the flow can continue); drops with a counter if the
    queue is full — matching Ape-X's num_samples_dropped behaviour.  Drops
    are also recorded in the shared metrics context (``num_samples_dropped``)
    so they surface in ``Algorithm.train()`` result dicts.

    ``check`` (like ``Dequeue``'s) guards blocking puts: while the consumer
    is alive the put retries with a timeout; once ``check()`` is False the
    stage raises instead of blocking a Concurrently driver thread forever
    against a queue nobody will ever drain (flow teardown, dead learner).
    """

    share_across_shards = True
    flow_pure = True  # always returns the item (never NextValueNotReady)

    def __init__(self, out_queue: "queue.Queue", block: bool = False, check: Any = None):
        self.queue = out_queue
        self.block = block
        self.check = check
        self.num_dropped = 0

    def __call__(self, item: Any) -> Any:
        if self.block and self.check is not None:
            while self.check():
                try:
                    self.queue.put(item, timeout=0.05)
                    return item
                except queue.Full:
                    continue
            raise RuntimeError("Enqueue check failed: consumer is dead")
        try:
            self.queue.put(item, block=self.block)
        except queue.Full:
            self.num_dropped += 1
            get_metrics().counters[NUM_SAMPLES_DROPPED] += 1
        return item


def Dequeue(in_queue: "queue.Queue", check: Any = None) -> LocalIterator:
    """Iterator over items popped from a queue (e.g. learner out-queue)."""

    def _gen():
        while True:
            if check is not None and not check():
                raise RuntimeError("Dequeue check failed: producer is dead")
            try:
                yield in_queue.get(timeout=0.05)
            except queue.Empty:
                yield NextValueNotReady()

    return LocalIterator(_gen, name="Dequeue")
