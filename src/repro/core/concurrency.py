"""Concurrency operators: Concurrently (union), Enqueue/Dequeue (paper §4/5.2).

``Concurrently`` composes multiple dataflow fragments — the operator the paper
shows enabling Ape-X (store/replay/update sub-flows) and multi-agent PPO+DQN
composition that "end users could not do before without writing low-level
systems code".

``Enqueue``/``Dequeue`` are the credited boundary between a flow and a
deferred resource (learner thread): the queue window is the credit pool, and
``Enqueue``'s overflow policy (``block | drop_newest | drop_oldest``) decides
what happens when the consumer falls behind — with stalls, drops, occupancy,
and bytes all recorded into the shared metrics context (ISSUE 3).
"""

from __future__ import annotations

import queue
import time
from typing import Any, List, Optional, Sequence, Union

from repro.core.iterators import LocalIterator, NextValueNotReady
from repro.core.metrics import (
    BYTES_MOVED_PREFIX,
    CREDIT_STALL_TIME,
    NUM_CREDIT_STALLS,
    NUM_SAMPLES_DROPPED,
    QUEUE_OCCUPANCY_PREFIX,
    get_metrics,
    payload_nbytes,
)
from repro.core.transport import OverflowPolicy

__all__ = ["Concurrently", "Enqueue", "Dequeue", "OverflowPolicy"]


def Concurrently(
    ops: Sequence[LocalIterator],
    mode: str = "round_robin",
    output_indexes: Optional[Sequence[int]] = None,
    round_robin_weights: Optional[Sequence[Union[int, str]]] = None,
) -> LocalIterator:
    """Execute dataflow fragments concurrently; emit from ``output_indexes``.

    mode='round_robin' -> deterministic interleave (optionally weighted — the
        rate-limiting facility for e.g. 1:4 store:replay ratios [Acme]).
    mode='async'       -> each fragment driven independently; items surface in
        completion order (maximum pipeline parallelism).
    """
    if not ops:
        raise ValueError("Concurrently needs at least one op")
    if mode not in ("round_robin", "async"):
        raise ValueError(f"unknown mode {mode!r}")
    out_idx = list(output_indexes) if output_indexes is not None else list(range(len(ops)))
    for i in out_idx:
        if not (0 <= i < len(ops)):
            raise ValueError(f"output index {i} out of range")

    # Tag items with their branch so we can filter after the union.
    tagged: List[LocalIterator] = [
        op.for_each(lambda item, _i=i: (_i, item)) for i, op in enumerate(ops)
    ]

    merged = tagged[0].union(
        *tagged[1:],
        deterministic=(mode == "round_robin"),
        round_robin_weights=round_robin_weights,
    )

    def _select(tagged_item: Any) -> Any:
        i, item = tagged_item
        return item if i in out_idx else NextValueNotReady()

    return merged.for_each(_select)


class Enqueue:
    """Push items into a bounded queue (e.g. a learner thread's in-queue).

    Returns the item (so the flow can continue).  The queue's capacity is the
    credit window; ``policy`` decides what happens when it is exhausted:

      * ``block``       — wait for a free slot, charging the wait to
        ``credit_stall_time_s`` / ``num_credit_stalls`` (lossless Ape-X feed,
        backpressuring the producing sub-flow).
      * ``drop_newest`` — reject the incoming item and count it in
        ``num_samples_dropped`` (the paper's lossy Ape-X behaviour).
      * ``drop_oldest`` — evict the stalest queued item to admit the fresh
        one (bounded staleness: what you want for on-policy-ish feeds).

    Bytes enqueued are recorded under ``bytes_moved/<metrics_key>`` and the
    queue depth is gauged under ``queue_occupancy/<metrics_key>`` so the
    numbers surface in ``Algorithm.train()`` results and ``to_dot()`` labels.

    ``check`` guards blocking puts: while the consumer is alive the put
    retries with a timeout; once ``check()`` is False the stage raises
    instead of blocking a Concurrently driver thread forever against a queue
    nobody will ever drain (flow teardown, dead learner).

    ``block=True/False`` is accepted as a legacy alias for
    ``policy="block"/"drop_newest"``.
    """

    share_across_shards = True
    flow_pure = True  # always returns the item (never NextValueNotReady)

    def __init__(
        self,
        out_queue: "queue.Queue",
        block: Optional[bool] = None,
        check: Any = None,
        policy: Optional[str] = None,
        metrics_key: Optional[str] = None,
    ):
        if policy is None:
            policy = OverflowPolicy.BLOCK if block else OverflowPolicy.DROP_NEWEST
        elif block is not None:
            raise ValueError("pass either block= (legacy) or policy=, not both")
        self.queue = out_queue
        self.policy = OverflowPolicy.validate(policy)
        self.check = check
        self.metrics_key = metrics_key or "enqueue"
        self.num_dropped = 0

    # Kept for callers/tests introspecting the legacy flag.
    @property
    def block(self) -> bool:
        return self.policy == OverflowPolicy.BLOCK

    def __call__(self, item: Any) -> Any:
        metrics = get_metrics()
        if self.policy == OverflowPolicy.BLOCK:
            try:
                self._stamp(item)
                self.queue.put(item, block=False)
            except queue.Full:
                # The window is exhausted: this producer is now stalled on a
                # credit, however briefly — record it, then wait it out.
                stalled_at = time.perf_counter()
                metrics.counters[NUM_CREDIT_STALLS] += 1
                while self.check is None or self.check():
                    try:
                        # Re-stamp per attempt: the queue-wait metric must
                        # measure residency in the queue, not this
                        # producer-side credit stall (already counted).
                        self._stamp(item)
                        self.queue.put(item, timeout=0.05)
                        break
                    except queue.Full:
                        continue
                else:
                    raise RuntimeError("Enqueue check failed: consumer is dead")
                metrics.counters[CREDIT_STALL_TIME] = (
                    metrics.counters.get(CREDIT_STALL_TIME, 0)
                    + (time.perf_counter() - stalled_at)
                )
        elif self.policy == OverflowPolicy.DROP_OLDEST:
            while True:
                try:
                    self._stamp(item)
                    self.queue.put(item, block=False)
                    break
                except queue.Full:
                    try:
                        self.queue.get_nowait()
                        self.num_dropped += 1
                        metrics.counters[NUM_SAMPLES_DROPPED] += 1
                    except queue.Empty:
                        continue  # consumer drained it first: retry the put
        else:  # DROP_NEWEST
            try:
                self._stamp(item)
                self.queue.put(item, block=False)
            except queue.Full:
                self.num_dropped += 1
                metrics.counters[NUM_SAMPLES_DROPPED] += 1
                metrics.gauges[QUEUE_OCCUPANCY_PREFIX + self.metrics_key] = (
                    self.queue.qsize()
                )
                return item
        nbytes = payload_nbytes(item)
        if nbytes:
            metrics.counters[BYTES_MOVED_PREFIX + self.metrics_key] += nbytes
        metrics.gauges[QUEUE_OCCUPANCY_PREFIX + self.metrics_key] = self.queue.qsize()
        return item

    @staticmethod
    def _stamp(item: Any) -> None:
        """Mark the enqueue instant on the payload batch (queue-wait latency
        is measured by the consumer; see ``LearnerThread``)."""
        batch = item[0] if isinstance(item, tuple) and item else item
        try:
            batch._enqueued_at = time.perf_counter()
        except (AttributeError, TypeError):
            pass  # non-batch payloads simply go unmeasured


def Dequeue(
    in_queue: "queue.Queue", check: Any = None, metrics_key: Optional[str] = None
) -> LocalIterator:
    """Iterator over items popped from a queue (e.g. learner out-queue)."""
    key = metrics_key or "dequeue"

    def _gen():
        while True:
            if check is not None and not check():
                raise RuntimeError("Dequeue check failed: producer is dead")
            try:
                item = in_queue.get(timeout=0.05)
            except queue.Empty:
                yield NextValueNotReady()
                continue
            get_metrics().gauges[QUEUE_OCCUPANCY_PREFIX + key] = in_queue.qsize()
            yield item

    return LocalIterator(_gen, name="Dequeue")
