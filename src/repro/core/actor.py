"""Virtual actors: the process abstraction underneath RLlib Flow iterators.

The paper implements dataflow shards on Ray actors.  On a TPU pod there is no
per-chip RPC endpoint, so we provide *virtual actors*: Python objects that own
state (policy params, env state, replay shards) plus a dedicated executor
thread that serializes method execution, giving Ray-like semantics:

  * ``actor.call(method, *args)``  -> Future   (async, like ``.remote()``)
  * ``actor.sync(method, *args)``  -> result   (blocking convenience)
  * per-actor FIFO execution order (one mailbox thread per actor)
  * ``wait(futures, num_returns)`` (like ``ray.wait``) with *batched wait* —
    the small optimization the paper credits for Fig 13a throughput wins.

JAX releases the GIL inside compiled computations, so virtual actors provide
true overlap of device compute even in a single process.  On a real multi-host
pod, one ``ActorPool`` maps onto per-host processes and ``core/spmd.py`` fuses
synchronous fragments into single pjit programs instead (see DESIGN.md §3).
"""

from __future__ import annotations

import itertools
import queue
import threading
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "VirtualActor",
    "ActorHandle",
    "ActorPool",
    "wait",
    "get",
    "create_colocated",
]

_actor_ids = itertools.count()

import logging

_logger = logging.getLogger(__name__)


def _log_if_failed(actor_name: str, method: str):
    def _cb(fut: Future) -> None:
        exc = fut.exception()
        if exc is not None and not isinstance(exc, StopIteration):
            _logger.error("actor %s.%s failed: %r", actor_name, method, exc)

    return _cb


class VirtualActor:
    """A stateful worker with a mailbox thread.

    ``target`` is any object; method calls are dispatched by name onto the
    mailbox thread so actor state is never accessed concurrently (the Ray
    actor model's serialized-execution guarantee).
    """

    def __init__(self, target: Any, name: Optional[str] = None):
        self.target = target
        self.actor_id = next(_actor_ids)
        self.name = name or f"{type(target).__name__}-{self.actor_id}"
        self._inbox: "queue.Queue[Optional[Tuple[Future, Callable, tuple, dict]]]" = queue.Queue()
        self._thread = threading.Thread(
            target=self._run_loop, name=f"actor-{self.name}", daemon=True
        )
        self._alive = True
        self._thread.start()

    # ------------------------------------------------------------------ api
    def call(self, method: str, *args: Any, **kwargs: Any) -> Future:
        """Asynchronously invoke ``target.<method>(*args)``; returns a Future."""
        if not self._alive:
            raise RuntimeError(f"actor {self.name} is stopped")
        fut: Future = Future()
        fn = getattr(self.target, method)
        # Fire-and-forget callers never see exceptions; log them so failures
        # in message-passing operators (StoreToReplayBuffer, ...) surface.
        fut.add_done_callback(_log_if_failed(self.name, method))
        self._inbox.put((fut, fn, args, kwargs))
        return fut

    def apply(self, fn: Callable[[Any], Any], *args: Any) -> Future:
        """Asynchronously run ``fn(target, *args)`` on the actor thread.

        This is how parallel transformations are *scheduled onto the source
        actor* (paper §4, Transformation): the callable sees actor-local state.
        """
        if not self._alive:
            raise RuntimeError(f"actor {self.name} is stopped")
        fut: Future = Future()
        self._inbox.put((fut, fn, (self.target, *args), {}))
        return fut

    def sync(self, method: str, *args: Any, **kwargs: Any) -> Any:
        return self.call(method, *args, **kwargs).result()

    def stop(self) -> None:
        if self._alive:
            self._alive = False
            self._inbox.put(None)
            self._thread.join(timeout=5.0)

    # ------------------------------------------------------------- internals
    def _run_loop(self) -> None:
        while True:
            item = self._inbox.get()
            if item is None:
                return
            fut, fn, args, kwargs = item
            if fut.set_running_or_notify_cancel():
                try:
                    fut.set_result(fn(*args, **kwargs))
                except BaseException as exc:  # propagate to the caller
                    fut.set_exception(exc)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"VirtualActor({self.name})"


# ``ActorHandle`` is what flows through dataflow metadata (zip_with_source_actor)
ActorHandle = VirtualActor


class ActorPool:
    """A named group of actors — the unit a ParallelIterator shards over."""

    def __init__(self, actors: Sequence[VirtualActor], name: str = "pool"):
        if not actors:
            raise ValueError("ActorPool needs at least one actor")
        self.actors: List[VirtualActor] = list(actors)
        self.name = name

    @classmethod
    def from_targets(cls, targets: Sequence[Any], name: str = "pool") -> "ActorPool":
        return cls([VirtualActor(t) for t in targets], name=name)

    def __len__(self) -> int:
        return len(self.actors)

    def __iter__(self):
        return iter(self.actors)

    def __getitem__(self, i: int) -> VirtualActor:
        return self.actors[i]

    # Broadcast a method call to every actor; returns futures.
    def broadcast(self, method: str, *args: Any, **kwargs: Any) -> List[Future]:
        return [a.call(method, *args, **kwargs) for a in self.actors]

    def broadcast_sync(self, method: str, *args: Any, **kwargs: Any) -> List[Any]:
        return [f.result() for f in self.broadcast(method, *args, **kwargs)]

    def stop(self) -> None:
        for a in self.actors:
            a.stop()


def wait(
    futures: Sequence[Future],
    num_returns: int = 1,
    timeout: Optional[float] = None,
) -> Tuple[List[Future], List[Future]]:
    """``ray.wait`` equivalent: split futures into (ready, pending).

    Blocks until ``num_returns`` futures are done (or timeout).  Uses a single
    condition variable over all futures — the *batched RPC wait* the paper
    cites as an easy cross-algorithm optimization (Fig 13a).
    """
    futures = list(futures)
    if num_returns > len(futures):
        raise ValueError(f"num_returns={num_returns} > #futures={len(futures)}")
    cond = threading.Condition()
    n_done = [0]

    def _on_done(_f: Future) -> None:
        with cond:
            n_done[0] += 1
            cond.notify_all()

    for f in futures:
        f.add_done_callback(_on_done)
    with cond:
        cond.wait_for(lambda: sum(f.done() for f in futures) >= num_returns, timeout)
    ready = [f for f in futures if f.done()]
    pending = [f for f in futures if not f.done()]
    # Deterministic "first num_returns" semantics like ray.wait
    return ready[:max(num_returns, len(ready))], pending


def get(obj: Any) -> Any:
    """``ray.get`` equivalent (works on Futures, lists of Futures, plain values)."""
    if isinstance(obj, Future):
        return obj.result()
    if isinstance(obj, (list, tuple)):
        return type(obj)(get(o) for o in obj)
    return obj


def create_colocated(
    factory: Callable[[], Any], count: int, name: str = "colocated"
) -> ActorPool:
    """Paper's ``create_colocated`` (Ape-X replay actors): a colocation group.

    On Ray this pins actors to the head node; here all virtual actors share
    the process, so colocation is a naming/grouping concern only.
    """
    return ActorPool.from_targets([factory() for _ in range(count)], name=name)
