"""Virtual actors: the process abstraction underneath RLlib Flow iterators.

The paper implements dataflow shards on Ray actors.  On a TPU pod there is no
per-chip RPC endpoint, so we provide *virtual actors*: Python objects that own
state (policy params, env state, replay shards) plus a dedicated executor
thread that serializes method execution, giving Ray-like semantics:

  * ``actor.call(method, *args)``  -> Future   (async, like ``.remote()``)
  * ``actor.sync(method, *args)``  -> result   (blocking convenience)
  * per-actor FIFO execution order (one mailbox thread per actor)
  * ``wait(futures, num_returns)`` (like ``ray.wait``) with *batched wait* —
    the small optimization the paper credits for Fig 13a throughput wins.

Where the target executes is pluggable (``core.executor``): ``ThreadBackend``
keeps it in-process (JAX releases the GIL inside compiled computations, so
virtual actors still overlap device compute); ``ProcessBackend`` builds it in
a child process from a pickled factory and turns method calls into pipe RPCs.
Actors are also *supervised*: with a factory and ``max_restarts`` the target
is rebuilt with exponential backoff after a failure, and a ``FailurePolicy``
tells downstream gather operators whether to restart, drop the shard, or
raise (see ``core.iterators``).
"""

from __future__ import annotations

import itertools
import queue
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, List, Optional, Sequence, Tuple

from repro.core.executor import (
    ActorDiedError,
    ActorError,
    ExecutionBackend,
    FailurePolicy,
    SupervisorSpec,
    resolve_backend,
)

__all__ = [
    "VirtualActor",
    "ActorHandle",
    "ActorPool",
    "wait",
    "get",
    "create_colocated",
]

_actor_ids = itertools.count()

import logging

_logger = logging.getLogger(__name__)


def _log_if_failed(actor_name: str, method: str):
    def _cb(fut: Future) -> None:
        exc = fut.exception()
        # StopIteration = stream exhaustion; AttributeError = protocol probe
        # against an optional method (configure_vectorization, get_state,
        # episode_stats on legacy workers).  Both are expected control flow,
        # not worker faults — same exemption the supervision path applies.
        if exc is not None and not isinstance(exc, (StopIteration, AttributeError)):
            _logger.error("actor %s.%s failed: %s", actor_name, method, repr(exc))

    return _cb


class VirtualActor:
    """A stateful worker with a mailbox thread.

    ``target`` is any object; method calls are dispatched by name onto the
    mailbox thread so actor state is never accessed concurrently (the Ray
    actor model's serialized-execution guarantee).  Alternatively pass a
    zero-arg ``factory`` — required for ``ProcessBackend`` (the factory is
    pickled into the child) and for supervision (``max_restarts`` rebuilds
    the target from the factory after a failure).
    """

    def __init__(
        self,
        target: Any = None,
        name: Optional[str] = None,
        *,
        factory: Optional[Callable[[], Any]] = None,
        backend: Any = None,
        max_restarts: int = 0,
        backoff_base: float = 0.05,
        backoff_cap: float = 2.0,
        failure_policy: str = FailurePolicy.RAISE,
        restart_window_s: Optional[float] = None,
    ):
        if (target is None) == (factory is None):
            raise ValueError("pass exactly one of target= or factory=")
        if max_restarts > 0 and factory is None:
            raise ValueError("max_restarts > 0 requires a factory= (restart rebuilds the target)")
        self._backend: ExecutionBackend = resolve_backend(backend)
        self._factory = factory
        self._cell = self._backend.make_cell(factory=factory, target=target)
        self.supervision = SupervisorSpec(
            max_restarts=max_restarts,
            backoff_base=backoff_base,
            backoff_cap=backoff_cap,
            failure_policy=failure_policy,
            restart_window_s=restart_window_s,
        )
        self.failure_policy = self.supervision.failure_policy
        self.actor_id = next(_actor_ids)
        base = type(target).__name__ if target is not None else getattr(
            factory, "__name__", type(factory).__name__
        )
        self.name = name or f"{base}-{self.actor_id}"
        self._inbox: "queue.Queue[Optional[Tuple[Future, str, Any, tuple, dict]]]" = queue.Queue()
        self._thread = threading.Thread(
            target=self._run_loop, name=f"actor-{self.name}", daemon=True
        )
        self._alive = True
        self._dead = False
        self.num_failures = 0
        self.num_restarts = 0
        self._budget_used = 0
        self._last_failure_t: Optional[float] = None
        self._thread.start()

    # ----------------------------------------------------------- properties
    @property
    def target(self) -> Any:
        """The execution target (real object, or an RPC proxy for processes)."""
        return self._cell.target

    @property
    def alive(self) -> bool:
        """False once stopped, killed, or the restart budget is exhausted."""
        return self._alive and not self._dead

    @property
    def backend_name(self) -> str:
        return self._backend.name

    # ------------------------------------------------------------------ api
    def call(self, method: str, *args: Any, **kwargs: Any) -> Future:
        """Asynchronously invoke ``target.<method>(*args)``; returns a Future."""
        fut = self._submit("method", method, args, kwargs)
        # Fire-and-forget callers never see exceptions; log them so failures
        # in message-passing operators (StoreToReplayBuffer, ...) surface.
        fut.add_done_callback(_log_if_failed(self.name, method))
        return fut

    def apply(self, fn: Callable[..., Any], *args: Any) -> Future:
        """Asynchronously run ``fn(target, *args)`` on the actor thread.

        This is how parallel transformations are *scheduled onto the source
        actor* (paper §4, Transformation): the callable sees actor-local
        state (or, under ``ProcessBackend``, a proxy to it).
        """
        return self._submit("apply", fn, args, {})

    def sync(self, method: str, *args: Any, **kwargs: Any) -> Any:
        return self.call(method, *args, **kwargs).result()

    def kill(self) -> None:
        """Simulate hard actor loss: the execution vehicle is torn down and
        every queued/future call fails with ``ActorDiedError``."""
        self._dead = True
        self._cell.kill()

    def restart(self, timeout: float = 10.0) -> None:
        """Force-rebuild the target from its factory and mark the actor
        alive again (resets the supervisor's restart budget).  Runs on the
        mailbox thread so it serializes with in-flight calls.

        Concurrent restarts *coalesce*: a queued restart that finds the
        actor already healed (another caller's restart won the race) is a
        no-op.  Without this, two clients of a shared actor — e.g. rollout
        shards recovering one InferenceActor — would rebuild it twice, the
        second rebuild silently discarding whatever state (re-synced
        weights) the first recovery installed between the two."""
        if not self._alive:
            raise RuntimeError(f"actor {self.name} is stopped")
        if self._factory is None:
            raise ActorError(f"actor {self.name} has no factory; cannot restart")
        fut: Future = Future()
        self._inbox.put((fut, "restart", None, (), {}))
        fut.result(timeout=timeout)

    def rehome(self, backend: Any, timeout: float = 60.0) -> None:
        """Move this actor's target onto a different execution backend.

        The fragment assembler's lever (``flow.compile``): a pool built on
        the default backend is re-homed onto the ``RemoteBackend`` of its
        placement host at lowering time.  The new cell rebuilds the target
        from the factory (fresh state, like ``restart``), so only
        factory-built actors can move.  Serializes through the mailbox
        thread: calls queued behind the rehome reach the new cell.
        """
        if not self._alive:
            raise RuntimeError(f"actor {self.name} is stopped")
        if self._factory is None:
            raise ActorError(f"actor {self.name} has no factory; cannot rehome")
        fut: Future = Future()
        self._inbox.put((fut, "rehome", resolve_backend(backend), (), {}))
        fut.result(timeout=timeout)

    def stop(self) -> None:
        if self._alive:
            self._alive = False
            self._inbox.put(None)
            self._thread.join(timeout=5.0)
            self._cell.stop()

    # ------------------------------------------------------------- internals
    def _submit(self, kind: str, fn_or_method: Any, args: tuple, kwargs: dict) -> Future:
        if not self._alive:
            raise RuntimeError(f"actor {self.name} is stopped")
        fut: Future = Future()
        if self._dead:
            fut.set_exception(ActorDiedError(f"actor {self.name} is dead"))
            return fut
        self._inbox.put((fut, kind, fn_or_method, args, kwargs))
        return fut

    def _run_loop(self) -> None:
        while True:
            item = self._inbox.get()
            if item is None:
                return
            fut, kind, fn_or_method, args, kwargs = item
            if kind == "restart":
                self._manual_restart(fut)
                continue
            if kind == "rehome":
                self._do_rehome(fut, fn_or_method)
                continue
            if self._dead:
                fut.set_exception(ActorDiedError(f"actor {self.name} is dead"))
                continue
            if not fut.set_running_or_notify_cancel():
                continue
            try:
                # Resolve against the *current* cell target at execution time
                # so calls queued across a restart reach the fresh target.
                if kind == "method":
                    result = getattr(self._cell.target, fn_or_method)(*args, **kwargs)
                else:  # apply
                    result = fn_or_method(self._cell.target, *args, **kwargs)
            except BaseException as exc:
                # StopIteration = stream exhaustion; AttributeError = protocol
                # probe against an optional method (episode_stats, get_state).
                # Neither is a worker fault: supervision must not burn a
                # restart (wiping worker state) on them.
                if isinstance(exc, Exception) and not isinstance(
                    exc, (StopIteration, AttributeError)
                ):
                    self._handle_failure(exc)
                fut.set_exception(exc)
            else:
                fut.set_result(result)

    def _do_rehome(self, fut: Future, backend: ExecutionBackend) -> None:
        """Mailbox-thread half of ``rehome``: build the new cell first, so a
        backend that cannot construct (unreachable host) leaves the actor
        exactly where it was."""
        old_cell = self._cell
        try:
            new_cell = backend.make_cell(factory=self._factory)
        except BaseException as exc:
            fut.set_exception(exc)
            return
        self._backend = backend
        self._cell = new_cell
        self._dead = False
        self._budget_used = 0
        try:
            old_cell.stop()
        except Exception:
            pass
        fut.set_result(None)

    def _manual_restart(self, fut: Future) -> None:
        if not self._dead and self._cell.alive:
            fut.set_result(None)  # coalesced: already healed by another caller
            return
        try:
            self._cell.restart()
        except BaseException as exc:
            self._mark_dead()
            fut.set_exception(exc)
        else:
            self._dead = False
            self._budget_used = 0
            self.num_restarts += 1
            fut.set_result(None)

    def _handle_failure(self, exc: Exception) -> None:
        """Supervision (mailbox thread): restart with backoff, or mark dead."""
        self.num_failures += 1
        if self._dead:
            return
        sup = self.supervision
        died = isinstance(exc, ActorDiedError) or not self._cell.alive
        # Read the *mutable* failure_policy (flow-graph annotations may have
        # overridden the construction-time spec) so supervisor and gather
        # consumers always act on the same policy.
        if self.failure_policy == FailurePolicy.DROP_SHARD and not died:
            # Consumers drop the shard on first failure regardless, so a
            # rebuild (plus its backoff sleep, which would stall a gather
            # barrier blocked on this future) is pure waste.
            return
        # Healthy-window forgiveness: a full restart_window_s without a
        # supervised failure resets the budget (and the backoff exponent),
        # so the budget bounds crash *loops*, not lifetime failures.
        window = sup.restart_window_s
        if (
            window is not None
            and self._budget_used > 0
            and self._last_failure_t is not None
            and time.monotonic() - self._last_failure_t >= window
        ):
            self._budget_used = 0
        self._last_failure_t = time.monotonic()
        if sup.max_restarts > 0 and self._budget_used < sup.max_restarts:
            delay = sup.backoff(self._budget_used)
            if delay > 0:
                time.sleep(delay)
            try:
                self._cell.restart()
            except BaseException as rexc:
                _logger.error("actor %s restart failed: %s", self.name, repr(rexc))
                self._mark_dead()
                return
            self._budget_used += 1
            self.num_restarts += 1
            _logger.warning(
                "actor %s restarted (%d/%d, backoff %.3fs) after %s",
                self.name, self._budget_used, sup.max_restarts, delay, repr(exc),
            )
            return
        if died or sup.max_restarts > 0:
            # Transport gone, or a supervised actor out of budget: actor dies.
            _logger.error(
                "actor %s died after %d failures (%d restarts used): %s",
                self.name, self.num_failures, self._budget_used, repr(exc),
            )
            self._mark_dead()
        # Unsupervised target-level exceptions keep legacy semantics: the
        # future carries the exception, the actor stays alive.

    def _mark_dead(self) -> None:
        self._dead = True

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"VirtualActor({self.name}, backend={self._backend.name}, alive={self.alive})"


# ``ActorHandle`` is what flows through dataflow metadata (zip_with_source_actor)
ActorHandle = VirtualActor


class ActorPool:
    """A named group of actors — the unit a ParallelIterator shards over.

    The pool is *elastic*: ``add``/``remove``/``replace`` bump a version
    counter that pool-aware iterators use to pick up membership changes
    mid-stream (``Algorithm.add_workers()/remove_workers()``).
    """

    def __init__(self, actors: Sequence[VirtualActor], name: str = "pool"):
        if not actors:
            raise ValueError("ActorPool needs at least one actor")
        self.actors: List[VirtualActor] = list(actors)
        self.name = name
        self._version = 0

    @classmethod
    def from_targets(cls, targets: Sequence[Any], name: str = "pool") -> "ActorPool":
        return cls([VirtualActor(t) for t in targets], name=name)

    @classmethod
    def from_factories(
        cls,
        factories: Sequence[Callable[[], Any]],
        name: str = "pool",
        **actor_kwargs: Any,
    ) -> "ActorPool":
        """Supervised/process-backed pools: one factory per actor."""
        return cls(
            [VirtualActor(factory=f, **actor_kwargs) for f in factories], name=name
        )

    @property
    def version(self) -> int:
        """Bumped on every membership change (elastic iterator sync point)."""
        return self._version

    def __len__(self) -> int:
        return len(self.actors)

    def __iter__(self):
        return iter(list(self.actors))

    def __getitem__(self, i: int) -> VirtualActor:
        return self.actors[i]

    # -------------------------------------------------------------- elastic
    def add(self, actor: VirtualActor) -> None:
        self.actors.append(actor)
        self._version += 1

    def remove(self, actor: VirtualActor, stop: bool = True) -> None:
        self.actors.remove(actor)
        self._version += 1
        if stop:
            actor.stop()

    def replace(self, old: VirtualActor, new: VirtualActor, stop_old: bool = True) -> None:
        self.actors[self.actors.index(old)] = new
        self._version += 1
        if stop_old:
            old.stop()

    def alive_actors(self) -> List[VirtualActor]:
        return [a for a in self.actors if getattr(a, "alive", True)]

    # Broadcast a method call to every actor; returns futures.
    def broadcast(self, method: str, *args: Any, **kwargs: Any) -> List[Future]:
        return [a.call(method, *args, **kwargs) for a in self.actors]

    def broadcast_sync(self, method: str, *args: Any, **kwargs: Any) -> List[Any]:
        return [f.result() for f in self.broadcast(method, *args, **kwargs)]

    def stop(self) -> None:
        for a in self.actors:
            a.stop()


def wait(
    futures: Sequence[Future],
    num_returns: int = 1,
    timeout: Optional[float] = None,
) -> Tuple[List[Future], List[Future]]:
    """``ray.wait`` equivalent: split futures into (ready, pending).

    Blocks until ``num_returns`` futures are done (or timeout).  Uses a single
    condition variable over all futures — the *batched RPC wait* the paper
    cites as an easy cross-algorithm optimization (Fig 13a).
    """
    futures = list(futures)
    if num_returns > len(futures):
        raise ValueError(f"num_returns={num_returns} > #futures={len(futures)}")
    cond = threading.Condition()
    n_done = [0]

    def _on_done(_f: Future) -> None:
        with cond:
            n_done[0] += 1
            cond.notify_all()

    for f in futures:
        f.add_done_callback(_on_done)
    with cond:
        cond.wait_for(lambda: sum(f.done() for f in futures) >= num_returns, timeout)
    ready = [f for f in futures if f.done()]
    pending = [f for f in futures if not f.done()]
    # Deterministic "first num_returns" semantics like ray.wait
    return ready[:max(num_returns, len(ready))], pending


def get(obj: Any) -> Any:
    """``ray.get`` equivalent (works on Futures, lists of Futures, plain values)."""
    if isinstance(obj, Future):
        return obj.result()
    if isinstance(obj, (list, tuple)):
        return type(obj)(get(o) for o in obj)
    return obj


def create_colocated(
    factory: Callable[[], Any], count: int, name: str = "colocated"
) -> ActorPool:
    """Paper's ``create_colocated`` (Ape-X replay actors): a colocation group.

    On Ray this pins actors to the head node; here all virtual actors share
    the process, so colocation is a naming/grouping concern only.
    """
    return ActorPool.from_targets([factory() for _ in range(count)], name=name)
