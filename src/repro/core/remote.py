"""RemoteBackend: actors hosted in external processes over socket RPC.

The thread and process backends both live on the driver's box.  This module
crosses the host boundary — the missing piece between this runtime and the
MSRL/SRL-scale topologies ROADMAP item 1 names: a ``RemoteHost`` server
process (potentially on another machine) builds and owns actor targets, and
a ``RemoteCell`` on the driver speaks to it over a length-prefixed socket
RPC protocol (``core.transport.encode_frame``/``FrameDecoder``).

The protocol deliberately reuses the shapes the in-box runtime already has:

  * **Handshake with name-generation** — the first frame on a connection is
    ``("hello", name, prefix, factory_bytes, transport_bytes)``.  ``prefix``
    follows the ``ProcessCell`` scheme (``rmt<pid>x<cell>g<generation>``):
    a fresh generation per (re)connect, so a restarted cell gets a fresh
    target and its transport endpoints can never collide with a prior life.
    The host replies ``(True, {"pid": ..., "name": ...})`` once the target
    is constructed, or ``(False, exc)`` carrying the real construction
    error.
  * **RPC frames** — ``(method, args, kwargs, released)``: byte-identical
    in shape to the ``ProcessCell`` pipe message, so everything above the
    cell (``_Proxy``/``apply``, supervision, gather operators) is reused
    verbatim.  Replies are ``(ok, payload)`` with payload run through the
    cell's transport endpoints (``SocketTransport`` by default: batch
    columns as one contiguous blob per batch).
  * **Heartbeat** — an idle cell pings ``("__ping__", (), {}, [])`` on a
    background thread; the host answers without touching the target.  A
    failed ping marks the cell dead, so a lost machine surfaces as
    ``ActorDiedError`` (a *shard loss* to the failure policies) even when
    the flow is between calls.

A whole host dying takes every cell homed on it down at once — that is the
"machine loss" failure mode the chaos suite injects (``tests/chaos.py``),
and ``FailurePolicy.DROP_SHARD`` shrinks the shard set exactly as it does
for a killed worker process.
"""

from __future__ import annotations

import itertools
import logging
import os
import pickle
import socket
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core.executor import (
    ActorDiedError,
    ActorError,
    BACKENDS,
    Cell,
    ExecutionBackend,
    _Proxy,
    _ReturnTarget,
)
from repro.core.transport import (
    FrameDecoder,
    SocketTransport,
    Transport,
    encode_frame,
    resolve_transport,
)

__all__ = [
    "RemoteBackend",
    "RemoteCell",
    "LocalHostHandle",
    "start_local_host",
    "PING_METHOD",
]

_logger = logging.getLogger(__name__)

_cell_seq = itertools.count()

PING_METHOD = "__ping__"  # heartbeat: served host-side, never hits the target

_RECV_CHUNK = 1 << 16


def _resolve_remote_transport(transport: Any) -> Transport:
    """Default to the socket data plane: shm's ``resolve_transport(None)``
    default is an intra-host assumption this backend exists to break."""
    if transport is None:
        return SocketTransport()
    return resolve_transport(transport)


# --------------------------------------------------------------------------
# Host side: the server process that owns actor targets
# --------------------------------------------------------------------------
def _serve_remote_connection(conn: socket.socket, peer: Any) -> None:
    """Serve one actor cell over one connection (mirrors executor._serve).

    The first frame must be the hello handshake; after that the loop is the
    ``ProcessCell`` serve loop with the pipe swapped for framed sockets:
    reclaim released refs, dispatch the method, encode the result through
    the negotiated transport, reply ``(ok, payload)``.
    """
    decoder = FrameDecoder()
    target: Any = None
    encoder: Any = None

    def _send(obj: Any) -> None:
        conn.sendall(encode_frame(obj))

    def _frames():
        while True:
            try:
                chunk = conn.recv(_RECV_CHUNK)
            except OSError:
                return
            if not chunk:
                return
            for msg in decoder.feed(chunk):
                yield msg

    frames = _frames()
    try:
        try:
            hello = next(frames)
        except StopIteration:
            return
        try:
            kind, name, prefix, factory_bytes, transport_bytes = hello
            if kind != "hello":
                raise ActorError(f"expected hello handshake, got {kind!r}")
            spec = pickle.loads(transport_bytes)
            encoder = spec.server_endpoint(prefix)
            target = pickle.loads(factory_bytes)()
        except BaseException as exc:
            try:
                _send((False, exc))
            except Exception:
                _send((False, ActorError(f"target construction failed: {exc!r}")))
            return
        _send((True, {"pid": os.getpid(), "name": name}))
        for msg in frames:
            if msg is None:  # graceful cell shutdown
                return
            method, args, kwargs, released = msg
            encoder.reclaim(released)
            if method == PING_METHOD:
                _send((True, "pong"))
                continue
            try:
                result = getattr(target, method)(*args, **kwargs)
            except BaseException as exc:
                try:
                    _send((False, exc))
                except Exception:  # unpicklable exception: degrade to a summary
                    _send((False, ActorError(f"{type(exc).__name__}: {exc}")))
                continue
            try:
                wire = encoder.encode(result)
                _send((True, wire))
            except Exception as exc:
                try:
                    _send((False, ActorError(f"transport encode failed for {method}(): {exc!r}")))
                except OSError:
                    return
    except OSError:
        pass  # peer vanished mid-reply: the cell will report ActorDiedError
    finally:
        if encoder is not None:
            encoder.close()
        stop = getattr(target, "stop", None)
        if callable(stop):
            try:
                stop()
            except Exception:
                pass
        try:
            conn.close()
        except OSError:
            pass


def _host_main(port: int, ready: Any) -> None:
    """RemoteHost entry point: accept loop, one serving thread per cell.

    Run in its own (spawned) process: a fresh interpreter, so JAX-backed
    targets initialize cleanly regardless of the driver's thread state.
    Reports the bound ``(host, port)`` through ``ready`` — ``port=0`` lets
    the OS pick, which is what the localhost test matrix uses.
    """
    server = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    server.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    server.bind(("127.0.0.1", port))
    server.listen()
    ready.send(server.getsockname())
    ready.close()
    while True:
        try:
            conn, peer = server.accept()
        except OSError:
            return
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        threading.Thread(
            target=_serve_remote_connection,
            args=(conn, peer),
            daemon=True,
            name="remote-cell-serve",
        ).start()


class LocalHostHandle:
    """A RemoteHost process this driver launched (and may kill).

    ``kill()`` is the machine-loss injector's lever: terminating the process
    drops every fragment endpoint homed on it at once.
    """

    def __init__(self, proc: Any, address: Tuple[str, int]):
        self._proc = proc
        self.address = address

    @property
    def alive(self) -> bool:
        return self._proc is not None and self._proc.is_alive()

    @property
    def pid(self) -> Optional[int]:
        """OS pid of the host process (None once reaped)."""
        return getattr(self._proc, "pid", None)

    def kill(self) -> None:
        """Terminate the host process (abrupt: simulated machine loss)."""
        if self._proc is None:
            return
        if self._proc.is_alive():
            self._proc.terminate()
            self._proc.join(timeout=5.0)

    def stop(self) -> None:
        self.kill()

    def __repr__(self) -> str:  # pragma: no cover
        return f"LocalHostHandle({self.address!r}, alive={self.alive})"


def start_local_host(port: int = 0, start_method: str = "spawn") -> LocalHostHandle:
    """Launch a RemoteHost on localhost; returns once its port is bound.

    Spawn (not fork) so the host interpreter is clean — same reasoning as
    JAX workers on the process backend: the host will likely build jitted
    targets, and fork would inherit the driver's XLA threads.
    """
    import multiprocessing

    ctx = multiprocessing.get_context(start_method)
    parent, child = ctx.Pipe()
    proc = ctx.Process(
        target=_host_main, args=(port, child), daemon=True, name="remote-host"
    )
    proc.start()
    child.close()
    if not parent.poll(30.0):
        proc.terminate()
        raise ActorError("remote host failed to bind within 30s")
    address = tuple(parent.recv())
    parent.close()
    return LocalHostHandle(proc, address)  # type: ignore[arg-type]


# --------------------------------------------------------------------------
# Driver side: the cell + backend
# --------------------------------------------------------------------------
class RemoteCell(Cell):
    """Target lives on a RemoteHost; calls are framed socket RPCs.

    Like ``ProcessCell`` the factory is pickled eagerly (a cell that
    constructs at all can always be restarted) and each (re)connect bumps
    the name generation, so the host builds a fresh target whose transport
    prefix can never collide with a previous life's.
    """

    def __init__(
        self,
        factory: Optional[Callable[[], Any]] = None,
        target: Any = None,
        address: Tuple[str, int] = ("127.0.0.1", 0),
        transport: Any = None,
        connect_timeout: float = 10.0,
        heartbeat_interval: Optional[float] = 5.0,
    ):
        payload = factory if factory is not None else _ReturnTarget(target)
        self._payload = pickle.dumps(payload)
        self._transport = _resolve_remote_transport(transport)
        self._address = (str(address[0]), int(address[1]))
        self._connect_timeout = connect_timeout
        self._heartbeat_interval = heartbeat_interval
        self._prefix_base = f"rmt{os.getpid()}x{next(_cell_seq)}"
        self._generation = 0
        self._sock: Optional[socket.socket] = None
        self._frames: Optional[FrameDecoder] = None
        self._decoder: Any = None
        self._dead = False
        self._stopped = False
        self._lock = threading.Lock()  # serializes request/reply pairs
        self._last_rpc = time.monotonic()
        self._proxy = _Proxy(self)  # RemoteCell.rpc matches the _Proxy contract
        self._connect()
        if heartbeat_interval is not None:
            self._hb_stop = threading.Event()
            self._hb_thread = threading.Thread(
                target=self._heartbeat_loop, daemon=True, name="remote-cell-heartbeat"
            )
            self._hb_thread.start()
        else:
            self._hb_stop = None  # type: ignore[assignment]
            self._hb_thread = None  # type: ignore[assignment]

    # ----------------------------------------------------------- connection
    def _connect(self) -> None:
        self._generation += 1
        prefix = f"{self._prefix_base}g{self._generation}"
        try:
            sock = socket.create_connection(self._address, timeout=self._connect_timeout)
        except OSError as exc:
            self._dead = True
            raise ActorDiedError(
                f"remote host {self._address} unreachable: {exc}"
            ) from exc
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.settimeout(None)  # RPCs block like ProcessCell pipe recv
        self._sock = sock
        self._frames = FrameDecoder()
        self._decoder = self._transport.client_endpoint(prefix)
        hello = ("hello", prefix, prefix, self._payload, pickle.dumps(self._transport))
        try:
            sock.sendall(encode_frame(hello))
            sock.settimeout(self._connect_timeout)
            ok, info = self._recv_reply("__handshake__")
            sock.settimeout(None)
        except ActorDiedError:
            self._dead = True
            raise
        if not ok:
            self._dead = True
            self._close_socket()
            err = info if isinstance(info, BaseException) else ActorError(repr(info))
            raise ActorError(f"remote target construction failed: {err!r}") from (
                err if isinstance(err, BaseException) else None
            )
        self._dead = False

    def _close_socket(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
        self._sock = None

    def _recv_reply(self, method: str) -> Tuple[bool, Any]:
        # Local refs: kill()/stop() may null out self._sock from another
        # thread to unblock this recv (the close makes it raise OSError).
        sock, frames = self._sock, self._frames
        assert sock is not None and frames is not None
        while True:
            try:
                chunk = sock.recv(_RECV_CHUNK)
            except OSError as exc:
                raise self._death_error(method, exc) from None
            if not chunk:
                raise self._death_error(method, None) from None
            msgs = frames.feed(chunk)
            if msgs:
                # Strict request/reply: at most one reply can be in flight.
                return msgs[0]

    def _death_error(self, method: str, cause: Any) -> ActorDiedError:
        self._dead = True
        self._close_socket()
        detail = f": {cause}" if cause else ""
        return ActorDiedError(
            f"remote cell on {self._address} died during {method}() "
            f"(generation={self._generation}){detail}"
        )

    # ------------------------------------------------------------------ rpc
    def rpc(self, method: str, args: tuple, kwargs: dict) -> Any:
        with self._lock:
            sock = self._sock
            if self._dead or sock is None:
                raise ActorDiedError(
                    f"remote cell on {self._address} is dead "
                    f"(generation={self._generation}); cannot run {method}()"
                )
            frame = encode_frame((method, args, kwargs, self._decoder.drain_releases()))
            try:
                sock.sendall(frame)
            except OSError as exc:
                raise self._death_error(method, exc) from None
            ok, payload = self._recv_reply(method)
            self._last_rpc = time.monotonic()
        if ok:
            return self._decoder.decode(payload)
        raise payload

    # ------------------------------------------------------------ heartbeat
    def _heartbeat_loop(self) -> None:
        interval = self._heartbeat_interval
        assert interval is not None
        while not self._hb_stop.wait(interval):
            if self._dead or self._stopped:
                return
            if time.monotonic() - self._last_rpc < interval:
                continue  # real traffic is the best heartbeat
            try:
                self.rpc(PING_METHOD, (), {})
            except BaseException as exc:
                if not self._stopped:
                    _logger.warning(
                        "remote cell %s heartbeat failed: %r", self._address, exc
                    )
                return  # rpc() already marked the cell dead

    # ------------------------------------------------------------ lifecycle
    @property
    def target(self) -> Any:
        return self._proxy

    @property
    def alive(self) -> bool:
        return not self._dead and self._sock is not None

    def restart(self) -> None:
        """Reconnect with a bumped generation: the host builds a fresh
        target (the old connection's serving thread tears the old one
        down when its socket dies)."""
        with self._lock:
            self._close_socket()
            self._connect()
            self._last_rpc = time.monotonic()

    def stop(self) -> None:
        """Graceful: frame ``None`` so the host tears the target down, then
        close.  Never blocks on a wedged RPC — if the lock can't be had
        quickly, degrade to ``kill()`` (the close unblocks the RPC)."""
        self._stopped = True
        if self._hb_stop is not None:
            self._hb_stop.set()
        if self._lock.acquire(timeout=1.0):
            try:
                if self._sock is not None:
                    try:
                        self._sock.sendall(encode_frame(None))
                    except OSError:
                        pass
            finally:
                self._lock.release()
        self.kill()

    def kill(self) -> None:
        # Deliberately lock-free: closing the socket is what unblocks an
        # in-flight recv (it raises OSError into _recv_reply, which marks
        # the cell dead on that thread).
        self._stopped = True
        self._dead = True
        if self._hb_stop is not None:
            self._hb_stop.set()
        self._close_socket()
        if self._decoder is not None:
            self._decoder.close()


class RemoteBackend(ExecutionBackend):
    """Cells homed on one RemoteHost address (one backend per host)."""

    name = "remote"

    def __init__(
        self,
        address: Any = None,
        transport: Any = None,
        heartbeat_interval: Optional[float] = 5.0,
        connect_timeout: float = 10.0,
    ):
        if address is None:
            raise ValueError(
                'RemoteBackend needs a host address: RemoteBackend(("10.0.0.2", 7011)) '
                'or RemoteBackend("10.0.0.2:7011")'
            )
        if isinstance(address, str):
            host, _, port = address.rpartition(":")
            if not host or not port.isdigit():
                raise ValueError(f"address string must be 'host:port' (got {address!r})")
            address = (host, int(port))
        self.address: Tuple[str, int] = (str(address[0]), int(address[1]))
        self.transport = _resolve_remote_transport(transport)
        self.heartbeat_interval = heartbeat_interval
        self.connect_timeout = connect_timeout

    def make_cell(
        self, factory: Optional[Callable[[], Any]] = None, target: Any = None
    ) -> Cell:
        return RemoteCell(
            factory=factory,
            target=target,
            address=self.address,
            transport=self.transport,
            connect_timeout=self.connect_timeout,
            heartbeat_interval=self.heartbeat_interval,
        )


# Registered for discoverability/error messages; RemoteBackend requires an
# address, so string resolution ("remote") fails loudly with the hint above
# instead of silently building a cell with nowhere to connect.
BACKENDS.setdefault("remote", RemoteBackend)
