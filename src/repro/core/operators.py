"""The RL-specific dataflow operator library (paper §4–5).

Creation operators return iterators; transformation operators are callable
classes applied with ``for_each``.  Together with the sequencing/concurrency
primitives in ``iterators.py`` / ``concurrency.py`` these are sufficient to
express every algorithm plan in ``plans.py`` — the paper's Table 2 suite.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.actor import ActorPool, VirtualActor
from repro.core.iterators import (
    LocalIterator,
    NextValueNotReady,
    ParallelIterator,
)
from repro.core.metrics import (
    APPLY_GRADS_TIMER,
    LEARN_ON_BATCH_TIMER,
    STEPS_SAMPLED_COUNTER,
    STEPS_TRAINED_COUNTER,
    TARGET_NET_UPDATES,
    get_metrics,
)
from repro.core.workers import WorkerSet
from repro.rl.sample_batch import MultiAgentBatch, SampleBatch

__all__ = [
    "ParallelRollouts",
    "configure_vectorized_rollouts",
    "ComputeGradients",
    "ApplyGradients",
    "AverageGradients",
    "TrainOneStep",
    "ConcatBatches",
    "SelectExperiences",
    "StandardizeFields",
    "StoreToReplayBuffer",
    "Replay",
    "UpdateReplayPriorities",
    "UpdateTargetNetwork",
    "UpdateWorkerWeights",
    "ReportMetrics",
    "StandardMetricsReporting",
]


# --------------------------------------------------------------------------
# Creation
# --------------------------------------------------------------------------
def configure_vectorized_rollouts(
    workers: WorkerSet,
    vector: Optional[int] = None,
    inference: Optional[str] = None,
    inference_clients: Optional[Sequence[Any]] = None,
    decode: Optional[str] = None,
) -> List[Dict[str, Any]]:
    """Broadcast vectorization config onto the rollout workers.

    The graph carries ``vector=``/``inference=``/``decode=`` declaratively
    (FlowSpec annotations on the rollouts node); this is the lowering step —
    workers exposing ``configure_vectorization`` (``VectorizedRolloutWorker``)
    rebuild their ``VectorEnv`` to ``vector`` lanes and adopt the inference
    mode; anything else (plain ``RolloutWorker``, stubs) is skipped with a
    one-time warning, mirroring the learner-annotation fallback.

    ``inference_clients``: one ``InferenceClient`` per shard (round-robin if
    fewer).  Clients hold live actor handles and do not pickle, so for
    process-backed workers the client is withheld and the worker keeps
    local inference — vectorization still applies.

    ``decode='cache'`` routes local acting through the stateful-policy
    protocol (per-lane KV cache through the rollout scan); workers whose
    policy lacks the protocol fall back to ``'forward'`` in their ack.
    """
    if vector is None and inference is None and decode is None:
        return []
    import logging

    clients = list(inference_clients or [])
    acks: List[Dict[str, Any]] = []
    skipped: List[str] = []
    fell_back: List[str] = []
    for idx, actor in enumerate(workers.remote_workers()):
        client = clients[idx % len(clients)] if clients else None
        if client is not None and actor.backend_name != "thread":
            # Actor handles don't cross the process RPC boundary.
            client = None
            fell_back.append(actor.name)
        kwargs: Dict[str, Any] = dict(
            vector=vector,
            inference=inference if client is not None or inference != "server" else "local",
            client=client,
        )
        if decode is not None:
            # Only sent when requested: legacy configure_vectorization
            # signatures (pre-decode fakes/workers) stay callable.
            kwargs["decode"] = decode
        try:
            acks.append(actor.sync("configure_vectorization", **kwargs))
        except AttributeError:
            skipped.append(actor.name)
    log = logging.getLogger(__name__)
    if skipped:
        log.warning(
            "vector=%s/inference=%s/decode=%s requested but workers %s do not "
            "support configure_vectorization (expected VectorizedRolloutWorker); "
            "they keep their existing rollout path", vector, inference, decode, skipped,
        )
    if fell_back:
        log.warning(
            "inference='server' needs thread-backend rollout workers (actor "
            "handles do not pickle); workers %s fall back to local inference",
            fell_back,
        )
    return acks


def ParallelRollouts(
    workers: WorkerSet,
    mode: str = "bulk_sync",
    num_async: int = 1,
    credits: Optional[int] = None,
    metrics_key: Optional[str] = None,
    vector: Optional[int] = None,
    inference: Optional[str] = None,
    inference_clients: Optional[Sequence[Any]] = None,
    decode: Optional[str] = None,
) -> Any:
    """Stream of experience batches from the rollout workers (paper Fig 5).

    mode='raw'       -> ParIter[SampleBatch]   (caller sequences it)
    mode='bulk_sync' -> Iter[SampleBatch]      (synchronously concatenated
                        across workers per round — PPO/A2C style)
    mode='async'     -> Iter[SampleBatch]      (completion order — Ape-X/
                        IMPALA style, pipeline depth ``num_async``; the
                        total in-flight window is capped at ``credits``
                        when given — credit-based backpressure)

    ``vector=``/``inference=`` configure the vectorized rollout engine on
    the workers before the stream starts (see
    ``configure_vectorized_rollouts``): ``vector=N`` resizes each worker's
    ``VectorEnv`` to N lanes; ``inference='server'`` routes acting through
    the given ``inference_clients`` (decoupled batched inference);
    ``decode='cache'`` carries per-lane model state (KV cache) through the
    rollout scan via the stateful-policy protocol.
    """
    if credits is not None and mode != "async":
        raise ValueError(
            f"credits= is an async-gather window; rollout mode {mode!r} has no "
            "in-flight pipeline to bound (use mode='async')"
        )
    configure_vectorized_rollouts(workers, vector, inference, inference_clients, decode)
    par = ParallelIterator.from_actors(
        workers.remote_workers(), lambda w: w.sample(), name="ParallelRollouts"
    )

    def _count(batch: SampleBatch) -> SampleBatch:
        get_metrics().counters[STEPS_SAMPLED_COUNTER] += batch.count
        return batch

    if mode == "raw":
        return par
    if mode == "bulk_sync":
        def _concat(batches: List[SampleBatch]) -> SampleBatch:
            if batches and isinstance(batches[0], MultiAgentBatch):
                out: Any = MultiAgentBatch.concat_samples(batches)
            else:
                out = SampleBatch.concat_samples(batches)
            get_metrics().counters[STEPS_SAMPLED_COUNTER] += out.count
            return out

        return par.batch_across_shards(metrics_key=metrics_key).for_each(_concat)
    if mode == "async":
        return par.gather_async(
            num_async=num_async, credits=credits, metrics_key=metrics_key
        ).for_each(_count)
    raise ValueError(f"unknown rollout mode {mode!r}")


def Replay(
    actors: ActorPool,
    num_async: int = 4,
    credits: Optional[int] = None,
    metrics_key: Optional[str] = None,
) -> LocalIterator[SampleBatch]:
    """Stream of replayed batches from replay-buffer actors (Ape-X §5.2).

    Pulls with ``num_async``-deep pipelining; buffers that are not yet warm
    return None, which is skipped (NextValueNotReady semantics).  ``credits``
    caps the total in-flight window across replay actors (backpressure
    against a consumer that falls behind, e.g. a saturated learner feed).
    """
    par = ParallelIterator.from_actors(actors, lambda r: r.replay(), name="Replay")

    def _skip_cold(item: Any) -> Any:
        return NextValueNotReady() if item is None else item

    return par.gather_async(
        num_async=num_async, credits=credits, metrics_key=metrics_key
    ).for_each(_skip_cold)


# --------------------------------------------------------------------------
# Gradient-based transformations
# --------------------------------------------------------------------------
class ComputeGradients:
    """batch -> (grads, info); runs ON the source rollout actor, reading its
    local policy snapshot (paper §4, Transformation)."""

    def __call__(self, batch: SampleBatch) -> Tuple[Any, Dict[str, Any]]:
        # Inside a parallel for_each this executes on the actor thread; the
        # actor's target is reachable through the batch producer closure, so
        # RLlib Flow instead passes the *worker itself* via ParallelIterator
        # scheduling. We mirror that: plans use `par_compute_gradients`.
        raise RuntimeError(
            "ComputeGradients must be applied with par_compute_gradients() "
            "on a raw ParallelRollouts iterator"
        )


def par_compute_gradients(
    workers: WorkerSet,
    vector: Optional[int] = None,
    inference: Optional[str] = None,
    inference_clients: Optional[Sequence[Any]] = None,
    decode: Optional[str] = None,
) -> ParallelIterator:
    """ParIter[(grads, info)] — sample + grad computed on each worker.

    ``vector=``/``inference=``/``decode=`` configure the vectorized rollout
    engine on the workers first (A2C/A3C share the knob with
    ``ParallelRollouts``)."""
    configure_vectorized_rollouts(workers, vector, inference, inference_clients, decode)

    def _sample_and_grad(w: Any) -> Tuple[Any, Dict[str, Any]]:
        batch = w.sample()
        grads, info = w.compute_gradients(batch)
        info = dict(info)
        info["batch_count"] = batch.count
        return grads, info

    return ParallelIterator.from_actors(
        workers.remote_workers(), _sample_and_grad, name="ComputeGradients"
    )


class ApplyGradients:
    """Apply (grads, info) on the local worker; push weights to the source
    actor (A3C) or all actors (synchronous algorithms).  Paper Table 1:
    ApplyGradients (Fig 9a's central apply step)."""

    share_across_shards = True
    flow_pure = True  # never emits NextValueNotReady (see repro.flow.spec.pure)

    def __init__(self, workers: WorkerSet, update_all: bool = False):
        self.workers = workers
        self.update_all = update_all

    def __call__(self, item: Tuple[Any, Dict[str, Any]]) -> Dict[str, Any]:
        grads, info = item
        metrics = get_metrics()
        with metrics.timers[APPLY_GRADS_TIMER]:
            self.workers.local_worker().apply_gradients(grads)
        metrics.counters[STEPS_TRAINED_COUNTER] += info.get("batch_count", 0)
        metrics.counters[STEPS_SAMPLED_COUNTER] += info.get("batch_count", 0)
        if self.update_all:
            self.workers.sync_weights()
        else:
            # Fine-grained message passing: update only the producing actor.
            actor = metrics.current_actor
            if actor is not None:
                weights = self.workers.local_worker().get_weights()
                actor.call("set_weights", weights)
        return info


class AverageGradients:
    """List[(grads, info)] -> (averaged grads, merged info).  Paper Table 1:
    AverageGradients (the barrier-reduce of synchronous A2C)."""

    flow_pure = True

    def __call__(self, items: Sequence[Tuple[Any, Dict[str, Any]]]) -> Tuple[Any, Dict]:
        import jax

        grads = [g for g, _ in items if g is not None]
        info = dict(items[0][1]) if items else {}
        info["batch_count"] = sum(i.get("batch_count", 0) for _, i in items)
        avg = jax.tree_util.tree_map(lambda *gs: sum(gs) / len(gs), *grads)
        return avg, info


class TrainOneStep:
    """Take a (possibly multi-agent) batch, run one learner update on the
    local worker, then broadcast new weights (paper Fig 10b/11b:
    TrainOneStep).

    ``num_learners``/``microbatch`` lower the update onto a data-parallel
    SPMD learner group (``repro.rl.learner_group.ShardedLearnerGroup``):
    batch columns are sharded across a device mesh at the transport
    boundary and gradients accumulate over ``microbatch`` slices.  Flow
    graphs set these declaratively — ``stream.learners(4).microbatch(2)``
    on the TrainOneStep node — and ``compile()`` lowers the annotations
    onto this operator.  The sharded path needs the local worker's pure
    loss (``_loss_for``); multi-agent or per-policy routing falls back to
    the plain ``learn_on_batch`` with a one-time warning.
    """

    share_across_shards = True
    flow_pure = True

    def __init__(
        self,
        workers: WorkerSet,
        policies: Optional[Sequence[str]] = None,
        num_sgd_iter: int = 1,
        sgd_minibatch_size: int = 0,
        num_learners: int = 0,
        microbatch: int = 0,
    ):
        self.workers = workers
        self.policies = list(policies) if policies else None
        self.num_sgd_iter = num_sgd_iter
        self.sgd_minibatch_size = sgd_minibatch_size
        self.num_learners = num_learners
        self.microbatch = microbatch
        self._group: Any = None
        self._warned_fallback = False
        self._rng = np.random.default_rng(0)

    def _sharded(self) -> bool:
        return self.num_learners > 1 or self.microbatch > 1

    def _learner_group(self, lw: Any) -> Any:
        if self._group is None or self._group.worker is not lw:
            from repro.rl.learner_group import ShardedLearnerGroup

            self._group = ShardedLearnerGroup(
                lw, num_learners=self.num_learners, microbatch=self.microbatch
            )
        return self._group

    def __call__(self, batch: Any) -> Any:
        metrics = get_metrics()
        lw = self.workers.local_worker()
        with metrics.timers[LEARN_ON_BATCH_TIMER]:
            if self.num_sgd_iter > 1 or self.sgd_minibatch_size:
                infos = []
                mbs = self.sgd_minibatch_size or batch.count
                for _ in range(self.num_sgd_iter):
                    for mb in batch.minibatches(mbs, self._rng):
                        infos.append(self._learn(lw, mb))
                info = infos[-1] if infos else {}
            else:
                info = self._learn(lw, batch)
        metrics.counters[STEPS_TRAINED_COUNTER] += batch.count
        self.workers.sync_weights()
        return batch, info

    def reset_warnings(self) -> None:
        """Re-arm the warn-once fallback latch.

        Called by ``CompiledFlow._instantiate`` once per compile: operator
        instances that survive a deepcopy carry the old latch into the new
        flow, and instances that *can't* be deep-copied (this one holds a
        live WorkerSet) are shared across every compile of the spec — either
        way, without the reset a fallback in one Algorithm would silently
        suppress the warning in every later Algorithm built from the same
        operators (and across test runs in one process).
        """
        self._warned_fallback = False

    def _warn_fallback(self, lw: Any, why: str) -> None:
        if self._warned_fallback:
            return
        self._warned_fallback = True
        import logging

        logging.getLogger(__name__).warning(
            "TrainOneStep(num_learners=%d, microbatch=%d): %s (worker %s); "
            "falling back to the plain single-device learn_on_batch",
            self.num_learners, self.microbatch, why, type(lw).__name__,
        )

    def _learn(self, lw: Any, batch: Any) -> Dict[str, Any]:
        if isinstance(batch, MultiAgentBatch):
            if self._sharded():
                self._warn_fallback(lw, "multi-agent batches route per policy")
            out = {}
            for pid, b in batch.policy_batches.items():
                if self.policies is None or pid in self.policies:
                    out[pid] = lw.learn_on_batch(b, policy_id=pid)
            return out
        if self.policies:
            if self._sharded():
                self._warn_fallback(lw, "per-policy routing is not sharded")
            return lw.learn_on_batch(batch, policy_id=self.policies[0])
        if self._sharded():
            if hasattr(lw, "_loss_for"):
                return self._learner_group(lw).learn_on_batch(batch)
            self._warn_fallback(lw, "worker has no pure loss (_loss_for)")
        return lw.learn_on_batch(batch)


# --------------------------------------------------------------------------
# Batch shaping
# --------------------------------------------------------------------------
class ConcatBatches:
    """Buffer incoming batches until ``min_batch_size`` steps accumulated.
    Paper Table 1: ConcatBatches (PPO's train-batch assembly, Fig 10)."""

    def __init__(self, min_batch_size: int):
        self.min_batch_size = min_batch_size
        self._buf: List[SampleBatch] = []
        self._count = 0

    def __call__(self, batch: Any) -> Any:
        self._buf.append(batch)
        self._count += batch.count
        if self._count >= self.min_batch_size:
            cls = MultiAgentBatch if isinstance(self._buf[0], MultiAgentBatch) else SampleBatch
            out = cls.concat_samples(self._buf)
            self._buf, self._count = [], 0
            return out
        return NextValueNotReady()


class SelectExperiences:
    """Keep only the given policies' experiences (multi-agent, paper §5.3)."""

    flow_pure = True

    def __init__(self, policy_ids: Sequence[str]):
        self.policy_ids = list(policy_ids)

    def __call__(self, batch: Any) -> Any:
        if isinstance(batch, MultiAgentBatch):
            return batch.select(self.policy_ids)
        return batch


class StandardizeFields:
    """Z-score the given columns.  Paper Table 1: StandardizeFields (PPO's
    advantage normalization stage)."""

    flow_pure = True

    def __init__(self, fields: Sequence[str]):
        self.fields = list(fields)

    def __call__(self, batch: Any) -> Any:
        if isinstance(batch, MultiAgentBatch):
            for b in batch.policy_batches.values():
                self._standardize(b)
            return batch
        self._standardize(batch)
        return batch

    def _standardize(self, batch: SampleBatch) -> None:
        for f in self.fields:
            if f in batch:
                col = batch[f]
                batch[f] = (col - col.mean()) / max(1e-4, col.std())


# --------------------------------------------------------------------------
# Replay interaction
# --------------------------------------------------------------------------
class StoreToReplayBuffer:
    """Send each batch to a random replay actor.  Paper Table 1:
    StoreToReplayBuffer (the Ape-X/DQN store sub-flow, §5.2)."""

    share_across_shards = True
    flow_pure = True

    def __init__(self, actors: ActorPool, seed: int = 0):
        self.actors = actors
        self._rng = np.random.default_rng(seed)

    def __call__(self, batch: SampleBatch) -> SampleBatch:
        actor = self.actors[int(self._rng.integers(len(self.actors)))]
        actor.call("add_batch", batch)
        return batch


class UpdateReplayPriorities:
    """Push new TD-error priorities back to the producing replay actor.
    Paper §5.2: Ape-X's UpdatePriorities message-passing operator.

    Consumes ((batch, info), replay_actor) tuples produced by
    ``Replay(...).zip_with_source_actor()`` + TrainOneStep.
    """

    share_across_shards = True
    flow_pure = True

    def __call__(self, item: Tuple[Tuple[Any, Dict], VirtualActor]) -> Any:
        (batch, info), actor = item
        td = info.get("td_error") if isinstance(info, dict) else None
        if td is not None and actor is not None and "batch_indices" in batch:
            actor.call("update_priorities", batch["batch_indices"], np.abs(td))
        return batch, info


# --------------------------------------------------------------------------
# Actor message-passing operators
# --------------------------------------------------------------------------
class UpdateTargetNetwork:
    """Periodically sync the target network (DQN family).  Paper Table 1:
    UpdateTargetNetwork (actor message-passing operator, §4)."""

    share_across_shards = True
    flow_pure = True

    def __init__(self, workers: WorkerSet, target_update_freq: int):
        self.workers = workers
        self.target_update_freq = target_update_freq
        self._last = 0

    def __call__(self, item: Any) -> Any:
        metrics = get_metrics()
        trained = metrics.counters[STEPS_TRAINED_COUNTER]
        if trained - self._last >= self.target_update_freq:
            self._last = trained
            self.workers.local_worker().update_target()
            metrics.counters[TARGET_NET_UPDATES] += 1
        return item


class UpdateWorkerWeights:
    """Fine-grained weight push to the actor that produced the item
    (Ape-X: max_weight_sync_delay staleness control)."""

    share_across_shards = True
    flow_pure = True

    def __init__(self, workers: WorkerSet, max_weight_sync_delay: int = 400):
        self.workers = workers
        self.max_weight_sync_delay = max_weight_sync_delay
        self._steps_since: Dict[int, int] = {}

    def __call__(self, item: Tuple[Any, VirtualActor]) -> Any:
        batch, actor = item
        if actor is None:
            return batch
        n = self._steps_since.get(actor.actor_id, 0) + getattr(batch, "count", 0)
        if n >= self.max_weight_sync_delay:
            weights = self.workers.local_worker().get_weights()
            actor.call("set_weights", weights)
            n = 0
        self._steps_since[actor.actor_id] = n
        return batch


# --------------------------------------------------------------------------
# Metrics
# --------------------------------------------------------------------------
class ReportMetrics:
    """item -> training-result dict, merging the shared metrics context.
    The per-item half of the paper's StandardMetricsReporting (Listing A2)."""

    share_across_shards = True
    flow_pure = True

    def __init__(self, workers: Optional[WorkerSet] = None):
        self.workers = workers
        self._t0 = time.perf_counter()
        # None = unknown, probed on first report; False = targets lack
        # episode_stats(), stop dispatching (and spamming logs) every tick.
        self._remote_has_stats: Optional[bool] = None

    def __call__(self, item: Any) -> Dict[str, Any]:
        metrics = get_metrics()
        info = item[1] if isinstance(item, tuple) and len(item) == 2 else item
        result = dict(metrics.save())
        # Per-item learner info wins over the context's info blob.
        result["info"] = info
        result["time_total_s"] = time.perf_counter() - self._t0
        if self.workers is not None:
            stats = []
            lw = self.workers.local_worker()
            if hasattr(lw, "episode_stats"):
                stats.append(lw.episode_stats())
            # Per-worker stats: dispatch to all live workers in parallel
            # (batched wait, not N serial round-trips), then absorb per-
            # worker failures — a dropped shard must not poison reporting.
            # apply() (not call()) so a missing episode_stats() doesn't hit
            # the fire-and-forget ERROR logger; after one AttributeError the
            # capability is cached and dispatch stops entirely.
            futures = []
            if self._remote_has_stats is not False:
                for actor in self.workers.remote_workers():
                    if not getattr(actor, "alive", True):
                        continue
                    try:
                        futures.append(actor.apply(lambda t: t.episode_stats()))
                    except RuntimeError:
                        continue
            for f in futures:
                try:
                    stats.append(f.result())
                except AttributeError:
                    self._remote_has_stats = False
                    break  # targets predate episode_stats(): skip the rest
                except Exception:
                    continue
            else:
                if futures:
                    self._remote_has_stats = True
            rewards = [
                s["episode_reward_mean"]
                for s in stats
                if s.get("episodes", 0) > 0 and s["episode_reward_mean"] == s["episode_reward_mean"]
            ]
            result["episodes"] = {
                "episode_reward_mean": float(np.mean(rewards)) if rewards else float("nan"),
                "episodes": int(sum(s.get("episodes", 0) for s in stats)),
            }
        return result


def StandardMetricsReporting(
    train_op: LocalIterator,
    workers: WorkerSet,
    report_interval: int = 1,
) -> LocalIterator[Dict[str, Any]]:
    """Wrap a train op into the standard result stream (every Nth item).
    Paper Table 1 / Listing A2: StandardMetricsReporting."""
    it = train_op
    if report_interval > 1:
        counter = {"n": 0}

        def _every(item: Any) -> Any:
            counter["n"] += 1
            if counter["n"] % report_interval == 0:
                return item
            return NextValueNotReady()

        it = it.for_each(_every)
    return it.for_each(ReportMetrics(workers))
