"""Distributed iterators: the RLlib Flow programming model core.

Two iterator kinds (paper §4):

  * ``ParallelIterator[T]`` — a lazy parallel stream of items sharded across a
    pool of (virtual) actors.  Transformations added with ``for_each`` are
    *scheduled onto the source actor* so they can read actor-local state
    (policy weights, env state).  Consuming a parallel iterator requires a
    sequencing operator: ``gather_sync`` (deterministic, barrier semantics) or
    ``gather_async`` (items surface as soon as ready; ``num_async`` controls
    pipeline depth).

  * ``LocalIterator[T]`` — a lazy sequential stream.  Supports ``for_each``,
    ``filter``, ``batch``, ``combine``, ``zip_with_source_actor``, ``union``
    (round-robin or async, with rate-limiting weights) and ``duplicate``.

Iterators are lazy: building a dataflow does nothing; pulling items from the
output iterator drives the whole graph (Volcano-style).

Fault tolerance (executor runtime): the gather operators honor each source
actor's ``FailurePolicy`` — a failing worker either restarts (item skipped,
shard kept), gets its shard dropped (the stream continues with survivors),
or propagates the error (default).  Failures and dropped shards are counted
into the shared metrics context.  Pool-backed parallel iterators are also
*elastic*: actors added to / removed from the source ``ActorPool`` mid-stream
are picked up by the gather loops (``Algorithm.add_workers()``).

Backpressure (data plane): ``gather_async`` is credit-bounded — the total
dispatched-but-unconsumed window is capped (``credits``; default
``num_async * shards``), starved shards are backfilled FIFO as the consumer
frees credits, and stalls/bytes/occupancy are recorded into the shared
metrics context (``core.metrics``; see ``core.transport`` for the
inter-process data plane itself).
"""

from __future__ import annotations

import copy
import logging
import queue
import threading
import time
import types
from typing import (
    Any,
    Callable,
    Dict,
    Generic,
    Iterable,
    Iterator,
    List,
    Optional,
    Sequence,
    TypeVar,
    Union,
)

from repro.core.actor import ActorPool, VirtualActor
from repro.core.executor import FailurePolicy
from repro.core.metrics import (
    BYTES_MOVED_PREFIX,
    CREDIT_STALL_TIME,
    GATHER_TIMER_PREFIX,
    INFLIGHT_PREFIX,
    NUM_BYTES_MOVED,
    NUM_CREDIT_STALLS,
    NUM_SHARDS_DROPPED,
    NUM_WORKER_FAILURES,
    MetricsContext,
    get_metrics,
    payload_nbytes,
    set_metrics_for_thread,
)

T = TypeVar("T")
U = TypeVar("U")

logger = logging.getLogger(__name__)

__all__ = [
    "LocalIterator",
    "ParallelIterator",
    "NextValueNotReady",
    "from_actors",
    "from_items",
    "from_iterators",
]


class NextValueNotReady:
    """Sentinel yielded by non-blocking fragments when no item is ready yet.

    Round-robin unions propagate it so one starved branch cannot stall the
    others (paper: asynchronous dependencies / pink arrows).
    """

    def __repr__(self) -> str:  # pragma: no cover
        return "<NextValueNotReady>"


_NOT_READY = NextValueNotReady()


def _apply_stages(item: Any, stages: Sequence[Callable]) -> Any:
    for fn in stages:
        if isinstance(item, NextValueNotReady):
            return item
        item = fn(item)
    return item


class _Exhausted:
    """Internal marker: a shard's underlying stream raised StopIteration.

    PEP 479: raising StopIteration inside a generator is a RuntimeError, so
    the gather generators map finite shards' exhaustion to this marker."""


_EXHAUSTED = _Exhausted()


class _ShardVerdict:
    """Internal marker: how a shard failure was absorbed (policy != raise)."""

    def __init__(self, name: str):
        self.name = name

    def __repr__(self) -> str:  # pragma: no cover
        return f"<{self.name}>"


_SKIPPED = _ShardVerdict("item-skipped")  # restart policy: shard stays
_DROPPED = _ShardVerdict("shard-dropped")  # shard leaves the active set


def _absorb_shard_failure(actor: Any, exc: Exception, dropped: Dict[int, str], stream: str) -> Any:
    """Apply the source actor's FailurePolicy to a failed shard dispatch.

    Returns ``_SKIPPED`` (keep shard, lose item) or ``_DROPPED`` (shard
    leaves the set), or re-raises under the default RAISE policy.  Counts
    failures/drops into the driving thread's metrics context.

    ``dropped`` maps actor_id -> drop reason: ``"dead"`` drops are pruned by
    the gather loops when the actor comes back alive (``recover()``'s
    in-place restart), ``"policy"`` drops are permanent for this stream.
    """
    policy = getattr(actor, "failure_policy", FailurePolicy.RAISE)
    metrics = get_metrics()
    metrics.counters[NUM_WORKER_FAILURES] += 1
    if policy == FailurePolicy.RAISE:
        raise exc
    alive = getattr(actor, "alive", True)
    # RESTART is only meaningful when the supervisor can actually heal the
    # worker: it needs a restart budget, and AttributeError is exempt from
    # supervision (protocol probes, see actor._run_loop) so a persistent one
    # can never be fixed by restarting.  Either way, skipping would
    # re-dispatch the same failing call forever (livelock) — degrade to
    # dropping the shard.
    restartable = (
        getattr(getattr(actor, "supervision", None), "max_restarts", 0) > 0
        and not isinstance(exc, AttributeError)
    )
    if policy == FailurePolicy.DROP_SHARD or not alive or not restartable:
        dropped[actor.actor_id] = "dead" if not alive else "policy"
        metrics.counters[NUM_SHARDS_DROPPED] += 1
        # repr(exc) eagerly: a live exception in a LogRecord pins its
        # traceback frames — and any in-flight shm attachments they
        # reference — for as long as a buffering handler (pytest's capture,
        # a QueueHandler) retains the record.
        logger.warning(
            "%s: dropping shard %s after failure (%s); %s",
            stream, getattr(actor, "name", actor), repr(exc),
            "actor dead" if not alive
            else ("drop_shard policy" if policy == FailurePolicy.DROP_SHARD
                  else "restart policy without restart budget"),
        )
        return _DROPPED
    # RESTART policy with a live (supervisor-restarted) actor: the failed
    # item is lost, the shard stays in the set.
    logger.warning(
        "%s: worker %s failed (%s); restart policy, item skipped",
        stream, getattr(actor, "name", actor), repr(exc),
    )
    return _SKIPPED


def _rejoin_revived(dropped: Dict[int, str], shards: Sequence["_Shard"]) -> List["_Shard"]:
    """Prune ``"dead"`` drops whose actor is alive again (healed by
    ``recover()``'s in-place restart) so they rejoin the stream; returns the
    shards revived this round."""
    revived = []
    for s in shards:
        aid = s.actor.actor_id
        if dropped.get(aid) == "dead" and getattr(s.actor, "alive", True):
            del dropped[aid]
            revived.append(s)
    return revived


# --------------------------------------------------------------------------
# LocalIterator
# --------------------------------------------------------------------------
class LocalIterator(Generic[T]):
    """A lazy sequential stream of items with a shared metrics context."""

    def __init__(
        self,
        base_builder: Callable[[], Iterator[T]],
        metrics: Optional[MetricsContext] = None,
        stages: Optional[List[Callable]] = None,
        name: str = "LocalIterator",
        parents: Optional[List["LocalIterator"]] = None,
    ):
        self._base_builder = base_builder
        self._stages: List[Callable] = list(stages or [])
        self.metrics = metrics or MetricsContext()
        self.name = name
        self._built: Optional[Iterator[T]] = None
        # Upstream iterators captured by wrapper generators (flatten,
        # duplicate, union children): close() propagates teardown to them.
        self._parents: List["LocalIterator"] = list(parents or [])

    # ------------------------------------------------------------- plumbing
    def _build(self) -> Iterator[T]:
        if self._built is None:
            self._built = self._base_builder()
        return self._built

    def close(self) -> None:
        """Tear down the driven stream: close the built generator so its
        ``finally`` blocks run now (joining union driver threads, closing
        child branches) instead of at GC time, then close parents."""
        gen = self._built
        if gen is not None and hasattr(gen, "close"):
            try:
                gen.close()
            except RuntimeError:
                # Generator currently executing on another thread; its own
                # teardown path (done-flag) will unwind it.
                pass
        for p in self._parents:
            p.close()

    def __iter__(self) -> Iterator[T]:
        it = self._build()
        while True:
            # Install this dataflow's context before pulling: base generators
            # (gather ops) report current_actor through the thread-local.
            set_metrics_for_thread(self.metrics)
            try:
                item = next(it)
            except StopIteration:
                return
            item = _apply_stages(item, self._stages)
            if isinstance(item, NextValueNotReady):
                continue
            yield item

    def __next__(self) -> T:
        # Pull until a concrete item emerges (skipping not-ready sentinels).
        it = self._build()
        while True:
            set_metrics_for_thread(self.metrics)
            item = next(it)
            item = _apply_stages(item, self._stages)
            if not isinstance(item, NextValueNotReady):
                return item

    def next(self) -> T:
        return self.__next__()

    def _iter_with_sentinels(self) -> Iterator[Any]:
        """Like ``__iter__`` but yields NextValueNotReady through, so unions
        can move on to other branches instead of blocking on a starved one."""
        it = self._build()
        while True:
            set_metrics_for_thread(self.metrics)
            try:
                item = next(it)
            except StopIteration:
                return
            yield _apply_stages(item, self._stages)

    def _chain(self, fn: Callable, name: str) -> "LocalIterator":
        return LocalIterator(
            self._base_builder,
            metrics=self.metrics,
            stages=self._stages + [fn],
            name=f"{self.name}.{name}",
            parents=self._parents,
        )

    # ------------------------------------------------------------ operators
    def for_each(self, fn: Callable[[T], U]) -> "LocalIterator[U]":
        """Transformation operator (paper Fig 6). ``fn`` may be stateful."""
        return self._chain(fn, f"for_each({getattr(fn, '__name__', type(fn).__name__)})")

    def filter(self, predicate: Callable[[T], bool]) -> "LocalIterator[T]":
        def _filter(item: Any) -> Any:
            return item if predicate(item) else _NOT_READY

        return self._chain(_filter, "filter")

    def batch(self, n: int) -> "LocalIterator[List[T]]":
        buf: List[Any] = []

        def _batch(item: Any) -> Any:
            buf.append(item)
            if len(buf) >= n:
                out, buf[:] = list(buf), []
                return out
            return _NOT_READY

        return self._chain(_batch, f"batch({n})")

    def flatten(self) -> "LocalIterator[Any]":
        parent = self

        def _gen() -> Iterator[Any]:
            for item in parent:
                for sub in item:
                    yield sub

        return LocalIterator(
            _gen, metrics=self.metrics, name=f"{self.name}.flatten", parents=[parent]
        )

    def combine(self, fn: Callable[[T], Iterable[U]]) -> "LocalIterator[U]":
        """for_each returning a list, flattened (RLlib's ``combine``)."""
        return self.for_each(fn).flatten()

    def take(self, n: int) -> List[T]:
        out: List[T] = []
        it = iter(self)
        for _ in range(n):
            try:
                out.append(next(it))
            except StopIteration:
                break
        return out

    def zip_with_source_actor(self) -> "LocalIterator[tuple]":
        """Pair each item with the actor that produced it (paper §5.2)."""

        def _zip(item: Any) -> Any:
            return (item, get_metrics().current_actor)

        return self._chain(_zip, "zip_with_source_actor")

    # -------------------------------------------------------------- unions
    def union(
        self,
        *others: "LocalIterator",
        deterministic: bool = False,
        round_robin_weights: Optional[Sequence[Union[int, str]]] = None,
    ) -> "LocalIterator":
        """Concurrency operator (paper Fig 8): merge concurrent fragments.

        deterministic=True  -> round-robin (optionally weighted; weight ``k``
            pulls k items per turn, ``'*'`` drains what is ready).  This is
            the rate-limiting mechanism [Acme] for e.g. replay:sample ratios.
        deterministic=False -> async merge: each child is driven by its own
            thread; items surface in completion order (pink arrows).  The
            driver threads are joined when the merged stream is closed or
            exhausted — they do not leak across dataflows.
        """
        children = [self, *others]
        # Children share one metrics context so counters/current_actor flow.
        merged_metrics = self.metrics
        for c in others:
            for k, v in c.metrics.counters.items():
                merged_metrics.counters[k] += v
            c.metrics = merged_metrics

        if deterministic:
            weights = list(round_robin_weights or [1] * len(children))
            if len(weights) != len(children):
                raise ValueError("round_robin_weights must match #children")

            def _rr_gen() -> Iterator[Any]:
                # Sentinel-aware pulls: a branch that reports "not ready"
                # (e.g. a cold replay buffer) yields its turn instead of
                # blocking the whole union (paper: rate-limited concurrency).
                try:
                    iters = [c._iter_with_sentinels() for c in children]
                    alive = [True] * len(iters)
                    while any(alive):
                        for i, it in enumerate(iters):
                            if not alive[i]:
                                continue
                            pulls = weights[i]
                            n = 1 if pulls == "*" else int(pulls)
                            for _ in range(n):
                                try:
                                    item = next(it)
                                except StopIteration:
                                    alive[i] = False
                                    break
                                yield item  # may be a sentinel; consumer skips
                finally:
                    for c in children:
                        c.close()

            return LocalIterator(
                _rr_gen, metrics=merged_metrics, name="union_rr", parents=children
            )

        def _async_gen() -> Iterator[Any]:
            q: "queue.Queue[Any]" = queue.Queue(maxsize=max(8, 2 * len(children)))
            done = threading.Event()
            n_alive = [len(children)]
            lock = threading.Lock()

            def _put(item: Any) -> bool:
                # Bounded-blocking put that aborts on teardown, so a driver
                # blocked against a full queue can always exit and be joined.
                while not done.is_set():
                    try:
                        q.put(item, timeout=0.05)
                        return True
                    except queue.Full:
                        pass
                return False

            def _drive(child: LocalIterator) -> None:
                try:
                    set_metrics_for_thread(merged_metrics)
                    for item in child:
                        if not _put(item):
                            return
                except BaseException as exc:  # surface errors to consumer
                    _put(exc)
                finally:
                    with lock:
                        n_alive[0] -= 1
                        if n_alive[0] == 0:
                            _put(StopIteration())

            threads = [
                threading.Thread(
                    target=_drive, args=(c,), daemon=True, name=f"union-drive-{i}"
                )
                for i, c in enumerate(children)
            ]
            for t in threads:
                t.start()
            try:
                while True:
                    item = q.get()
                    if isinstance(item, StopIteration):
                        return
                    if isinstance(item, BaseException):
                        raise item
                    yield item
            finally:
                done.set()
                # Unblock drivers racing a full queue, then join them so no
                # daemon threads outlive the merged stream.
                while True:
                    try:
                        q.get_nowait()
                    except queue.Empty:
                        break
                for t in threads:
                    t.join(timeout=2.0)
                for c in children:
                    c.close()

        return LocalIterator(
            _async_gen, metrics=merged_metrics, name="union_async", parents=children
        )

    def duplicate(self, n: int, bound: int = 1000) -> List["LocalIterator[T]"]:
        """Split an iterator into ``n`` copies (paper Fig 8, split).

        Buffers are inserted to retain items until fully consumed; the
        scheduler bounds memory by warning when a consumer falls more than
        ``bound`` items behind (RLlib Flow behaviour).
        """
        parent_iter = iter(self)
        lock = threading.Lock()
        buffers: List[List[Any]] = [[] for _ in range(n)]
        exhausted = [False]

        def _make(i: int) -> Iterator[Any]:
            while True:
                with lock:
                    if buffers[i]:
                        item = buffers[i].pop(0)
                    elif exhausted[0]:
                        return
                    else:
                        try:
                            item = next(parent_iter)
                        except StopIteration:
                            exhausted[0] = True
                            return
                        for j in range(n):
                            if j != i:
                                buffers[j].append(item)
                                if len(buffers[j]) > bound:
                                    logger.warning(
                                        "duplicate(): consumer %d lags %d items",
                                        j,
                                        len(buffers[j]),
                                    )
                yield item

        return [
            LocalIterator(
                lambda i=i: _make(i),
                metrics=self.metrics,
                name=f"{self.name}.dup{i}",
                parents=[self],
            )
            for i in range(n)
        ]

    def __repr__(self) -> str:  # pragma: no cover
        return f"LocalIterator[{self.name}]"


# --------------------------------------------------------------------------
# ParallelIterator
# --------------------------------------------------------------------------
class _Shard:
    """One shard of a parallel iterator, bound to a source actor."""

    def __init__(self, actor: VirtualActor, pull_fn: Callable[[Any], Any]):
        self.actor = actor
        self.pull_fn = pull_fn  # target -> item

    def dispatch(self, stages: Sequence[Callable]) -> "Any":
        """Schedule one item production (pull + stages) onto the actor."""
        pull_fn = self.pull_fn

        def _produce(target: Any) -> Any:
            item = pull_fn(target)
            return _apply_stages(item, stages)

        return self.actor.apply(_produce)


def _clone_stage(fn: Callable) -> Callable:
    """Per-shard stage cloning rule (see ``ParallelIterator.for_each``)."""
    if isinstance(fn, types.FunctionType) or getattr(fn, "share_across_shards", False):
        return fn
    try:
        return copy.deepcopy(fn)
    except Exception:
        return fn


class ParallelIterator(Generic[T]):
    """A parallel stream sharded over an actor pool (``ParIter[T]``).

    When built ``from_actors`` the iterator keeps a reference to the source
    pool and re-syncs shard membership with it inside the gather loops, so
    workers added or removed mid-stream (elastic training, supervision
    replacing a dead actor) join/leave the stream without a rebuild.
    """

    def __init__(
        self,
        shards: Sequence[_Shard],
        name: str = "ParallelIterator",
        pool: Optional[ActorPool] = None,
        pull_fn: Optional[Callable[[Any], Any]] = None,
    ):
        self._shards = list(shards)
        self._pool = pool
        self._pull_fn = pull_fn
        self._pool_version = pool.version if pool is not None else None
        # Original stage callables; per-actor clones are made lazily so that
        # shards added later (elasticity) get their own stateful copies.
        self._stage_fns: List[Callable] = []
        self._clones: List[Dict[int, Callable]] = []
        self.name = name

    # ------------------------------------------------------------- creation
    @classmethod
    def from_actors(
        cls,
        pool: ActorPool,
        pull_fn: Callable[[Any], Any],
        name: str = "ParallelIterator",
    ) -> "ParallelIterator":
        return cls(
            [_Shard(a, pull_fn) for a in pool], name=name, pool=pool, pull_fn=pull_fn
        )

    @property
    def actors(self) -> List[VirtualActor]:
        return [s.actor for s in self._shards]

    def num_shards(self) -> int:
        return len(self._shards)

    # ------------------------------------------------------------ operators
    def for_each(self, fn: Callable[[T], U]) -> "ParallelIterator[U]":
        """Parallel transformation, *executed on the source actor* so that
        ``fn`` can observe actor-local state (paper §4, Transformation).

        Stateful callable classes are cloned per shard (each shard gets its
        own state, as when Ray pickles the callable to each worker) unless
        they set ``share_across_shards = True`` or are not deep-copyable
        (operators that hold actor handles).
        """
        out = ParallelIterator(
            self._shards, name=f"{self.name}.for_each",
            pool=self._pool, pull_fn=self._pull_fn,
        )
        out._stage_fns = self._stage_fns + [fn]
        out._clones = [dict() for _ in out._stage_fns]
        return out

    # Alias matching the paper's pseudocode.
    par_for_each = for_each

    def _stages_for(self, actor: VirtualActor) -> List[Callable]:
        """The per-actor stage chain (clones created lazily per shard)."""
        out: List[Callable] = []
        for i, fn in enumerate(self._stage_fns):
            cache = self._clones[i]
            if actor.actor_id not in cache:
                cache[actor.actor_id] = _clone_stage(fn)
            out.append(cache[actor.actor_id])
        return out

    def _sync_shards(self) -> bool:
        """Reflect source-pool membership changes (elastic add/remove)."""
        if self._pool is None or self._pull_fn is None:
            return False
        if self._pool.version == self._pool_version:
            return False
        self._pool_version = self._pool.version
        have = {s.actor.actor_id: s for s in self._shards}
        self._shards = [
            have.get(a.actor_id) or _Shard(a, self._pull_fn) for a in self._pool
        ]
        return True

    def union(self, other: "ParallelIterator") -> "ParallelIterator":
        """Union of two parallel iterators (shards side by side).

        Requires both to be gathered later; stages already applied per side
        are preserved by materializing them into the shard pull functions.
        """
        def _freeze(par: "ParallelIterator") -> List[_Shard]:
            frozen = []
            for s in par._shards:
                stages = par._stages_for(s.actor)
                pull = s.pull_fn

                def _pull(target: Any, _p=pull, _st=tuple(stages)) -> Any:
                    return _apply_stages(_p(target), _st)

                frozen.append(_Shard(s.actor, _pull))
            return frozen

        return ParallelIterator(_freeze(self) + _freeze(other), name=f"{self.name}.union")

    # ------------------------------------------------------------ gathering
    def gather_sync(self, metrics_key: Optional[str] = None) -> "LocalIterator[T]":
        """Deterministic sequencing with *barrier semantics* (paper Fig 7).

        One item is pulled from every shard; upstream actors are fully halted
        between fetches, so messages sent to source actors between item
        fetches are ordered w.r.t. the dataflow (black arrows).  Failed
        shards are skipped/dropped per their actor's FailurePolicy.  Bytes
        yielded are recorded under ``bytes_moved/<metrics_key>``.
        """

        def _gen() -> Iterator[Any]:
            dropped: Dict[int, str] = {}
            while True:
                self._sync_shards()
                _rejoin_revived(dropped, self._shards)
                shards = [s for s in self._shards if s.actor.actor_id not in dropped]
                if not shards:
                    if dropped:
                        raise RuntimeError(f"{self.name}: all shards failed")
                    return
                # Dispatch defensively: an actor stopped mid-round (elastic
                # remove_workers race / teardown) is skipped, but futures
                # already dispatched this round are still gathered so their
                # items are never silently discarded.
                round_start = time.perf_counter()
                futures = []
                for s in shards:
                    try:
                        futures.append((s, s.dispatch(self._stages_for(s.actor))))
                    except RuntimeError:
                        pass
                if not futures:
                    if self._sync_shards():
                        continue  # membership changed: retry with survivors
                    return  # all actors stopped: stream teardown
                # Global barrier: wait for every shard's item.
                results = []
                for s, f in futures:
                    try:
                        item = f.result()
                    except StopIteration:
                        item = _EXHAUSTED
                    except Exception as exc:
                        item = _absorb_shard_failure(s.actor, exc, dropped, self.name)
                    results.append((item, s.actor))
                if any(isinstance(item, _Exhausted) for item, _ in results):
                    return
                # Per-round wall time of the dispatch -> barrier -> gathered
                # window, keyed by node id: the stage's live wall-time column
                # in Algorithm.explain() (for a rollouts source this is the
                # sample time the flow actually observed).
                get_metrics().timers[GATHER_TIMER_PREFIX + key].push(
                    time.perf_counter() - round_start
                )
                for item, actor in results:
                    if isinstance(item, (NextValueNotReady, _ShardVerdict)):
                        continue
                    metrics = get_metrics()
                    metrics.current_actor = actor
                    nbytes = payload_nbytes(item)
                    if nbytes:
                        metrics.counters[NUM_BYTES_MOVED] += nbytes
                        metrics.counters[BYTES_MOVED_PREFIX + key] += nbytes
                    yield item

        key = metrics_key or f"{self.name}.gather_sync"
        return LocalIterator(_gen, name=f"{self.name}.gather_sync")

    def gather_async(
        self,
        num_async: int = 1,
        credits: Optional[int] = None,
        metrics_key: Optional[str] = None,
    ) -> "LocalIterator[T]":
        """Asynchronous sequencing (paper Fig 7, pink arrow).

        Keeps up to ``num_async`` items in flight *per shard*; yields items in
        completion order and immediately backfills the producing shard —
        equivalent to RLlib Flow's async gather with configurable pipeline
        parallelism.  A failed shard is skipped or dropped per its actor's
        FailurePolicy; newly added pool actors join the pipeline mid-stream.

        Backpressure (data plane, ISSUE 3): ``credits`` caps the *total*
        number of dispatched-but-not-yet-consumed items across all shards
        (default: ``num_async * num_shards``, i.e. the per-shard window).  A
        shard that would exceed the window is *starved* instead of
        dispatched; the stall is recorded (``num_credit_stalls`` /
        ``credit_stall_time_s``) and the shard is backfilled as soon as the
        consumer frees a credit — so a slow consumer can never accumulate an
        unbounded completed-item backlog.  ``inflight/<metrics_key>`` gauges
        the window occupancy; bytes yielded are recorded under
        ``bytes_moved/<metrics_key>``.
        """
        if num_async < 1:
            raise ValueError("num_async must be >= 1")
        if credits is not None and credits < 1:
            raise ValueError("credits must be >= 1 (or None for num_async * shards)")

        def _gen() -> Iterator[Any]:
            result_q: "queue.Queue[tuple]" = queue.Queue()
            shard_by_id: Dict[int, _Shard] = {}
            inflight: Dict[int, int] = {}
            dropped: Dict[int, str] = {}
            exhausted: set = set()
            removed: set = set()
            # The credit window: one credit per dispatched-but-unconsumed
            # item, resized as shard membership changes.  Starved shards
            # wait here (aid -> stall start) until a credit frees.
            from repro.core.transport import CreditPool

            credit_pool = CreditPool(credits if credits is not None else 1)
            starved: Dict[int, float] = {}

            def _capacity() -> int:
                if credits is not None:
                    return credits
                live = len(
                    [
                        aid
                        for aid in shard_by_id
                        if aid not in dropped and aid not in removed and aid not in exhausted
                    ]
                )
                return num_async * max(1, live)

            def _dispatch(s: _Shard, have_credit: bool = False) -> None:
                aid = s.actor.actor_id
                if not have_credit and not credit_pool.try_acquire():
                    if aid not in starved:
                        starved[aid] = time.perf_counter()
                        get_metrics().counters[NUM_CREDIT_STALLS] += 1
                    return
                try:
                    fut = s.dispatch(self._stages_for(s.actor))
                except RuntimeError:
                    # Actor stopped between membership sync and dispatch
                    # (graceful remove_workers race): treat as removed.
                    credit_pool.release()
                    removed.add(aid)
                    return
                inflight[aid] = inflight.get(aid, 0) + 1
                fut.add_done_callback(lambda f, aid=aid: result_q.put((aid, f)))

            def _backfill_starved() -> None:
                # A credit was just freed: resume starved shards FIFO,
                # charging their stall time to the shared metrics context.
                while starved and credit_pool.try_acquire():
                    aid, t0 = next(iter(starved.items()))
                    del starved[aid]
                    metrics = get_metrics()
                    metrics.counters[CREDIT_STALL_TIME] = (
                        metrics.counters.get(CREDIT_STALL_TIME, 0)
                        + (time.perf_counter() - t0)
                    )
                    if aid in shard_by_id and aid not in dropped and aid not in removed:
                        _dispatch(shard_by_id[aid], have_credit=True)
                    else:
                        credit_pool.release()

            def _admit() -> None:
                # Pick up pool membership changes (elastic add/remove) and
                # rejoin shards whose dead actor was revived by recover().
                self._sync_shards()
                credit_pool.resize(_capacity())
                for s in _rejoin_revived(dropped, self._shards):
                    for _ in range(num_async - inflight.get(s.actor.actor_id, 0)):
                        _dispatch(s)
                current = set()
                for s in self._shards:
                    aid = s.actor.actor_id
                    current.add(aid)
                    if aid not in shard_by_id:
                        shard_by_id[aid] = s
                        credit_pool.resize(_capacity())
                        for _ in range(num_async):
                            _dispatch(s)
                for aid in shard_by_id:
                    if aid not in current:
                        removed.add(aid)  # stop backfilling; drain in-flight
                        starved.pop(aid, None)
                credit_pool.resize(_capacity())

            _admit()
            while True:
                _admit()  # cheap (pool version compare); elastic sync point
                if sum(inflight.values()) == 0:
                    active = set(shard_by_id) - set(dropped) - exhausted - removed
                    if not active:
                        if dropped and not (exhausted or removed):
                            raise RuntimeError(f"{self.name}: all shards failed")
                        return
                    if starved:
                        _backfill_starved()  # window freed below a live shard
                try:
                    aid, fut = result_q.get(timeout=0.1)
                except queue.Empty:
                    continue  # elastic wake-up: re-check membership
                inflight[aid] -= 1
                credit_pool.release()  # every popped result frees its credit
                gone = aid in dropped or aid in removed
                try:
                    item = fut.result()
                except StopIteration:
                    exhausted.add(aid)
                    starved.pop(aid, None)
                    _backfill_starved()
                    continue
                except Exception as exc:
                    verdict = _absorb_shard_failure(
                        shard_by_id[aid].actor, exc, dropped, self.name
                    )
                    if verdict is _SKIPPED and not gone:
                        _dispatch(shard_by_id[aid])  # keep the pipeline full
                    else:
                        starved.pop(aid, None)
                        _backfill_starved()
                    continue
                if not gone:
                    if starved:
                        # Credits are contended: queue this shard behind the
                        # ones already stalled (FIFO fairness) rather than
                        # letting the fastest producer monopolize the window.
                        if aid not in starved:
                            starved[aid] = time.perf_counter()
                            get_metrics().counters[NUM_CREDIT_STALLS] += 1
                    else:
                        _dispatch(shard_by_id[aid])
                if isinstance(item, NextValueNotReady):
                    _backfill_starved()
                    continue
                metrics = get_metrics()
                metrics.current_actor = shard_by_id[aid].actor
                nbytes = payload_nbytes(item)
                if nbytes:
                    metrics.counters[NUM_BYTES_MOVED] += nbytes
                    metrics.counters[BYTES_MOVED_PREFIX + key] += nbytes
                metrics.gauges[INFLIGHT_PREFIX + key] = sum(inflight.values())
                yield item
                # The consumer took the item: its credit is free again.
                _backfill_starved()

        key = metrics_key or f"{self.name}.gather_async"
        return LocalIterator(_gen, name=f"{self.name}.gather_async")

    def batch_across_shards(
        self, metrics_key: Optional[str] = None
    ) -> "LocalIterator[List[T]]":
        """One synchronized list of per-shard items per pull (sync barrier)."""

        def _gen() -> Iterator[Any]:
            dropped: Dict[int, str] = {}
            while True:
                self._sync_shards()
                _rejoin_revived(dropped, self._shards)
                shards = [s for s in self._shards if s.actor.actor_id not in dropped]
                if not shards:
                    if dropped:
                        raise RuntimeError(f"{self.name}: all shards failed")
                    return
                # Defensive dispatch: see gather_sync — skip actors stopped
                # mid-round but never abandon already-dispatched futures.
                round_start = time.perf_counter()
                futures = []
                for s in shards:
                    try:
                        futures.append((s, s.dispatch(self._stages_for(s.actor))))
                    except RuntimeError:
                        pass
                if not futures:
                    if self._sync_shards():
                        continue
                    return
                items = []
                for s, f in futures:
                    try:
                        items.append(f.result())
                    except StopIteration:
                        items.append(_EXHAUSTED)
                    except Exception as exc:
                        items.append(
                            _absorb_shard_failure(s.actor, exc, dropped, self.name)
                        )
                if any(isinstance(x, _Exhausted) for x in items):
                    return
                # Same per-round gather timer as gather_sync (see there); for
                # a bulk_sync rollouts source this is the observed sample time.
                get_metrics().timers[GATHER_TIMER_PREFIX + key].push(
                    time.perf_counter() - round_start
                )
                items = [
                    x for x in items
                    if not isinstance(x, (NextValueNotReady, _ShardVerdict))
                ]
                if items:
                    metrics = get_metrics()
                    nbytes = payload_nbytes(items)
                    if nbytes:
                        metrics.counters[NUM_BYTES_MOVED] += nbytes
                        metrics.counters[BYTES_MOVED_PREFIX + key] += nbytes
                    yield items

        key = metrics_key or f"{self.name}.batch_across_shards"
        return LocalIterator(_gen, name=f"{self.name}.batch_across_shards")

    def __repr__(self) -> str:  # pragma: no cover
        return f"ParallelIterator[{self.name}, shards={len(self._shards)}]"


# --------------------------------------------------------------------------
# Convenience constructors
# --------------------------------------------------------------------------
def from_actors(pool: ActorPool, method: str = "sample") -> ParallelIterator:
    """Parallel iterator pulling ``actor.target.<method>()`` per item."""
    return ParallelIterator.from_actors(pool, lambda target: getattr(target, method)())


def from_items(items: Sequence[Any], repeat: bool = False) -> LocalIterator:
    def _gen() -> Iterator[Any]:
        while True:
            for x in items:
                yield x
            if not repeat:
                return

    return LocalIterator(_gen, name="from_items")


def from_iterators(
    pools: Sequence[Iterable[Any]],
) -> ParallelIterator:
    """Shard a parallel iterator over plain python iterables (testing aid)."""
    class _IterHolder:
        def __init__(self, it: Iterable[Any]):
            self.it = iter(it)

        def pull(self) -> Any:
            return next(self.it)

    pool = ActorPool.from_targets([_IterHolder(it) for it in pools], name="from_iterators")
    return ParallelIterator.from_actors(pool, lambda t: t.pull())
