"""Distributed iterators: the RLlib Flow programming model core.

Two iterator kinds (paper §4):

  * ``ParallelIterator[T]`` — a lazy parallel stream of items sharded across a
    pool of (virtual) actors.  Transformations added with ``for_each`` are
    *scheduled onto the source actor* so they can read actor-local state
    (policy weights, env state).  Consuming a parallel iterator requires a
    sequencing operator: ``gather_sync`` (deterministic, barrier semantics) or
    ``gather_async`` (items surface as soon as ready; ``num_async`` controls
    pipeline depth).

  * ``LocalIterator[T]`` — a lazy sequential stream.  Supports ``for_each``,
    ``filter``, ``batch``, ``combine``, ``zip_with_source_actor``, ``union``
    (round-robin or async, with rate-limiting weights) and ``duplicate``.

Iterators are lazy: building a dataflow does nothing; pulling items from the
output iterator drives the whole graph (Volcano-style).
"""

from __future__ import annotations

import copy
import logging
import queue
import threading
from typing import (
    Any,
    Callable,
    Generic,
    Iterable,
    Iterator,
    List,
    Optional,
    Sequence,
    TypeVar,
    Union,
)

from repro.core.actor import ActorPool, VirtualActor, wait
from repro.core.metrics import MetricsContext, get_metrics, set_metrics_for_thread

T = TypeVar("T")
U = TypeVar("U")

logger = logging.getLogger(__name__)

__all__ = [
    "LocalIterator",
    "ParallelIterator",
    "NextValueNotReady",
    "from_actors",
    "from_items",
    "from_iterators",
]


class NextValueNotReady:
    """Sentinel yielded by non-blocking fragments when no item is ready yet.

    Round-robin unions propagate it so one starved branch cannot stall the
    others (paper: asynchronous dependencies / pink arrows).
    """

    def __repr__(self) -> str:  # pragma: no cover
        return "<NextValueNotReady>"


_NOT_READY = NextValueNotReady()


def _apply_stages(item: Any, stages: Sequence[Callable]) -> Any:
    for fn in stages:
        if isinstance(item, NextValueNotReady):
            return item
        item = fn(item)
    return item


class _Exhausted:
    """Internal marker: a shard's underlying stream raised StopIteration."""


_EXHAUSTED = _Exhausted()


def _result_or_exhausted(fut: Any) -> Any:
    """Future.result() that maps StopIteration to a marker.

    PEP 479: raising StopIteration inside a generator is a RuntimeError, so
    finite shards (testing) must signal exhaustion out-of-band.
    """
    try:
        return fut.result()
    except StopIteration:
        return _EXHAUSTED


# --------------------------------------------------------------------------
# LocalIterator
# --------------------------------------------------------------------------
class LocalIterator(Generic[T]):
    """A lazy sequential stream of items with a shared metrics context."""

    def __init__(
        self,
        base_builder: Callable[[], Iterator[T]],
        metrics: Optional[MetricsContext] = None,
        stages: Optional[List[Callable]] = None,
        name: str = "LocalIterator",
    ):
        self._base_builder = base_builder
        self._stages: List[Callable] = list(stages or [])
        self.metrics = metrics or MetricsContext()
        self.name = name
        self._built: Optional[Iterator[T]] = None

    # ------------------------------------------------------------- plumbing
    def _build(self) -> Iterator[T]:
        if self._built is None:
            self._built = self._base_builder()
        return self._built

    def __iter__(self) -> Iterator[T]:
        it = self._build()
        while True:
            # Install this dataflow's context before pulling: base generators
            # (gather ops) report current_actor through the thread-local.
            set_metrics_for_thread(self.metrics)
            try:
                item = next(it)
            except StopIteration:
                return
            item = _apply_stages(item, self._stages)
            if isinstance(item, NextValueNotReady):
                continue
            yield item

    def __next__(self) -> T:
        # Pull until a concrete item emerges (skipping not-ready sentinels).
        it = self._build()
        while True:
            set_metrics_for_thread(self.metrics)
            item = next(it)
            item = _apply_stages(item, self._stages)
            if not isinstance(item, NextValueNotReady):
                return item

    def next(self) -> T:
        return self.__next__()

    def _iter_with_sentinels(self) -> Iterator[Any]:
        """Like ``__iter__`` but yields NextValueNotReady through, so unions
        can move on to other branches instead of blocking on a starved one."""
        it = self._build()
        while True:
            set_metrics_for_thread(self.metrics)
            try:
                item = next(it)
            except StopIteration:
                return
            yield _apply_stages(item, self._stages)

    def _chain(self, fn: Callable, name: str) -> "LocalIterator":
        return LocalIterator(
            self._base_builder,
            metrics=self.metrics,
            stages=self._stages + [fn],
            name=f"{self.name}.{name}",
        )

    # ------------------------------------------------------------ operators
    def for_each(self, fn: Callable[[T], U]) -> "LocalIterator[U]":
        """Transformation operator (paper Fig 6). ``fn`` may be stateful."""
        return self._chain(fn, f"for_each({getattr(fn, '__name__', type(fn).__name__)})")

    def filter(self, predicate: Callable[[T], bool]) -> "LocalIterator[T]":
        def _filter(item: Any) -> Any:
            return item if predicate(item) else _NOT_READY

        return self._chain(_filter, "filter")

    def batch(self, n: int) -> "LocalIterator[List[T]]":
        buf: List[Any] = []

        def _batch(item: Any) -> Any:
            buf.append(item)
            if len(buf) >= n:
                out, buf[:] = list(buf), []
                return out
            return _NOT_READY

        return self._chain(_batch, f"batch({n})")

    def flatten(self) -> "LocalIterator[Any]":
        parent = self

        def _gen() -> Iterator[Any]:
            for item in parent:
                for sub in item:
                    yield sub

        return LocalIterator(_gen, metrics=self.metrics, name=f"{self.name}.flatten")

    def combine(self, fn: Callable[[T], Iterable[U]]) -> "LocalIterator[U]":
        """for_each returning a list, flattened (RLlib's ``combine``)."""
        return self.for_each(fn).flatten()

    def take(self, n: int) -> List[T]:
        out: List[T] = []
        it = iter(self)
        for _ in range(n):
            try:
                out.append(next(it))
            except StopIteration:
                break
        return out

    def zip_with_source_actor(self) -> "LocalIterator[tuple]":
        """Pair each item with the actor that produced it (paper §5.2)."""

        def _zip(item: Any) -> Any:
            return (item, get_metrics().current_actor)

        return self._chain(_zip, "zip_with_source_actor")

    # -------------------------------------------------------------- unions
    def union(
        self,
        *others: "LocalIterator",
        deterministic: bool = False,
        round_robin_weights: Optional[Sequence[Union[int, str]]] = None,
    ) -> "LocalIterator":
        """Concurrency operator (paper Fig 8): merge concurrent fragments.

        deterministic=True  -> round-robin (optionally weighted; weight ``k``
            pulls k items per turn, ``'*'`` drains what is ready).  This is
            the rate-limiting mechanism [Acme] for e.g. replay:sample ratios.
        deterministic=False -> async merge: each child is driven by its own
            thread; items surface in completion order (pink arrows).
        """
        children = [self, *others]
        # Children share one metrics context so counters/current_actor flow.
        merged_metrics = self.metrics
        for c in others:
            for k, v in c.metrics.counters.items():
                merged_metrics.counters[k] += v
            c.metrics = merged_metrics

        if deterministic:
            weights = list(round_robin_weights or [1] * len(children))
            if len(weights) != len(children):
                raise ValueError("round_robin_weights must match #children")

            def _rr_gen() -> Iterator[Any]:
                # Sentinel-aware pulls: a branch that reports "not ready"
                # (e.g. a cold replay buffer) yields its turn instead of
                # blocking the whole union (paper: rate-limited concurrency).
                iters = [c._iter_with_sentinels() for c in children]
                alive = [True] * len(iters)
                while any(alive):
                    for i, it in enumerate(iters):
                        if not alive[i]:
                            continue
                        pulls = weights[i]
                        n = 1 if pulls == "*" else int(pulls)
                        for _ in range(n):
                            try:
                                item = next(it)
                            except StopIteration:
                                alive[i] = False
                                break
                            yield item  # may be a sentinel; consumer skips

            return LocalIterator(_rr_gen, metrics=merged_metrics, name="union_rr")

        def _async_gen() -> Iterator[Any]:
            q: "queue.Queue[Any]" = queue.Queue(maxsize=max(8, 2 * len(children)))
            done = threading.Event()
            n_alive = [len(children)]
            lock = threading.Lock()

            def _drive(child: LocalIterator) -> None:
                try:
                    set_metrics_for_thread(merged_metrics)
                    for item in child:
                        if done.is_set():
                            return
                        q.put(item)
                except BaseException as exc:  # surface errors to consumer
                    q.put(exc)
                finally:
                    with lock:
                        n_alive[0] -= 1
                        if n_alive[0] == 0:
                            q.put(StopIteration())

            threads = [
                threading.Thread(target=_drive, args=(c,), daemon=True) for c in children
            ]
            for t in threads:
                t.start()
            try:
                while True:
                    item = q.get()
                    if isinstance(item, StopIteration):
                        return
                    if isinstance(item, BaseException):
                        raise item
                    yield item
            finally:
                done.set()

        return LocalIterator(_async_gen, metrics=merged_metrics, name="union_async")

    def duplicate(self, n: int, bound: int = 1000) -> List["LocalIterator[T]"]:
        """Split an iterator into ``n`` copies (paper Fig 8, split).

        Buffers are inserted to retain items until fully consumed; the
        scheduler bounds memory by warning when a consumer falls more than
        ``bound`` items behind (RLlib Flow behaviour).
        """
        parent_iter = iter(self)
        lock = threading.Lock()
        buffers: List[List[Any]] = [[] for _ in range(n)]
        exhausted = [False]

        def _make(i: int) -> Iterator[Any]:
            while True:
                with lock:
                    if buffers[i]:
                        item = buffers[i].pop(0)
                    elif exhausted[0]:
                        return
                    else:
                        try:
                            item = next(parent_iter)
                        except StopIteration:
                            exhausted[0] = True
                            return
                        for j in range(n):
                            if j != i:
                                buffers[j].append(item)
                                if len(buffers[j]) > bound:
                                    logger.warning(
                                        "duplicate(): consumer %d lags %d items",
                                        j,
                                        len(buffers[j]),
                                    )
                yield item

        return [
            LocalIterator(lambda i=i: _make(i), metrics=self.metrics, name=f"{self.name}.dup{i}")
            for i in range(n)
        ]

    def __repr__(self) -> str:  # pragma: no cover
        return f"LocalIterator[{self.name}]"


# --------------------------------------------------------------------------
# ParallelIterator
# --------------------------------------------------------------------------
class _Shard:
    """One shard of a parallel iterator, bound to a source actor."""

    def __init__(self, actor: VirtualActor, pull_fn: Callable[[Any], Any]):
        self.actor = actor
        self.pull_fn = pull_fn  # target -> item

    def dispatch(self, stages: Sequence[Callable]) -> "Any":
        """Schedule one item production (pull + stages) onto the actor."""
        pull_fn = self.pull_fn

        def _produce(target: Any) -> Any:
            item = pull_fn(target)
            return _apply_stages(item, stages)

        return self.actor.apply(_produce)


class ParallelIterator(Generic[T]):
    """A parallel stream sharded over an actor pool (``ParIter[T]``)."""

    def __init__(
        self,
        shards: Sequence[_Shard],
        name: str = "ParallelIterator",
    ):
        self._shards = list(shards)
        # List of per-stage, per-shard callables: _stage_clones[stage][shard].
        self._stage_clones: List[List[Callable]] = []
        self.name = name

    # ------------------------------------------------------------- creation
    @classmethod
    def from_actors(
        cls,
        pool: ActorPool,
        pull_fn: Callable[[Any], Any],
        name: str = "ParallelIterator",
    ) -> "ParallelIterator":
        return cls([_Shard(a, pull_fn) for a in pool], name=name)

    @property
    def actors(self) -> List[VirtualActor]:
        return [s.actor for s in self._shards]

    def num_shards(self) -> int:
        return len(self._shards)

    # ------------------------------------------------------------ operators
    def for_each(self, fn: Callable[[T], U]) -> "ParallelIterator[U]":
        """Parallel transformation, *executed on the source actor* so that
        ``fn`` can observe actor-local state (paper §4, Transformation).

        Stateful callable classes are cloned per shard (each shard gets its
        own state, as when Ray pickles the callable to each worker) unless
        they set ``share_across_shards = True`` or are not deep-copyable
        (operators that hold actor handles).
        """
        import types

        if isinstance(fn, types.FunctionType) or getattr(fn, "share_across_shards", False):
            clones = [fn] * len(self._shards)
        else:
            try:
                clones = [copy.deepcopy(fn) for _ in self._shards]
            except Exception:
                clones = [fn] * len(self._shards)
        out = ParallelIterator(self._shards, name=f"{self.name}.for_each")
        out._stage_clones = getattr(self, "_stage_clones", []) + [clones]  # type: ignore[attr-defined]
        return out

    # Alias matching the paper's pseudocode.
    par_for_each = for_each

    def _shard_stages(self, i: int) -> List[Callable]:
        return [stage_clones[i] for stage_clones in self._stage_clones]

    def union(self, other: "ParallelIterator") -> "ParallelIterator":
        """Union of two parallel iterators (shards side by side).

        Requires both to be gathered later; stages already applied per side
        are preserved by materializing them into the shard pull functions.
        """
        def _freeze(par: "ParallelIterator") -> List[_Shard]:
            frozen = []
            for i, s in enumerate(par._shards):
                stages = par._shard_stages(i)
                pull = s.pull_fn

                def _pull(target: Any, _p=pull, _st=tuple(stages)) -> Any:
                    return _apply_stages(_p(target), _st)

                frozen.append(_Shard(s.actor, _pull))
            return frozen

        return ParallelIterator(_freeze(self) + _freeze(other), name=f"{self.name}.union")

    # ------------------------------------------------------------ gathering
    def gather_sync(self) -> "LocalIterator[T]":
        """Deterministic sequencing with *barrier semantics* (paper Fig 7).

        One item is pulled from every shard; upstream actors are fully halted
        between fetches, so messages sent to source actors between item
        fetches are ordered w.r.t. the dataflow (black arrows).
        """

        def _gen() -> Iterator[Any]:
            while True:
                futures = [
                    shard.dispatch(self._shard_stages(i))
                    for i, shard in enumerate(self._shards)
                ]
                # Global barrier: wait for every shard's item.
                results = [
                    (_result_or_exhausted(f), s.actor)
                    for f, s in zip(futures, self._shards)
                ]
                if any(isinstance(item, _Exhausted) for item, _ in results):
                    return
                for item, actor in results:
                    if isinstance(item, NextValueNotReady):
                        continue
                    get_metrics().current_actor = actor
                    yield item

        return LocalIterator(_gen, name=f"{self.name}.gather_sync")

    def gather_async(self, num_async: int = 1) -> "LocalIterator[T]":
        """Asynchronous sequencing (paper Fig 7, pink arrow).

        Keeps up to ``num_async`` items in flight *per shard*; yields items in
        completion order and immediately backfills the producing shard —
        equivalent to RLlib Flow's async gather with configurable pipeline
        parallelism.
        """
        if num_async < 1:
            raise ValueError("num_async must be >= 1")

        def _gen() -> Iterator[Any]:
            result_q: "queue.Queue[tuple]" = queue.Queue()
            inflight = 0

            def _dispatch(i: int) -> None:
                nonlocal inflight
                fut = self._shards[i].dispatch(self._shard_stages(i))
                fut.add_done_callback(lambda f, i=i: result_q.put((i, f)))
                inflight += 1

            for i in range(len(self._shards)):
                for _ in range(num_async):
                    _dispatch(i)
            while inflight:
                i, fut = result_q.get()
                inflight -= 1
                item = _result_or_exhausted(fut)  # re-raises worker errors
                if isinstance(item, _Exhausted):
                    continue  # shard drained; stop backfilling it
                _dispatch(i)
                if isinstance(item, NextValueNotReady):
                    continue
                get_metrics().current_actor = self._shards[i].actor
                yield item

        return LocalIterator(_gen, name=f"{self.name}.gather_async")

    def batch_across_shards(self) -> "LocalIterator[List[T]]":
        """One synchronized list of per-shard items per pull (sync barrier)."""

        def _gen() -> Iterator[Any]:
            while True:
                futures = [
                    shard.dispatch(self._shard_stages(i))
                    for i, shard in enumerate(self._shards)
                ]
                items = [_result_or_exhausted(f) for f in futures]
                if any(isinstance(x, _Exhausted) for x in items):
                    return
                items = [x for x in items if not isinstance(x, NextValueNotReady)]
                if items:
                    yield items

        return LocalIterator(_gen, name=f"{self.name}.batch_across_shards")

    def __repr__(self) -> str:  # pragma: no cover
        return f"ParallelIterator[{self.name}, shards={len(self._shards)}]"


# --------------------------------------------------------------------------
# Convenience constructors
# --------------------------------------------------------------------------
def from_actors(pool: ActorPool, method: str = "sample") -> ParallelIterator:
    """Parallel iterator pulling ``actor.target.<method>()`` per item."""
    return ParallelIterator.from_actors(pool, lambda target: getattr(target, method)())


def from_items(items: Sequence[Any], repeat: bool = False) -> LocalIterator:
    def _gen() -> Iterator[Any]:
        while True:
            for x in items:
                yield x
            if not repeat:
                return

    return LocalIterator(_gen, name="from_items")


def from_iterators(
    pools: Sequence[Iterable[Any]],
) -> ParallelIterator:
    """Shard a parallel iterator over plain python iterables (testing aid)."""
    class _IterHolder:
        def __init__(self, it: Iterable[Any]):
            self.it = iter(it)

        def pull(self) -> Any:
            return next(self.it)

    pool = ActorPool.from_targets([_IterHolder(it) for it in pools], name="from_iterators")
    return ParallelIterator.from_actors(pool, lambda t: t.pull())
