"""Dataflow -> SPMD lowering (DESIGN.md §3.2).

A *synchronous* dataflow fragment — rollout/data -> transform ->
``gather_sync`` barrier -> train -> weight broadcast — has exactly the
semantics of one SPMD step: the barrier is the collective, and the broadcast
is the SPMD invariant that every shard already holds the updated params.
``SPMDTrainContext`` performs that lowering: it binds a model + optimizer to
a mesh and sharding rules and yields jit-compiled step functions whose
in/out shardings implement the fragment.

The resulting step plugs back into the host-level dataflow as the
``learn_on_batch`` of an ``SPMDLearnerWorker`` — so the same plans
(ppo_plan-shaped: data -> ConcatBatches -> TrainOneStep -> metrics) drive a
single CPU process or a 512-chip pod, which is the paper's thesis applied to
TPU: the dataflow is the program; the schedule is an execution detail.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import DEFAULT_RULES, AxisRules, axis_rules_context
from repro.distributed.specs import opt_state_specs, param_specs, tree_shardings
from repro.models import Model, make_train_step
from repro.optim import Optimizer

PyTree = Any

__all__ = ["SPMDTrainContext", "SPMDLearnerWorker"]


class SPMDTrainContext:
    def __init__(
        self,
        cfg: ModelConfig,
        optimizer: Optimizer,
        mesh: Any,
        rules: Optional[Dict[str, Any]] = None,
    ):
        self.cfg = cfg
        self.model = Model(cfg)
        self.optimizer = optimizer
        self.mesh = mesh
        self.rules = AxisRules(rules or DEFAULT_RULES, mesh)
        self._train_step: Optional[Callable] = None

    # ------------------------------------------------------------- lowering
    def shardings(self) -> Tuple[PyTree, PyTree]:
        params_shape = jax.eval_shape(self.model.init_params, jax.random.PRNGKey(0))
        pspecs = param_specs(params_shape, self.rules)
        opt_shape = jax.eval_shape(self.optimizer.init, params_shape)
        ospecs = opt_state_specs(opt_shape, pspecs, self.rules)
        return tree_shardings(self.mesh, pspecs), tree_shardings(self.mesh, ospecs)

    def init(self, seed: int = 0) -> Tuple[PyTree, PyTree]:
        """Initialize params/opt state directly sharded on the mesh."""
        p_shard, o_shard = self.shardings()
        with self.mesh, axis_rules_context(self.rules):
            params = jax.jit(
                self.model.init_params, out_shardings=p_shard
            )(jax.random.PRNGKey(seed))
            opt_state = jax.jit(self.optimizer.init, out_shardings=o_shard)(params)
        return params, opt_state

    def train_step(self) -> Callable:
        """The fused sync-fragment step: grads + barrier-reduce + apply."""
        if self._train_step is None:
            p_shard, o_shard = self.shardings()
            step = make_train_step(self.model, self.optimizer)
            self._train_step = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, None),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            )
        return self._train_step

    def __call__(self, params, opt_state, batch):
        with self.mesh, axis_rules_context(self.rules):
            device_batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            return self.train_step()(params, opt_state, device_batch)


class SPMDLearnerWorker:
    """Worker-protocol adapter: plugs an SPMD step into TrainOneStep.

    The host dataflow treats it like any rollout/learner worker; its
    ``learn_on_batch`` runs the pjit-compiled fragment on the mesh.
    """

    def __init__(self, ctx: SPMDTrainContext, seed: int = 0):
        self.ctx = ctx
        self.params, self.opt_state = ctx.init(seed)
        self.steps = 0

    def learn_on_batch(self, batch: Any, policy_id: Optional[str] = None) -> Dict[str, Any]:
        self.params, self.opt_state, metrics = self.ctx(self.params, self.opt_state, dict(batch))
        self.steps += 1
        return {k: float(np.asarray(v)) for k, v in metrics.items()}

    def get_weights(self) -> PyTree:
        return self.params

    def set_weights(self, weights: PyTree) -> None:
        self.params = weights

    def episode_stats(self) -> Dict[str, Any]:
        return {"episodes": 0, "episode_reward_mean": float("nan")}
