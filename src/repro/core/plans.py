"""Execution plans: compat shims over the declarative flow graphs.

The paper's Table 2 algorithm suite now lives in ``repro.flow.plans`` as
``FlowSpec`` graph builders — the graph is a first-class value there
(inspectable via ``to_dot()``, optimizable via stage fusion, runnable via
``repro.flow.Algorithm``).  These functions keep the original eager plan
signatures working: each builds the graph, compiles it, and returns the
result iterator, with side effects (learner-thread start) deferred to the
first pull instead of firing at build time.

New code should prefer::

    from repro.flow import Algorithm
    algo = Algorithm.from_plan("apex", workers, replay_actors)

``benchmarks/bench_loc.py`` counts the flow builders (not these shims)
against the low-level ports in ``repro/rl/lowlevel.py`` for Table 2.
"""

from __future__ import annotations

from typing import Dict, Sequence

from repro.core.actor import ActorPool
from repro.core.iterators import LocalIterator
from repro.core.workers import WorkerSet
from repro.flow import plans as flow_plans
from repro.flow.spec import FlowSpec

__all__ = [
    "a3c_plan",
    "a2c_plan",
    "ppo_plan",
    "dqn_plan",
    "apex_plan",
    "impala_plan",
    "sac_plan",
    "maml_plan",
    "appo_plan",
    "mbpo_plan",
    "multi_agent_ppo_dqn_plan",
]


def _as_plan_iterator(spec: FlowSpec) -> LocalIterator[Dict]:
    """Compile a flow graph and expose the legacy plan-iterator surface.

    The returned iterator carries ``.flow`` (the CompiledFlow) and, when the
    graph declares one, ``.learner_thread`` — kept so existing drivers'
    ``plan.learner_thread.stop()`` still works.  The learner thread only
    starts on the first pull.
    """
    compiled = spec.compile()
    it = compiled.iterator()
    it.flow = compiled
    learner = compiled.runtime.resources.get("learner")
    if learner is not None:
        it.learner_thread = learner
    return it


def a3c_plan(workers: WorkerSet, num_async: int = 1) -> LocalIterator[Dict]:
    return _as_plan_iterator(flow_plans.build_a3c(workers, num_async=num_async))


def a2c_plan(workers: WorkerSet) -> LocalIterator[Dict]:
    return _as_plan_iterator(flow_plans.build_a2c(workers))


def ppo_plan(
    workers: WorkerSet,
    train_batch_size: int = 4000,
    num_sgd_iter: int = 8,
    sgd_minibatch_size: int = 128,
) -> LocalIterator[Dict]:
    return _as_plan_iterator(
        flow_plans.build_ppo(
            workers,
            train_batch_size=train_batch_size,
            num_sgd_iter=num_sgd_iter,
            sgd_minibatch_size=sgd_minibatch_size,
        )
    )


def dqn_plan(
    workers: WorkerSet,
    replay_actors: ActorPool,
    target_update_freq: int = 500,
    store_weight: int = 1,
    replay_weight: int = 1,
) -> LocalIterator[Dict]:
    return _as_plan_iterator(
        flow_plans.build_dqn(
            workers,
            replay_actors,
            target_update_freq=target_update_freq,
            store_weight=store_weight,
            replay_weight=replay_weight,
        )
    )


def apex_plan(
    workers: WorkerSet,
    replay_actors: ActorPool,
    target_update_freq: int = 2500,
    max_weight_sync_delay: int = 400,
    num_async_rollouts: int = 2,
    num_async_replay: int = 4,
) -> LocalIterator[Dict]:
    return _as_plan_iterator(
        flow_plans.build_apex(
            workers,
            replay_actors,
            target_update_freq=target_update_freq,
            max_weight_sync_delay=max_weight_sync_delay,
            num_async_rollouts=num_async_rollouts,
            num_async_replay=num_async_replay,
        )
    )


def impala_plan(
    workers: WorkerSet,
    train_batch_size: int = 512,
    num_async: int = 2,
    broadcast_interval: int = 1,
) -> LocalIterator[Dict]:
    return _as_plan_iterator(
        flow_plans.build_impala(
            workers,
            train_batch_size=train_batch_size,
            num_async=num_async,
            broadcast_interval=broadcast_interval,
        )
    )


def sac_plan(
    workers: WorkerSet,
    replay_actors: ActorPool,
    target_update_freq: int = 1,
    store_weight: int = 1,
    replay_weight: int = 1,
) -> LocalIterator[Dict]:
    return _as_plan_iterator(
        flow_plans.build_sac(
            workers,
            replay_actors,
            target_update_freq=target_update_freq,
            store_weight=store_weight,
            replay_weight=replay_weight,
        )
    )


def maml_plan(workers: WorkerSet, inner_steps: int = 1) -> LocalIterator[Dict]:
    return _as_plan_iterator(flow_plans.build_maml(workers, inner_steps=inner_steps))


def appo_plan(
    workers: WorkerSet,
    train_batch_size: int = 512,
    num_async: int = 2,
    broadcast_interval: int = 1,
) -> LocalIterator[Dict]:
    return _as_plan_iterator(
        flow_plans.build_appo(
            workers,
            train_batch_size=train_batch_size,
            num_async=num_async,
            broadcast_interval=broadcast_interval,
        )
    )


def mbpo_plan(
    workers: WorkerSet,
    replay_actors: ActorPool,
    model_train_weight: int = 1,
    policy_train_weight: int = 1,
) -> LocalIterator[Dict]:
    return _as_plan_iterator(
        flow_plans.build_mbpo(
            workers,
            replay_actors,
            model_train_weight=model_train_weight,
            policy_train_weight=policy_train_weight,
        )
    )


def multi_agent_ppo_dqn_plan(
    workers: WorkerSet,
    replay_actors: ActorPool,
    ppo_policies: Sequence[str] = ("ppo_policy",),
    dqn_policies: Sequence[str] = ("dqn_policy",),
    ppo_batch_size: int = 1024,
    dqn_target_update_freq: int = 500,
) -> LocalIterator[Dict]:
    return _as_plan_iterator(
        flow_plans.build_multi_agent_ppo_dqn(
            workers,
            replay_actors,
            ppo_policies=ppo_policies,
            dqn_policies=dqn_policies,
            ppo_batch_size=ppo_batch_size,
            dqn_target_update_freq=dqn_target_update_freq,
        )
    )
