"""Execution plans: the paper's Table 2 algorithm suite as dataflow graphs.

Each plan is a handful of lines of operator composition — the paper's central
claim (2–9× LOC reduction, Figure 9/10/11/12/A2).  ``benchmarks/bench_loc.py``
counts these functions against the low-level ports in
``repro/rl/lowlevel.py`` to reproduce Table 2.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import numpy as np

from repro.core.actor import ActorPool
from repro.core.concurrency import Concurrently, Dequeue, Enqueue
from repro.core.iterators import LocalIterator
from repro.core.learner_thread import LearnerThread
from repro.core.operators import (
    ApplyGradients,
    AverageGradients,
    ConcatBatches,
    ParallelRollouts,
    Replay,
    ReportMetrics,
    SelectExperiences,
    StandardizeFields,
    StandardMetricsReporting,
    StoreToReplayBuffer,
    TrainOneStep,
    UpdateReplayPriorities,
    UpdateTargetNetwork,
    UpdateWorkerWeights,
    par_compute_gradients,
)
from repro.core.workers import WorkerSet

__all__ = [
    "a3c_plan",
    "a2c_plan",
    "ppo_plan",
    "dqn_plan",
    "apex_plan",
    "impala_plan",
    "sac_plan",
    "maml_plan",
    "appo_plan",
    "mbpo_plan",
    "multi_agent_ppo_dqn_plan",
]


# --------------------------------------------------------------------- A3C
def a3c_plan(workers: WorkerSet, num_async: int = 1) -> LocalIterator[Dict]:
    """Figure 9a: async per-worker gradients applied centrally."""
    grads = par_compute_gradients(workers).gather_async(num_async=num_async)
    apply_op = grads.for_each(ApplyGradients(workers, update_all=False))
    return StandardMetricsReporting(apply_op, workers)


# --------------------------------------------------------------------- A2C
def a2c_plan(workers: WorkerSet) -> LocalIterator[Dict]:
    """Synchronous A3C: barrier-gather gradients, average, apply, broadcast."""
    grads = par_compute_gradients(workers).batch_across_shards()
    apply_op = grads.for_each(AverageGradients()).for_each(
        ApplyGradients(workers, update_all=True)
    )
    return StandardMetricsReporting(apply_op, workers)


# --------------------------------------------------------------------- PPO
def ppo_plan(
    workers: WorkerSet,
    train_batch_size: int = 4000,
    num_sgd_iter: int = 8,
    sgd_minibatch_size: int = 128,
) -> LocalIterator[Dict]:
    """Synchronous sample -> concat -> standardize -> multi-epoch SGD."""
    rollouts = ParallelRollouts(workers, mode="bulk_sync")
    train_op = (
        rollouts.for_each(ConcatBatches(train_batch_size))
        .for_each(StandardizeFields(["advantages"]))
        .for_each(
            TrainOneStep(
                workers,
                num_sgd_iter=num_sgd_iter,
                sgd_minibatch_size=sgd_minibatch_size,
            )
        )
    )
    return StandardMetricsReporting(train_op, workers)


# --------------------------------------------------------------------- DQN
def dqn_plan(
    workers: WorkerSet,
    replay_actors: ActorPool,
    target_update_freq: int = 500,
    store_weight: int = 1,
    replay_weight: int = 1,
) -> LocalIterator[Dict]:
    """Store/replay sub-flows composed round-robin (rate-limited 1:1)."""
    rollouts = ParallelRollouts(workers, mode="bulk_sync")
    store_op = rollouts.for_each(StoreToReplayBuffer(replay_actors))

    # Train on replayed batches, then push new priorities back to the source
    # replay actor (fine-grained message passing).
    train = TrainOneStep(workers)

    def _train_keeping_actor(pair):
        batch, actor = pair
        out = train(batch)  # (batch, info)
        return out, actor

    replay_op = (
        Replay(replay_actors)
        .zip_with_source_actor()
        .for_each(_train_keeping_actor)
        .for_each(UpdateReplayPriorities())
        .for_each(UpdateTargetNetwork(workers, target_update_freq))
    )
    merged = Concurrently(
        [store_op, replay_op],
        mode="round_robin",
        output_indexes=[1],
        round_robin_weights=[store_weight, replay_weight],
    )
    return StandardMetricsReporting(merged, workers)


# -------------------------------------------------------------------- Ape-X
def apex_plan(
    workers: WorkerSet,
    replay_actors: ActorPool,
    target_update_freq: int = 2500,
    max_weight_sync_delay: int = 400,
    num_async_rollouts: int = 2,
    num_async_replay: int = 4,
) -> LocalIterator[Dict]:
    """Listing A3: three concurrent sub-flows around a learner thread."""
    learner = LearnerThread(workers.local_worker())
    learner.start()

    # (1) rollouts -> replay actors; fine-grained weight refresh.
    rollouts = ParallelRollouts(workers, mode="async", num_async=num_async_rollouts)
    store_op = (
        rollouts.for_each(StoreToReplayBuffer(replay_actors))
        .zip_with_source_actor()
        .for_each(UpdateWorkerWeights(workers, max_weight_sync_delay))
    )

    # (2) replayed batches -> learner in-queue.
    replay_op = (
        Replay(replay_actors, num_async=num_async_replay)
        .zip_with_source_actor()
        .for_each(Enqueue(learner.inqueue, block=True))
    )

    # (3) learner out-queue -> priority updates + target sync + metrics.
    def _record(item):
        actor, batch, info = item
        from repro.core.metrics import STEPS_TRAINED_COUNTER, get_metrics

        get_metrics().counters[STEPS_TRAINED_COUNTER] += batch.count
        return ((batch, info), actor)

    update_op = (
        Dequeue(learner.outqueue, check=learner.is_alive)
        .for_each(_record)
        .for_each(UpdateReplayPriorities())
        .for_each(UpdateTargetNetwork(workers, target_update_freq))
    )

    merged = Concurrently(
        [store_op, replay_op, update_op], mode="async", output_indexes=[2]
    )
    it = StandardMetricsReporting(merged, workers)
    it.learner_thread = learner  # exposed so drivers can stop it
    return it


# ------------------------------------------------------------------- IMPALA
def impala_plan(
    workers: WorkerSet,
    train_batch_size: int = 512,
    num_async: int = 2,
    broadcast_interval: int = 1,
) -> LocalIterator[Dict]:
    """Async rollouts -> learner thread -> periodic weight broadcast."""
    learner = LearnerThread(workers.local_worker())
    learner.start()

    rollouts = ParallelRollouts(workers, mode="async", num_async=num_async)
    enqueue_op = rollouts.for_each(ConcatBatches(train_batch_size)).for_each(
        Enqueue(learner.inqueue, block=True)
    )

    state = {"since_broadcast": 0}

    def _broadcast(item):
        _actor, batch, info = item
        from repro.core.metrics import STEPS_TRAINED_COUNTER, get_metrics

        get_metrics().counters[STEPS_TRAINED_COUNTER] += batch.count
        state["since_broadcast"] += 1
        if state["since_broadcast"] >= broadcast_interval and learner.weights_updated:
            learner.weights_updated = False
            state["since_broadcast"] = 0
            workers.sync_weights()
        return batch, info

    update_op = Dequeue(learner.outqueue, check=learner.is_alive).for_each(_broadcast)
    merged = Concurrently([enqueue_op, update_op], mode="async", output_indexes=[1])
    it = StandardMetricsReporting(merged, workers)
    it.learner_thread = learner
    return it


# ---------------------------------------------------------------------- SAC
def sac_plan(
    workers: WorkerSet,
    replay_actors: ActorPool,
    target_update_freq: int = 1,
    store_weight: int = 1,
    replay_weight: int = 1,
) -> LocalIterator[Dict]:
    """Off-policy continuous control: same dataflow shape as DQN."""
    return dqn_plan(
        workers,
        replay_actors,
        target_update_freq=target_update_freq,
        store_weight=store_weight,
        replay_weight=replay_weight,
    )


# --------------------------------------------------------------------- MAML
def maml_plan(workers: WorkerSet, inner_steps: int = 1) -> LocalIterator[Dict]:
    """Figure A2: nested optimization — inner adaptation on workers, meta
    update on the driver, broadcast."""

    def _inner_adaptation(w: Any) -> Any:
        # Pre-adaptation rollouts, inner-loop gradient steps (on the worker's
        # own model ensemble member), post-adaptation rollouts.
        pre = w.sample()
        for _ in range(inner_steps):
            w.inner_adapt(pre)
        post = w.sample()
        return {"pre": pre, "post": post}

    from repro.core.iterators import ParallelIterator

    rollouts = ParallelIterator.from_actors(
        workers.remote_workers(), _inner_adaptation, name="MAMLInner"
    )
    meta = TrainOneStep(workers)

    def _meta_update(items: Sequence[Dict[str, Any]]) -> Any:
        from repro.rl.sample_batch import SampleBatch

        batch = SampleBatch.concat_samples([d["post"] for d in items])
        out = meta(batch)
        # TrainOneStep already broadcast new weights; workers reset inner state.
        for f in workers.remote_workers().broadcast("reset_inner"):
            f.result()
        return out

    train_op = rollouts.batch_across_shards().for_each(_meta_update)
    return StandardMetricsReporting(train_op, workers)


# --------------------------------------------------------------------- APPO
def appo_plan(
    workers: WorkerSet,
    train_batch_size: int = 512,
    num_async: int = 2,
    broadcast_interval: int = 1,
) -> LocalIterator[Dict]:
    """Async PPO (IMPACT/APPO [Luo et al. 2020]): IMPALA's async pipeline
    with a clipped-surrogate learner — same dataflow, different numerics,
    which is exactly the paper's separation of concerns."""
    return impala_plan(
        workers,
        train_batch_size=train_batch_size,
        num_async=num_async,
        broadcast_interval=broadcast_interval,
    )


# --------------------------------------------------------------- MBPO
def mbpo_plan(
    workers: WorkerSet,
    replay_actors: ActorPool,
    model_train_weight: int = 1,
    policy_train_weight: int = 1,
) -> LocalIterator[Dict]:
    """Model-based RL as three concurrent sub-flows (paper §2.2: the pattern
    that 'breaks the mold' of model-free templates):

      (1) real rollouts -> replay buffer
      (2) replayed real batches -> supervised dynamics-model training
      (3) replayed states -> synthetic on-policy rollouts through the
          learned model -> policy TrainOneStep
    """
    lw = workers.local_worker()
    rollouts = ParallelRollouts(workers, mode="bulk_sync")
    store_op = rollouts.for_each(StoreToReplayBuffer(replay_actors))

    model_op = Replay(replay_actors).for_each(lambda b: lw.train_dynamics(b))

    train = TrainOneStep(workers)
    policy_op = (
        Replay(replay_actors)
        .for_each(lambda b: lw.synthesize(b))
        .for_each(train)
    )

    merged = Concurrently(
        [store_op, model_op, policy_op],
        mode="round_robin",
        output_indexes=[2],
        round_robin_weights=[1, model_train_weight, policy_train_weight],
    )
    return StandardMetricsReporting(merged, workers)


# ------------------------------------------------- Multi-agent composition
def multi_agent_ppo_dqn_plan(
    workers: WorkerSet,
    replay_actors: ActorPool,
    ppo_policies: Sequence[str] = ("ppo_policy",),
    dqn_policies: Sequence[str] = ("dqn_policy",),
    ppo_batch_size: int = 1024,
    dqn_target_update_freq: int = 500,
) -> LocalIterator[Dict]:
    """Figure 11/12: one environment, PPO trains some policies, DQN others.

    The rollout stream is duplicated; each branch selects its policies and
    runs its own training dataflow; the union composes them.
    """
    rollouts = ParallelRollouts(workers, mode="bulk_sync")
    ppo_rollouts, dqn_rollouts = rollouts.duplicate(2)

    ppo_op = (
        ppo_rollouts.for_each(SelectExperiences(ppo_policies))
        .for_each(ConcatBatches(ppo_batch_size))
        .for_each(StandardizeFields(["advantages"]))
        .for_each(TrainOneStep(workers, policies=ppo_policies))
    )

    def _select_dqn(batch):
        selected = SelectExperiences(dqn_policies)(batch)
        # Replay stores flat SampleBatches; all dqn policies share the buffer.
        from repro.rl.sample_batch import SampleBatch

        return SampleBatch.concat_samples(list(selected.policy_batches.values()))

    store_op = dqn_rollouts.for_each(_select_dqn).for_each(
        StoreToReplayBuffer(replay_actors)
    )
    train_dqn = TrainOneStep(workers, policies=dqn_policies)

    def _train_keeping_actor(pair):
        batch, actor = pair
        return train_dqn(batch), actor

    dqn_op = (
        Replay(replay_actors)
        .zip_with_source_actor()
        .for_each(_train_keeping_actor)
        .for_each(UpdateReplayPriorities())
        .for_each(UpdateTargetNetwork(workers, dqn_target_update_freq))
    )

    merged = Concurrently(
        [ppo_op, store_op, dqn_op], mode="round_robin", output_indexes=[0, 2]
    )
    return StandardMetricsReporting(merged, workers)
