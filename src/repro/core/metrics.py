"""Shared metrics context that travels with a dataflow.

The paper routes training statistics through the dataflow itself
(``ReportMetrics``); operator-internal bookkeeping (counters such as
``num_steps_sampled``, timers such as ``apply_timer``) lives in a *shared
metrics context* attached to the local iterator — the same design RLlib Flow
uses so that operators stay pure item transforms while still being observable.
"""

from __future__ import annotations

import threading
import time
from collections import defaultdict
from typing import Any, Dict, Optional

__all__ = [
    "TimerStat",
    "LatencyStat",
    "MetricsContext",
    "get_metrics",
    "set_metrics_for_thread",
    "payload_nbytes",
]

# Canonical counter names used by the built-in operators (mirrors RLlib Flow).
STEPS_SAMPLED_COUNTER = "num_steps_sampled"
STEPS_TRAINED_COUNTER = "num_steps_trained"
AGENT_STEPS_SAMPLED_COUNTER = "num_agent_steps_sampled"
TARGET_NET_UPDATES = "num_target_updates"

# Fault-tolerance counters (executor runtime, ISSUE 2): recorded by the
# gather operators / Enqueue so failures surface in Algorithm.train() results.
NUM_SAMPLES_DROPPED = "num_samples_dropped"
NUM_WORKER_FAILURES = "num_worker_failures"
NUM_SHARDS_DROPPED = "num_shards_dropped"

# Data-plane accounting (ISSUE 3): recorded by the gather operators, the
# queue operators (Enqueue/Dequeue), and the learner thread.  Per-operator
# breakdowns use the ``<name>/<operator-key>`` convention (the flow compiler
# keys them by node id so ``to_dot`` can label edges).
NUM_BYTES_MOVED = "num_bytes_moved"
NUM_CREDIT_STALLS = "num_credit_stalls"
CREDIT_STALL_TIME = "credit_stall_time_s"
BYTES_MOVED_PREFIX = "bytes_moved/"
QUEUE_OCCUPANCY_PREFIX = "queue_occupancy/"
INFLIGHT_PREFIX = "inflight/"
# Per-round wall time of a sync gather (dispatch -> barrier -> gathered),
# keyed by node id — the live wall-time column Algorithm.explain() joins
# for source nodes.
GATHER_TIMER_PREFIX = "gather/"

# Latency streams (LatencyStat reservoirs; p50/p99 surfaced by save()).
SAMPLE_TO_LEARN_LATENCY = "sample_to_learn_s"
LEARNER_QUEUE_WAIT = "learner_queue_wait_s"

SAMPLE_TIMER = "sample"
GRAD_WAIT_TIMER = "grad_wait"
APPLY_GRADS_TIMER = "apply_grad"
LEARN_ON_BATCH_TIMER = "learn"
UPDATE_PRIORITIES_TIMER = "update_priorities"


def payload_nbytes(item: Any, _depth: int = 0) -> int:
    """Best-effort byte size of a dataflow item (SampleBatch-aware).

    Counts numpy-backed payloads (``size_bytes()`` / ``nbytes``) through one
    level of tuple/list/dict nesting — enough for every wire shape the
    operators produce ((batch, actor), (grads, info), [batch, ...]).
    """
    if item is None or _depth > 2:
        return 0
    size_fn = getattr(item, "size_bytes", None)
    if callable(size_fn):
        try:
            return int(size_fn())
        except Exception:
            return 0
    nbytes = getattr(item, "nbytes", None)
    if isinstance(nbytes, int):
        return nbytes
    if isinstance(item, (tuple, list)):
        return sum(payload_nbytes(x, _depth + 1) for x in item)
    if isinstance(item, dict):
        return sum(payload_nbytes(x, _depth + 1) for x in item.values())
    batches = getattr(item, "policy_batches", None)  # MultiAgentBatch
    if isinstance(batches, dict):
        return sum(payload_nbytes(x, _depth + 1) for x in batches.values())
    return 0


class TimerStat:
    """EWMA + total timer, context-manager style (paper Listing A2)."""

    def __init__(self, window: int = 100):
        self._window = window
        self.count = 0
        self.total = 0.0
        self.mean = 0.0
        self.units = 0.0
        self._start: Optional[float] = None

    def __enter__(self) -> "TimerStat":
        self._start = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        assert self._start is not None
        self.push(time.perf_counter() - self._start)
        self._start = None

    def push(self, dt: float) -> None:
        self.count += 1
        self.total += dt
        alpha = 2.0 / (min(self.count, self._window) + 1)
        self.mean = dt if self.count == 1 else (1 - alpha) * self.mean + alpha * dt

    def push_units_processed(self, n: float) -> None:
        self.units += n

    @property
    def mean_throughput(self) -> float:
        return self.units / self.total if self.total else 0.0


class LatencyStat:
    """Sliding-window latency reservoir with percentile summaries.

    A fixed ring of the last ``window`` observations: pushes are O(1) and
    lock-free (single-writer per stream in practice; racy reads only smear
    the percentile by one sample), ``summary()`` computes p50/p99 on a copy.
    """

    def __init__(self, window: int = 512):
        self._window = window
        self._ring = [0.0] * window
        self.count = 0
        self.total = 0.0

    def push(self, dt: float) -> None:
        self._ring[self.count % self._window] = dt
        self.count += 1
        self.total += dt

    def _values(self) -> list:
        n = min(self.count, self._window)
        return list(self._ring[:n])

    @staticmethod
    def _pct(sorted_vals: list, p: float) -> float:
        if not sorted_vals:
            return 0.0
        idx = min(len(sorted_vals) - 1, max(0, int(round((p / 100.0) * (len(sorted_vals) - 1)))))
        return sorted_vals[idx]

    def percentile(self, p: float) -> float:
        return self._pct(sorted(self._values()), p)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> Dict[str, float]:
        vals = sorted(self._values())
        return {
            "count": self.count,
            "mean": self.mean,
            "p50": self._pct(vals, 50.0),
            "p99": self._pct(vals, 99.0),
        }


class MetricsContext:
    """Counters/timers/info shared by all operators of one dataflow.

    ``current_actor`` is set by gather operators while an item produced by a
    given source actor is in flight — this is what ``zip_with_source_actor``
    and fine-grained message passing (e.g. Ape-X per-worker weight updates)
    read.
    """

    def __init__(self) -> None:
        self.counters: Dict[str, int] = defaultdict(int)
        self.timers: Dict[str, TimerStat] = defaultdict(TimerStat)
        self.latencies: Dict[str, LatencyStat] = defaultdict(LatencyStat)
        self.gauges: Dict[str, float] = {}
        self.info: Dict[str, Any] = {}
        self.current_actor: Any = None
        self._lock = threading.Lock()
        # Pull-based publishers (ISSUE 9): subsystems that keep their own
        # counters (the inference router, external pools) register a probe
        # ``fn(ctx)`` that writes into this context; ``save()`` runs them
        # first, so serving gauges land in every train() result without the
        # subsystem pushing on its own hot path.
        self._probes: list = []

    def register_probe(self, probe: Any) -> None:
        with self._lock:
            self._probes.append(probe)

    def unregister_probe(self, probe: Any) -> None:
        with self._lock:
            if probe in self._probes:
                self._probes.remove(probe)

    def run_probes(self) -> None:
        with self._lock:
            probes = list(self._probes)
        for probe in probes:
            try:
                probe(self)
            except Exception:  # a dead publisher must not break reporting
                pass

    @staticmethod
    def _racefree_copy(d: Dict) -> Dict:
        """Copy a dict that other (driver) threads may be inserting into.

        Concurrently/union driver threads insert first-time counter/timer
        keys without locking; a plain ``dict()`` copy can then raise
        "dictionary changed size during iteration".  Retry — key insertion
        is rare (values mutating mid-copy is fine)."""
        for _ in range(1000):
            try:
                return dict(d)
            except RuntimeError:
                continue
        return dict(d)  # pragma: no cover - pathological contention

    def snapshot_counters(self) -> Dict[str, int]:
        return self._racefree_copy(self.counters)

    def save(self) -> Dict[str, Any]:
        self.run_probes()
        return {
            "counters": self.snapshot_counters(),
            "info": self._racefree_copy(self.info),
            "timers": {
                k: {"mean": v.mean, "count": v.count, "throughput": v.mean_throughput}
                for k, v in self._racefree_copy(self.timers).items()
            },
            "gauges": self._racefree_copy(self.gauges),
            "latencies": {
                k: v.summary() for k, v in self._racefree_copy(self.latencies).items()
            },
        }


# Thread-local pointer to the metrics context of the dataflow currently being
# driven on this thread (gather operators install it before running stages).
_local = threading.local()


def get_metrics() -> MetricsContext:
    ctx = getattr(_local, "metrics", None)
    if ctx is None:
        ctx = MetricsContext()
        _local.metrics = ctx
    return ctx


def set_metrics_for_thread(ctx: Optional[MetricsContext]) -> None:
    _local.metrics = ctx
