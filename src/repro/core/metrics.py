"""Shared metrics context that travels with a dataflow.

The paper routes training statistics through the dataflow itself
(``ReportMetrics``); operator-internal bookkeeping (counters such as
``num_steps_sampled``, timers such as ``apply_timer``) lives in a *shared
metrics context* attached to the local iterator — the same design RLlib Flow
uses so that operators stay pure item transforms while still being observable.
"""

from __future__ import annotations

import threading
import time
from collections import defaultdict
from typing import Any, Dict, Optional

__all__ = ["TimerStat", "MetricsContext", "get_metrics", "set_metrics_for_thread"]

# Canonical counter names used by the built-in operators (mirrors RLlib Flow).
STEPS_SAMPLED_COUNTER = "num_steps_sampled"
STEPS_TRAINED_COUNTER = "num_steps_trained"
AGENT_STEPS_SAMPLED_COUNTER = "num_agent_steps_sampled"
TARGET_NET_UPDATES = "num_target_updates"

# Fault-tolerance counters (executor runtime, ISSUE 2): recorded by the
# gather operators / Enqueue so failures surface in Algorithm.train() results.
NUM_SAMPLES_DROPPED = "num_samples_dropped"
NUM_WORKER_FAILURES = "num_worker_failures"
NUM_SHARDS_DROPPED = "num_shards_dropped"

SAMPLE_TIMER = "sample"
GRAD_WAIT_TIMER = "grad_wait"
APPLY_GRADS_TIMER = "apply_grad"
LEARN_ON_BATCH_TIMER = "learn"
UPDATE_PRIORITIES_TIMER = "update_priorities"


class TimerStat:
    """EWMA + total timer, context-manager style (paper Listing A2)."""

    def __init__(self, window: int = 100):
        self._window = window
        self.count = 0
        self.total = 0.0
        self.mean = 0.0
        self.units = 0.0
        self._start: Optional[float] = None

    def __enter__(self) -> "TimerStat":
        self._start = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        assert self._start is not None
        self.push(time.perf_counter() - self._start)
        self._start = None

    def push(self, dt: float) -> None:
        self.count += 1
        self.total += dt
        alpha = 2.0 / (min(self.count, self._window) + 1)
        self.mean = dt if self.count == 1 else (1 - alpha) * self.mean + alpha * dt

    def push_units_processed(self, n: float) -> None:
        self.units += n

    @property
    def mean_throughput(self) -> float:
        return self.units / self.total if self.total else 0.0


class MetricsContext:
    """Counters/timers/info shared by all operators of one dataflow.

    ``current_actor`` is set by gather operators while an item produced by a
    given source actor is in flight — this is what ``zip_with_source_actor``
    and fine-grained message passing (e.g. Ape-X per-worker weight updates)
    read.
    """

    def __init__(self) -> None:
        self.counters: Dict[str, int] = defaultdict(int)
        self.timers: Dict[str, TimerStat] = defaultdict(TimerStat)
        self.info: Dict[str, Any] = {}
        self.current_actor: Any = None
        self._lock = threading.Lock()

    @staticmethod
    def _racefree_copy(d: Dict) -> Dict:
        """Copy a dict that other (driver) threads may be inserting into.

        Concurrently/union driver threads insert first-time counter/timer
        keys without locking; a plain ``dict()`` copy can then raise
        "dictionary changed size during iteration".  Retry — key insertion
        is rare (values mutating mid-copy is fine)."""
        for _ in range(1000):
            try:
                return dict(d)
            except RuntimeError:
                continue
        return dict(d)  # pragma: no cover - pathological contention

    def snapshot_counters(self) -> Dict[str, int]:
        return self._racefree_copy(self.counters)

    def save(self) -> Dict[str, Any]:
        return {
            "counters": self.snapshot_counters(),
            "info": self._racefree_copy(self.info),
            "timers": {
                k: {"mean": v.mean, "count": v.count, "throughput": v.mean_throughput}
                for k, v in self._racefree_copy(self.timers).items()
            },
        }


# Thread-local pointer to the metrics context of the dataflow currently being
# driven on this thread (gather operators install it before running stages).
_local = threading.local()


def get_metrics() -> MetricsContext:
    ctx = getattr(_local, "metrics", None)
    if ctx is None:
        ctx = MetricsContext()
        _local.metrics = ctx
    return ctx


def set_metrics_for_thread(ctx: Optional[MetricsContext]) -> None:
    _local.metrics = ctx
