"""Pure-JAX optimizers (optax is not available offline).

Functional, pytree-based, shardable: ``init(params) -> state`` and
``update(grads, state, params) -> (updates, state)``; apply with
``params + updates``.  States mirror param pytree structure so the same
NamedSharding rules apply (FSDP over the data axis, see distributed/sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]

__all__ = [
    "Optimizer",
    "sgd",
    "adam",
    "adamw",
    "chain_clip_by_global_norm",
    "constant_schedule",
    "cosine_schedule",
    "linear_warmup_cosine",
]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]]

    def apply(self, params: PyTree, grads: PyTree, state: PyTree) -> Tuple[PyTree, PyTree]:
        updates, state = self.update(grads, state, params)
        params = jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)
        return params, state


# ------------------------------------------------------------------ schedules
def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1) -> Schedule:
    def f(step: jnp.ndarray) -> jnp.ndarray:
        t = jnp.minimum(step / total_steps, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)

    return f


def linear_warmup_cosine(lr: float, warmup: int, total_steps: int) -> Schedule:
    cos = cosine_schedule(lr, max(total_steps - warmup, 1))

    def f(step: jnp.ndarray) -> jnp.ndarray:
        warm = lr * (step + 1) / max(warmup, 1)
        return jnp.where(step < warmup, warm, cos(step - warmup))

    return f


def _as_schedule(lr: Any) -> Schedule:
    return lr if callable(lr) else constant_schedule(lr)


# ----------------------------------------------------------------- optimizers
class SgdState(NamedTuple):
    step: jnp.ndarray
    momentum: Optional[PyTree]


def sgd(lr: Any, momentum: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params: PyTree) -> SgdState:
        mom = (
            jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
            if momentum
            else None
        )
        return SgdState(jnp.zeros((), jnp.int32), mom)

    def update(grads: PyTree, state: SgdState, params: PyTree):
        lr_t = sched(state.step)
        if momentum:
            new_mom = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state.momentum, grads
            )
            updates = jax.tree_util.tree_map(lambda m: -lr_t * m, new_mom)
            return updates, SgdState(state.step + 1, new_mom)
        updates = jax.tree_util.tree_map(lambda g: -lr_t * g.astype(jnp.float32), grads)
        return updates, SgdState(state.step + 1, None)

    return Optimizer(init, update)


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: PyTree
    nu: PyTree


def adam(
    lr: Any, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.0
) -> Optimizer:
    """Adam / AdamW. Moments kept in fp32 regardless of param dtype."""
    sched = _as_schedule(lr)

    def init(params: PyTree) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(
            jnp.zeros((), jnp.int32),
            jax.tree_util.tree_map(zeros, params),
            jax.tree_util.tree_map(zeros, params),
        )

    def update(grads: PyTree, state: AdamState, params: PyTree):
        step = state.step + 1
        lr_t = sched(state.step)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        mu_hat_scale = 1.0 / (1 - b1 ** step.astype(jnp.float32))
        nu_hat_scale = 1.0 / (1 - b2 ** step.astype(jnp.float32))

        def _upd(m, v, p):
            u = -lr_t * (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree_util.tree_map(_upd, mu, nu, params)
        return updates, AdamState(step, mu, nu)

    return Optimizer(init, update)


def adamw(lr: Any, weight_decay: float = 0.01, **kw: Any) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def chain_clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    """Wrap an optimizer with global-norm gradient clipping."""

    def update(grads: PyTree, state: PyTree, params: PyTree):
        leaves = jax.tree_util.tree_leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
        scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        return opt.update(grads, state, params)

    return Optimizer(opt.init, update)
