from repro.optim.optimizers import (
    Optimizer,
    adam,
    adamw,
    chain_clip_by_global_norm,
    constant_schedule,
    cosine_schedule,
    linear_warmup_cosine,
    sgd,
)

__all__ = [
    "Optimizer",
    "adam",
    "adamw",
    "sgd",
    "chain_clip_by_global_norm",
    "cosine_schedule",
    "constant_schedule",
    "linear_warmup_cosine",
]
