"""State-space / recurrent layers: RWKV6 "Finch" time-mix and Mamba.

Both expose a sequence path (training/prefill) and an O(1)-state decode step;
the decode state is carried exactly like env state in a rollout actor
(DESIGN.md §4: model-state-as-actor-state).
"""

from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import shard
from repro.models.layers import dense_init, rms_norm

PyTree = Any

__all__ = [
    "rwkv6_init",
    "rwkv6_apply",
    "rwkv6_decode",
    "init_rwkv6_state",
    "mamba_init",
    "mamba_apply",
    "mamba_decode",
    "init_mamba_state",
]


# =========================================================== RWKV6 (Finch)
def rwkv6_init(key: jax.Array, cfg: ModelConfig) -> PyTree:
    s = cfg.ssm
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    H = d // s.head_dim
    ks = jax.random.split(key, 9)
    p = {
        "wr": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wg": dense_init(ks[3], d, d, dtype),
        "wo": dense_init(ks[4], d, d, dtype),
        # Data-dependent decay (Finch): w_t = exp(-exp(w0 + tanh(x w1) w2))
        "decay_w1": dense_init(ks[5], d, 64, dtype),
        "decay_w2": dense_init(ks[6], 64, d, dtype, scale=0.1),
        "decay_w0": jnp.full((d,), -2.0, dtype),
        "bonus_u": (jax.random.normal(ks[7], (H, s.head_dim), jnp.float32) * 0.1).astype(dtype),
        # token-shift mix coefficients per stream
        "mix": (jax.random.uniform(ks[8], (5, d), jnp.float32) * 0.5 + 0.25).astype(dtype),
        "ln_out": jnp.ones((d,), dtype),
    }
    return p


def _rwkv6_streams(params: PyTree, x: jax.Array, x_prev: jax.Array, cfg: ModelConfig):
    """Token-shift + projections. x: [B,T,d]; x_prev: [B,T,d] (shifted)."""
    s = cfg.ssm
    H = cfg.d_model // s.head_dim
    B, T, d = x.shape

    def mixed(i: int) -> jax.Array:
        mu = params["mix"][i]
        return x * mu + x_prev * (1 - mu)

    r = mixed(0) @ params["wr"]
    k = mixed(1) @ params["wk"]
    v = mixed(2) @ params["wv"]
    g = jax.nn.silu(mixed(3) @ params["wg"])
    dd = jnp.tanh(mixed(4) @ params["decay_w1"]) @ params["decay_w2"]
    log_w = -jnp.exp(
        jnp.clip((params["decay_w0"] + dd).astype(jnp.float32), -8.0, 2.0)
    )  # <= 0
    w = jnp.exp(log_w)  # decay in (0, 1]
    hs = lambda z: z.reshape(B, T, H, s.head_dim)
    return hs(r), hs(k), hs(v), g, hs(w.astype(x.dtype))


def rwkv6_apply(
    params: PyTree, x: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """Sequence path. x: [B, T, d] -> [B, T, d]."""
    from repro.kernels import ops as kops

    B, T, d = x.shape
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, w = _rwkv6_streams(params, x, x_prev, cfg)
    out, _ = kops.rwkv6(r, k, v, w, params["bonus_u"].astype(jnp.float32), chunk=cfg.ssm.chunk)
    out = out.reshape(B, T, d)
    out = rms_norm(out, params["ln_out"], cfg.norm_eps) * g
    out = out @ params["wo"]
    return shard(out, "batch", None, None)


def init_rwkv6_state(cfg: ModelConfig, batch: int) -> PyTree:
    s = cfg.ssm
    H = cfg.d_model // s.head_dim
    return {
        "wkv": jnp.zeros((batch, H, s.head_dim, s.head_dim), jnp.float32),
        "x_prev": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype)),
    }


def rwkv6_decode(
    params: PyTree, x: jax.Array, state: PyTree, cfg: ModelConfig
) -> Tuple[jax.Array, PyTree]:
    """One-token decode. x: [B,1,d]."""
    B = x.shape[0]
    d = cfg.d_model
    s = cfg.ssm
    x_prev = state["x_prev"][:, None, :]
    r, k, v, g, w = _rwkv6_streams(params, x, x_prev, cfg)
    r1, k1, v1, w1 = (z[:, 0].astype(jnp.float32) for z in (r, k, v, w))
    u = params["bonus_u"].astype(jnp.float32)
    S = state["wkv"]
    kv = k1[..., :, None] * v1[..., None, :]
    o = jnp.einsum("bhn,bhnm->bhm", r1, S + u[None, :, :, None] * kv)
    S = w1[..., :, None] * S + kv
    out = o.reshape(B, 1, d).astype(x.dtype)
    out = rms_norm(out, params["ln_out"], cfg.norm_eps) * g
    out = out @ params["wo"]
    return shard(out, "batch", None, None), {"wkv": S, "x_prev": x[:, 0]}


# ================================================================== Mamba
def _causal_conv(xc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time as stack+einsum.

    Expressed as dot_general (not slice+mul+sum) so XLA does not pattern-match
    a grouped convolution — GSPMD's conv partitioning replicates the batch
    dim for this shape, blowing device memory.
    xc: [B, T, d_in]; w: [K, d_in]; b: [d_in].
    """
    K = w.shape[0]
    T = xc.shape[1]
    pad = jnp.pad(xc, ((0, 0), (K - 1, 0), (0, 0)))
    stacked = jnp.stack([pad[:, i : i + T] for i in range(K)], axis=-1)  # [B,T,d,K]
    return jnp.einsum("btdk,kd->btd", stacked, w) + b


def mamba_init(key: jax.Array, cfg: ModelConfig) -> PyTree:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A.
    a = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :], (d_in, 1))
    p = {
        "in_proj": dense_init(ks[0], d, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, d_in), jnp.float32) / math.sqrt(s.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], d_in, 2 * s.d_state + 1, dtype),  # -> B, C, dt
        "dt_bias": jnp.full((d_in,), -4.0, dtype),  # softplus(-4) ~ small dt
        "dt_proj": dense_init(ks[3], 1, d_in, dtype),
        "A_log": jnp.log(a).astype(jnp.float32),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], d_in, d, dtype),
    }
    return p


def _mamba_scan(params: PyTree, xc: jax.Array, h0: jax.Array, s) -> Tuple[jax.Array, jax.Array]:
    """Selective scan. xc: [B,T,d_in] (post conv+silu); h0: [B,d_in,N]."""
    A = -jnp.exp(params["A_log"])  # [d_in, N]
    proj = xc @ params["x_proj"]  # [B,T,2N+1]
    Bp, Cp, dt_in = proj[..., : s.d_state], proj[..., s.d_state : 2 * s.d_state], proj[..., -1:]
    dt = jax.nn.softplus(dt_in @ params["dt_proj"] + params["dt_bias"])  # [B,T,d_in]

    def step(h, inp):
        # xs stay in model dtype (halves residual memory); math in fp32.
        x_t, b_t, c_t, dt_t = (z.astype(jnp.float32) for z in inp)
        dA = jnp.exp(dt_t[..., None] * A[None])  # [B,d_in,N]
        dBx = dt_t[..., None] * b_t[:, None, :] * x_t[..., None]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y.astype(inp[0].dtype)

    from repro.models.scan_utils import chunked_scan

    tm = lambda z: z.swapaxes(0, 1)
    h, ys = chunked_scan(step, h0, (tm(xc), tm(Bp), tm(Cp), tm(dt)), chunk=128)
    y = ys.swapaxes(0, 1).astype(jnp.float32) + xc.astype(jnp.float32) * params["D"]
    return y.astype(xc.dtype), h


def mamba_apply(params: PyTree, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Sequence path. x: [B,T,d]."""
    s = cfg.ssm
    B, T, d = x.shape
    d_in = s.expand * d
    xz = x @ params["in_proj"]
    xc, z = xz[..., :d_in], xz[..., d_in:]
    xc = shard(xc, "batch", None, "d_ff")
    xc = jax.nn.silu(_causal_conv(xc, params["conv_w"], params["conv_b"]))
    h0 = jnp.zeros((B, d_in, s.d_state), jnp.float32)
    y, _ = _mamba_scan(params, xc, h0, s)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return shard(out, "batch", None, None)


def init_mamba_state(cfg: ModelConfig, batch: int) -> PyTree:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, d_in, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, d_in), jnp.dtype(cfg.dtype)),
    }


def mamba_decode(
    params: PyTree, x: jax.Array, state: PyTree, cfg: ModelConfig
) -> Tuple[jax.Array, PyTree]:
    """One-token decode. x: [B,1,d]."""
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    xz = x @ params["in_proj"]
    xc, z = xz[..., :d_in], xz[..., d_in:]
    window = jnp.concatenate([state["conv"], xc], axis=1)  # [B, d_conv, d_in]
    conv = jnp.einsum("bkd,kd->bd", window, params["conv_w"]) + params["conv_b"]
    xc1 = jax.nn.silu(conv)[:, None, :]  # [B,1,d_in]
    y, h = _mamba_scan(params, xc1, state["h"], s)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return shard(out, "batch", None, None), {"h": h, "conv": window[:, 1:]}
