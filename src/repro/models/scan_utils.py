"""Chunked, remat-friendly time scans.

A plain ``lax.scan`` over T steps saves its carry (and per-step saveable
intermediates) for every step on the backward pass — for SSM layers that is
O(T x state) residual memory.  ``chunked_scan`` nests two scans: the outer
saves one carry per chunk, the inner is wrapped in ``jax.checkpoint`` so its
steps are recomputed during backward.  Residual memory drops by ~chunk x at
the cost of one extra forward over the sequence (standard remat trade).
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax

PyTree = Any

__all__ = ["chunked_scan"]


def chunked_scan(
    step: Callable[[PyTree, PyTree], Tuple[PyTree, PyTree]],
    carry: PyTree,
    xs: PyTree,
    chunk: int = 128,
    remat: bool = True,
) -> Tuple[PyTree, PyTree]:
    leaves = jax.tree_util.tree_leaves(xs)
    T = leaves[0].shape[0]
    if chunk <= 1 or T % chunk or T <= chunk:
        return jax.lax.scan(step, carry, xs)
    n = T // chunk
    xs_c = jax.tree_util.tree_map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), xs
    )

    def inner(c: PyTree, xc: PyTree):
        return jax.lax.scan(step, c, xc)

    if remat:
        inner = jax.checkpoint(inner)

    carry, ys_c = jax.lax.scan(inner, carry, xs_c)
    ys = jax.tree_util.tree_map(lambda a: a.reshape((T,) + a.shape[2:]), ys_c)
    return carry, ys
