from repro.models.transformer import (
    Model,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

__all__ = ["Model", "make_train_step", "make_prefill_step", "make_decode_step"]
