"""Mixture-of-Experts layer: top-k routing with per-row sort-based dispatch.

TPU-native adaptation (DESIGN.md §5): instead of the one-hot dispatch einsum
(whose FLOPs scale with num_experts x capacity and dwarf the expert compute),
each batch row sorts its tokens by expert id and scatters them into a dense
[B, E, C, d] buffer (gather/scatter = bytes, not FLOPs).  Keeping the batch
dim leading means routing/sort/scatter are *local to each data shard*; the
only cross-device movement is resharding the dispatch buffer from
batch-sharded to (batch, experts)-sharded — the expert-parallel all-to-all —
which XLA SPMD emits from the sharding constraints.  Expert FFN FLOPs are
~= tokens * top_k * capacity_factor * per-expert cost, i.e. the real MoE
compute.  Tokens over per-row capacity are dropped (capacity-factor
semantics); shared experts (DeepSeek) run densely.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import shard
from repro.kernels import ops
from repro.models.layers import dense_init

PyTree = Any

__all__ = ["moe_init", "moe_apply"]


# --------------------------------------------------------------------------
# Permutation gathers with gather-only VJPs (§Perf iteration B2).
#
# jax's autodiff turns take_along_axis backward into scatter-add, which XLA
# SPMD lowers as partial-scatter + f32 all-reduce over the model axis
# (~80 GB/device/step on deepseek).  Our routing indices are bijections on
# kept slots, so the cotangent is itself a gather — expressed explicitly via
# custom_vjp below, the whole MoE fwd+bwd is scatter-free.
# --------------------------------------------------------------------------
@jax.custom_vjp
def _permute_rows(x, idx, inv_idx, mask_fwd, mask_bwd):
    """y[b,i] = x[b, idx[b,i]] * mask_fwd[b,i]; idx a (masked) bijection."""
    return jnp.take_along_axis(x, idx[..., None], axis=1) * mask_fwd[..., None].astype(x.dtype)


def _permute_rows_fwd(x, idx, inv_idx, mask_fwd, mask_bwd):
    return _permute_rows(x, idx, inv_idx, mask_fwd, mask_bwd), (
        idx, inv_idx, mask_fwd, mask_bwd, x.shape,
    )


def _permute_rows_bwd(res, dy):
    idx, inv_idx, mask_fwd, mask_bwd, xshape = res
    dx = jnp.take_along_axis(
        dy * mask_fwd[..., None].astype(dy.dtype), inv_idx[..., None], axis=1
    ) * mask_bwd[..., None].astype(dy.dtype)
    return dx, None, None, None, None


_permute_rows.defvjp(_permute_rows_fwd, _permute_rows_bwd)


@jax.custom_vjp
def _replicate_rows(x, st, inv, k):
    """y[b,i] = x[b, st[b,i]] where each source row appears exactly k times;
    backward sums the k cotangent copies via gather (no scatter)."""
    return jnp.take_along_axis(x, st[..., None], axis=1)


def _replicate_rows_fwd(x, st, inv, k):
    return _replicate_rows(x, st, inv, k), (st, inv, k, x.shape)


def _replicate_rows_bwd(res, dy):
    st, inv, k, xshape = res
    B, S, d = xshape
    picked = jnp.take_along_axis(dy, inv[..., None], axis=1)  # [B, S*k, d]
    dx = jnp.sum(picked.reshape(B, S, k, d), axis=2)
    return dx, None, None, None


_replicate_rows.defvjp(_replicate_rows_fwd, _replicate_rows_bwd)


def moe_init(key: jax.Array, cfg: ModelConfig) -> PyTree:
    assert cfg.moe is not None
    e = cfg.moe
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)

    def experts_mat(k, din, dout):
        return (
            jax.random.normal(k, (e.num_experts, din, dout), jnp.float32) / math.sqrt(din)
        ).astype(dtype)

    p: Dict[str, Any] = {
        "router": dense_init(ks[0], d, e.num_experts, dtype),
        "up": experts_mat(ks[1], d, e.d_ff),
        "down": experts_mat(ks[2], e.d_ff, d),
    }
    gated = cfg.activation == "silu"
    if gated:
        p["gate"] = experts_mat(ks[3], d, e.d_ff)
    if e.num_shared:
        shared_ff = e.d_ff * e.num_shared
        p["shared_up"] = dense_init(ks[4], d, shared_ff, dtype)
        p["shared_down"] = dense_init(ks[5], shared_ff, d, dtype)
        if gated:
            p["shared_gate"] = dense_init(ks[6], d, shared_ff, dtype)
    return p


# --------------------------------------------------------------------------
# Expert matmuls, routed through the grouped-matmul kernel.
#
# The [B, E, C, d] dispatch buffer *is* a grouped-rows layout: transposing to
# [E, B*C, d] makes every expert's tokens contiguous with a static group size
# of B*C rows, exactly what ``ops.moe_gmm`` (MegaBlocks-style Pallas kernel,
# scalar-prefetch expert ids) consumes.  ``pallas_call`` has no transpose
# rule, so the routed op carries a custom_vjp whose backward is the two
# batched einsums of the dense path — gradients are identical to the einsum
# the kernel replaces.
# --------------------------------------------------------------------------
@jax.custom_vjp
def _gmm_matmul(xe, w):
    """[B, E, C, K] x [E, K, N] -> [B, E, C, N] via ``ops.moe_gmm``."""
    B, E, C, K = xe.shape
    xg = xe.transpose(1, 0, 2, 3).reshape(E * B * C, K)
    groups = jnp.full((E,), B * C, jnp.int32)
    # Row tiles may not straddle an expert boundary: block_m must divide the
    # per-expert group of B*C rows (the _gmm_ok gate guarantees it can).
    block_m = B * C if B * C <= 128 else 128
    out = ops.moe_gmm(xg, w, groups, block_m=block_m)
    return out.reshape(E, B, C, w.shape[-1]).transpose(1, 0, 2, 3).astype(xe.dtype)


def _gmm_matmul_fwd(xe, w):
    return _gmm_matmul(xe, w), (xe, w)


def _gmm_matmul_bwd(res, dy):
    xe, w = res
    dxe = jnp.einsum("becn,ekn->beck", dy, w).astype(xe.dtype)
    dw = jnp.einsum("beck,becn->ekn", xe, dy).astype(w.dtype)
    return dxe, dw


_gmm_matmul.defvjp(_gmm_matmul_fwd, _gmm_matmul_bwd)


def _gmm_ok(xe: jax.Array, w: jax.Array) -> bool:
    """Kernel tiling gate: the per-expert group of B*C rows must be
    tileable by a block_m that never straddles an expert boundary (B*C
    itself when small, else 128 | B*C), and the output columns by
    ``min(128, N)``; otherwise the dense einsum stays."""
    B, E, C, _ = xe.shape
    group, cols = B * C, w.shape[-1]
    rows_ok = group <= 128 or group % 128 == 0
    cols_ok = cols % min(128, max(cols, 1)) == 0
    return ops.use_pallas() and rows_ok and cols_ok


def _expert_mm(xe: jax.Array, w: jax.Array) -> jax.Array:
    if _gmm_ok(xe, w):
        return _gmm_matmul(xe, w)
    return jnp.einsum("beck,ekn->becn", xe, w)


def _expert_ffn(p: PyTree, xe: jax.Array, cfg: ModelConfig) -> jax.Array:
    """xe: [B, E, C, d] -> [B, E, C, d] via per-expert (gated) FFN."""
    h = _expert_mm(xe, p["up"])
    h = shard(h, "batch", "experts", None, None)
    if cfg.activation == "silu":
        g = _expert_mm(xe, p["gate"])
        h = jax.nn.silu(g) * h
    elif cfg.activation == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    out = _expert_mm(h, p["down"])
    return shard(out, "batch", "experts", None, None)


def moe_apply(
    params: PyTree, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], router aux loss scalar)."""
    e = cfg.moe
    B, S, d = x.shape
    k = e.top_k
    E = e.num_experts
    Sk = S * k

    # ------------------------------------------------------------- routing
    logits = (x @ params["router"]).astype(jnp.float32)  # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # [B, S, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # Router math stays f32; combine WEIGHTS drop to model dtype here so the
    # dispatch/combine cotangent chain stays bf16 (f32 cotangents double the
    # expert-parallel gather bytes; §Perf iteration B3).
    top_p = top_p.astype(x.dtype)

    # Load-balance auxiliary loss (Switch-style).
    density = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=2), axis=(0, 1)
    )
    aux = e.router_aux_coef * E * jnp.mean(density * jnp.mean(probs, axis=(0, 1)))

    # --------------------------------------- per-row sort-based dispatch
    # All index math has a leading batch dim, so it stays local to each data
    # shard; capacity is per row (what per-device capacity means in practice).
    C = max(1, int(math.ceil(e.capacity_factor * S * k / E)))
    flat_e = top_e.reshape(B, Sk)
    flat_t = jnp.broadcast_to(jnp.arange(S)[:, None], (S, k)).reshape(Sk)
    flat_w = top_p.reshape(B, Sk)

    # Every dispatch intermediate is explicitly batch-sharded: GSPMD's
    # gather/scatter propagation otherwise falls back to replication, which
    # materializes global-batch buffers on every device.
    order = jnp.argsort(flat_e, axis=-1)  # [B, Sk] stable per row
    se = shard(jnp.take_along_axis(flat_e, order, axis=-1), "batch", None)
    st = shard(flat_t[order], "batch", None)  # token index per sorted slot
    sw = shard(jnp.take_along_axis(flat_w, order, axis=-1), "batch", None)
    # rank within expert, per row
    counts = jnp.sum(
        jax.nn.one_hot(se, E, dtype=jnp.int32), axis=1
    )  # [B, E]
    starts = jnp.cumsum(counts, axis=-1) - counts  # [B, E]
    pos = jnp.arange(Sk)[None, :] - jnp.take_along_axis(starts, se, axis=-1)
    keep = pos < C
    slot = shard(se * C + jnp.minimum(pos, C - 1), "batch", None)  # drops -> C-1

    bidx = jnp.arange(B)[:, None]
    if e.dispatch == "gather":
        # Gather-based dispatch: after the per-row sort, expert e's kept
        # tokens occupy sorted positions starts[e] .. starts[e]+C-1, so the
        # [B, E*C] buffer is a pure gather — no scatter in the forward pass
        # (XLA lowers batched scatters as partial-scatter + f32 all-reduce
        # over the model axis, ~300 GB/device/step on deepseek; §Perf B1).
        cpos = jnp.arange(E * C) % C                     # capacity slot
        eid = jnp.arange(E * C) // C
        src_idx = starts[:, eid] + cpos[None, :]         # slot -> sorted idx
        slot_filled = cpos[None, :] < jnp.take_along_axis(
            counts, eid[None, :].repeat(B, 0), axis=-1
        ).clip(0, C)
        src_idx = jnp.minimum(src_idx, Sk - 1)
        inv = jnp.argsort(order, axis=-1)                # flat pos -> sorted idx

        # x -> k replicated rows in sorted order (bwd: gather + sum over k).
        gathered = _replicate_rows(x, st, inv, k)        # [B, Sk, d]
        gathered = shard(gathered, "batch", None, None)
        # sorted rows -> dispatch slots (bwd: gather by the slot map).
        xe = _permute_rows(gathered, src_idx, slot, slot_filled, keep)
        xe = xe.reshape(B, E, C, d)
        xe = shard(xe, "batch", "experts", None, None)  # expert-parallel a2a

        ye = _expert_ffn(params, xe, cfg).reshape(B, E * C, d)
        ye = shard(ye, "batch", None, None)

        # Slots -> token positions (bwd: gather by the slot's unique reader).
        tok_slot = jnp.take_along_axis(slot, inv, axis=-1)
        inv_p = jnp.take_along_axis(order, src_idx, axis=-1)  # slot -> flat pos
        picked_raw = _permute_rows(
            ye, tok_slot, inv_p, jnp.ones_like(tok_slot, jnp.bool_), slot_filled
        )
        tok_w = jnp.take_along_axis(sw * keep.astype(sw.dtype), inv, axis=-1)
        picked = picked_raw * tok_w[..., None].astype(x.dtype)
        out = jnp.sum(picked.reshape(B, S, k, d), axis=2)
        out = shard(out, "batch", None, None)
    else:
        gathered = jnp.take_along_axis(x, st[..., None], axis=1)  # [B, Sk, d]
        gathered = gathered * keep[..., None].astype(x.dtype)  # dropped -> 0
        gathered = shard(gathered, "batch", None, None)
        xe = jnp.zeros((B, E * C, d), x.dtype)
        xe = shard(xe.at[bidx, slot].add(gathered), "batch", None, None)
        xe = xe.reshape(B, E, C, d)
        xe = shard(xe, "batch", "experts", None, None)  # expert-parallel a2a

        ye = _expert_ffn(params, xe, cfg).reshape(B, E * C, d)
        ye = shard(ye, "batch", None, None)

        back = ye[bidx, slot] * (sw * keep.astype(sw.dtype))[..., None].astype(x.dtype)
        back = shard(back, "batch", None, None)
        out = jnp.zeros((B, S, d), x.dtype).at[bidx, st].add(back)
        out = shard(out, "batch", None, None)

    # ------------------------------------------------------ shared experts
    if e.num_shared:
        h = x @ params["shared_up"]
        h = shard(h, "batch", None, "d_ff")
        if cfg.activation == "silu":
            h = jax.nn.silu(x @ params["shared_gate"]) * h
        elif cfg.activation == "relu2":
            h = jnp.square(jax.nn.relu(h))
        else:
            h = jax.nn.gelu(h)
        out = out + h @ params["shared_down"]

    return out, aux
