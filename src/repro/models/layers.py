"""Transformer building blocks: norms, rope, MLPs, attention (GQA / MLA /
qk-norm / QKV-bias / sliding-window) with training and decode (KV cache)
paths.

All functions are pure; params are plain dict pytrees.  Logical sharding
annotations use ``repro.distributed.shard`` which is a no-op without an
active mesh, so the same code runs single-device smoke tests and 512-chip
dry-runs.
"""

from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import shard

PyTree = Any

__all__ = [
    "rms_norm",
    "rope",
    "dense_init",
    "mlp_init",
    "mlp_apply",
    "attention_init",
    "attention_apply",
    "attention_decode",
    "init_attn_cache",
]


# ------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


# -------------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., S, H, D]; positions: [S] or [B, S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    # Broadcast over heads: [..., S, 1, half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ------------------------------------------------------------------- linear
def dense_init(key: jax.Array, din: int, dout: int, dtype: Any, scale: float = 1.0) -> jax.Array:
    std = scale / math.sqrt(din)
    return (jax.random.normal(key, (din, dout), jnp.float32) * std).astype(dtype)


# --------------------------------------------------------------------- MLP
def mlp_init(key: jax.Array, cfg: ModelConfig, d_ff: int) -> PyTree:
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p = {
        "up": dense_init(ks[0], d, d_ff, dtype),
        "down": dense_init(ks[1], d_ff, d, dtype),
    }
    if cfg.activation == "silu":  # gated
        p["gate"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def mlp_apply(params: PyTree, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = x @ params["up"]
    h = shard(h, "batch", None, "d_ff")
    if cfg.activation == "silu":
        h = jax.nn.silu(x @ params["gate"]) * h
    elif cfg.activation == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:  # gelu
        h = jax.nn.gelu(h)
    out = h @ params["down"]
    return shard(out, "batch", None, None)


# --------------------------------------------------------- attention (GQA)
def attention_init(key: jax.Array, cfg: ModelConfig) -> PyTree:
    dtype = jnp.dtype(cfg.dtype)
    d, hd = cfg.d_model, cfg.head_dim
    if cfg.mla is not None:
        m = cfg.mla
        ks = jax.random.split(key, 5)
        q_dim = m.nope_head_dim + m.rope_head_dim
        p = {
            "wq": dense_init(ks[0], d, cfg.num_heads * q_dim, dtype),
            "w_dkv": dense_init(ks[1], d, m.kv_lora_rank + m.rope_head_dim, dtype),
            # up-projections from the latent: [lora, H, nope] and [lora, H, v]
            "w_uk": (
                jax.random.normal(ks[2], (m.kv_lora_rank, cfg.num_heads, m.nope_head_dim), jnp.float32)
                / math.sqrt(m.kv_lora_rank)
            ).astype(dtype),
            "w_uv": (
                jax.random.normal(ks[3], (m.kv_lora_rank, cfg.num_heads, m.v_head_dim), jnp.float32)
                / math.sqrt(m.kv_lora_rank)
            ).astype(dtype),
            "wo": dense_init(ks[4], cfg.num_heads * m.v_head_dim, d, dtype),
        }
        return p
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, cfg.num_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.num_heads * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(params: PyTree, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def _mla_qkv_train(params: PyTree, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    """MLA without absorption (training/prefill path)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q = (x @ params["wq"]).reshape(B, S, H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim :]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv = x @ params["w_dkv"]  # [B, S, lora + rope_dim]
    c, k_rope = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank :]
    k_rope = rope(k_rope[..., None, :], positions, cfg.rope_theta)  # shared head
    k_nope = jnp.einsum("bsc,chn->bshn", c, params["w_uk"])
    v = jnp.einsum("bsc,chv->bshv", c, params["w_uv"])

    # Pack rope parts into the head dim so standard attention applies:
    # k_rope is shared across heads -> broadcast.
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.rope_head_dim))], axis=-1
    )
    q_full = shard(q_full, "batch", None, "heads", None)
    k_full = shard(k_full, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    return q_full, k_full, v


def attention_apply(
    params: PyTree,
    x: jax.Array,
    cfg: ModelConfig,
    positions: Optional[jax.Array] = None,
    window: int = 0,
) -> jax.Array:
    """Training / prefill attention (no cache). x: [B, S, d]."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    win = window or cfg.sliding_window
    if cfg.mla is not None:
        from repro.kernels.ref import chunked_attention

        q, k, v = _mla_qkv_train(params, x, cfg, positions)
        # MLA has distinct qk vs v head dims -> jnp chunked path (the Pallas
        # kernel handles the standard equal-dims case).
        out = chunked_attention(q, k, v, causal=True, window=win)
        out = out.reshape(B, S, -1) @ params["wo"]
        return shard(out, "batch", None, None)
    q, k, v = _project_qkv(params, x, cfg, positions)
    from repro.kernels import ops as kops

    out = kops.flash_attention(q, k, v, causal=True, window=win)
    out = out.reshape(B, S, -1) @ params["wo"]
    return shard(out, "batch", None, None)


# ------------------------------------------------------------ decode / cache
def _quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """[..., head_dim] -> (int8 values, per-row bf16 scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype: Any) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def init_attn_cache(cfg: ModelConfig, batch: int, window: int) -> PyTree:
    dtype = jnp.dtype(cfg.dtype)
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c": jnp.zeros((batch, window, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, window, m.rope_head_dim), dtype),
        }
    if cfg.kv_cache_dtype == "int8":
        return {
            "k_q": jnp.zeros((batch, window, cfg.num_kv_heads, cfg.head_dim), jnp.int8),
            "k_s": jnp.zeros((batch, window, cfg.num_kv_heads, 1), jnp.bfloat16),
            "v_q": jnp.zeros((batch, window, cfg.num_kv_heads, cfg.head_dim), jnp.int8),
            "v_s": jnp.zeros((batch, window, cfg.num_kv_heads, 1), jnp.bfloat16),
        }
    return {
        "k": jnp.zeros((batch, window, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, window, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


def attention_decode(
    params: PyTree,
    x: jax.Array,
    cache: PyTree,
    pos: jax.Array,
    cfg: ModelConfig,
) -> Tuple[jax.Array, PyTree]:
    """Single-token decode with ring-buffer KV cache.

    x: [B, 1, d]; pos: scalar int32 absolute position, or a [B] vector of
    per-lane positions (co-batched sequences at ragged depths); cache
    window W. Returns (out [B, 1, d], new_cache).
    """
    B = x.shape[0]
    if cfg.mla is not None:
        return _mla_decode(params, x, cache, pos, cfg)
    quant = "k_q" in cache
    W = (cache["k_q"] if quant else cache["k"]).shape[1]
    hd = cfg.head_dim
    positions = pos[None] if pos.ndim == 0 else pos[:, None]
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, 1, cfg.num_heads, hd)
    k = k.reshape(B, 1, cfg.num_kv_heads, hd)
    v = v.reshape(B, 1, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    slot = (pos % W).astype(jnp.int32)
    if pos.ndim == 0:
        dus = lambda buf, upd: jax.lax.dynamic_update_slice_in_dim(buf, upd, slot, axis=1)
    else:
        # Per-lane write slot: one-hot select along the window axis.
        hit = jnp.arange(W)[None] == slot[:, None]  # [B, W]
        dus = lambda buf, upd: jnp.where(hit[:, :, None, None], upd, buf)
    if quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        new_cache = {
            "k_q": shard(dus(cache["k_q"], kq), "batch", "window", "kv_heads", None),
            "k_s": shard(dus(cache["k_s"], ks), "batch", "window", "kv_heads", None),
            "v_q": shard(dus(cache["v_q"], vq), "batch", "window", "kv_heads", None),
            "v_s": shard(dus(cache["v_s"], vs), "batch", "window", "kv_heads", None),
        }
        # Dequantize for the attention math (fused on TPU; the HBM-resident
        # cache is int8 either way, which is the memory win).
        ck = _dequantize_kv(new_cache["k_q"], new_cache["k_s"], k.dtype)
        cv = _dequantize_kv(new_cache["v_q"], new_cache["v_s"], v.dtype)
    else:
        ck = shard(dus(cache["k"], k), "batch", "window", "kv_heads", None)
        cv = shard(dus(cache["v"], v), "batch", "window", "kv_heads", None)
        new_cache = {"k": ck, "v": cv}

    from repro.kernels import ops as kops

    if pos.ndim == 0:
        valid = jnp.arange(W) <= jnp.minimum(pos, W - 1)  # ring-buffer occupancy
    else:
        valid = jnp.arange(W)[None] <= jnp.minimum(pos, W - 1)[:, None]  # [B, W]
    out = kops.decode_attention(q, ck, cv, valid)
    out = out.reshape(B, 1, -1) @ params["wo"]
    return shard(out, "batch", None, None), new_cache


def _mla_decode(params: PyTree, x: jax.Array, cache: PyTree, pos: jax.Array, cfg: ModelConfig):
    """MLA decode with matrix absorption: attend in the latent space so the
    cache is only [B, W, lora + rope] (the technique's memory win)."""
    m = cfg.mla
    B = x.shape[0]
    W = cache["c"].shape[1]
    H = cfg.num_heads
    positions = pos[None] if pos.ndim == 0 else pos[:, None]

    q = (x @ params["wq"]).reshape(B, 1, H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim :]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv = x @ params["w_dkv"]
    c_new, k_rope_new = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank :]
    k_rope_new = rope(k_rope_new[..., None, :], positions, cfg.rope_theta)[..., 0, :]

    slot = (pos % W).astype(jnp.int32)
    if pos.ndim == 0:
        cc = jax.lax.dynamic_update_slice_in_dim(cache["c"], c_new, slot, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope_new, slot, axis=1)
    else:
        hit = (jnp.arange(W)[None] == slot[:, None])[:, :, None]  # [B, W, 1]
        cc = jnp.where(hit, c_new, cache["c"])
        cr = jnp.where(hit, k_rope_new, cache["k_rope"])

    # Absorb W_uk into the query: q_lat [B, H, lora].
    q_lat = jnp.einsum("bhn,chn->bhc", q_nope[:, 0], params["w_uk"])
    scores = jnp.einsum("bhc,bwc->bhw", q_lat, cc, preferred_element_type=jnp.float32)
    scores += jnp.einsum("bhr,bwr->bhw", q_rope[:, 0].astype(jnp.float32), cr.astype(jnp.float32))
    scores *= 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    if pos.ndim == 0:
        valid = jnp.broadcast_to(jnp.arange(W) <= jnp.minimum(pos, W - 1), (B, W))
    else:
        valid = jnp.arange(W)[None] <= jnp.minimum(pos, W - 1)[:, None]
    scores = jnp.where(valid[:, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(valid[:, None], p, 0.0).astype(cc.dtype)  # empty cache -> zeros
    ctx_lat = jnp.einsum("bhw,bwc->bhc", p, cc)
    # Absorb W_uv on the way out.
    v = jnp.einsum("bhc,chv->bhv", ctx_lat, params["w_uv"])
    out = v.reshape(B, 1, H * m.v_head_dim) @ params["wo"]
    return shard(out, "batch", None, None), {"c": cc, "k_rope": cr}
