"""Model assembly: embeddings -> prologue -> scanned blocks -> head.

The layer stack compiles as ``lax.scan`` over blocks (HLO size independent of
depth; per-block ``jax.checkpoint`` for training remat).  Supports:

  * train/prefill forward (prefill also returns the KV/state cache)
  * single-token decode against a ring-buffer cache (``serve_step``)
  * text / VLM (prepended media embeddings) / audio (multi-codebook) inputs

Step builders (``make_train_step`` / ``make_prefill_step`` /
``make_decode_step``) produce the pure functions that plans wrap via
``TrainOneStep`` and that the dry-run lowers under the production mesh.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.distributed import shard
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    attention_apply,
    attention_decode,
    attention_init,
    init_attn_cache,
    mlp_apply,
    mlp_init,
    rms_norm,
)
from repro.models.moe import moe_apply, moe_init

PyTree = Any

__all__ = ["Model", "make_train_step", "make_prefill_step", "make_decode_step"]


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pattern = cfg.block_pattern
        self.num_blocks = cfg.num_blocks

    # ------------------------------------------------------------------ init
    def _init_layer(self, key: jax.Array, spec: LayerSpec) -> PyTree:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        k1, k2 = jax.random.split(key)
        p: Dict[str, Any] = {"norm1": jnp.ones((cfg.d_model,), dtype)}
        if spec.kind == "attn":
            p["attn"] = attention_init(k1, cfg)
        elif spec.kind == "rwkv6":
            p["attn"] = ssm_mod.rwkv6_init(k1, cfg)
        elif spec.kind == "mamba":
            p["attn"] = ssm_mod.mamba_init(k1, cfg)
        else:
            raise ValueError(spec.kind)
        if spec.mlp != "none":
            p["norm2"] = jnp.ones((cfg.d_model,), dtype)
            p["mlp"] = moe_init(k2, cfg) if spec.mlp == "moe" else mlp_init(k2, cfg, cfg.d_ff)
        return p

    def init_params(self, key: jax.Array) -> PyTree:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        keys = jax.random.split(key, 4 + len(cfg.prologue))
        scale = 0.02
        if cfg.modality == "audio":
            embed = (
                jax.random.normal(
                    keys[0], (cfg.num_codebooks, cfg.vocab_size, cfg.d_model), jnp.float32
                )
                * scale
            ).astype(dtype)
            head = (
                jax.random.normal(
                    keys[1], (cfg.d_model, cfg.num_codebooks * cfg.vocab_size), jnp.float32
                )
                * scale
            ).astype(dtype)
        else:
            embed = (
                jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model), jnp.float32) * scale
            ).astype(dtype)
            head = (
                jax.random.normal(keys[1], (cfg.d_model, cfg.vocab_size), jnp.float32) * scale
            ).astype(dtype)
        params: Dict[str, Any] = {
            "embed": embed,
            "lm_head": head,
            "final_norm": jnp.ones((cfg.d_model,), dtype),
        }
        for i, spec in enumerate(cfg.prologue):
            params[f"prologue_{i}"] = self._init_layer(keys[4 + i], spec)

        def one_block(k: jax.Array) -> PyTree:
            pk = jax.random.split(k, len(self.pattern))
            return {str(i): self._init_layer(pk[i], s) for i, s in enumerate(self.pattern)}

        block_keys = jax.random.split(keys[2], self.num_blocks)
        params["blocks"] = jax.vmap(one_block)(block_keys)
        return params

    # ------------------------------------------------------------ embedding
    def _embed(self, params: PyTree, tokens: jax.Array, media_emb: Optional[jax.Array]) -> jax.Array:
        cfg = self.cfg
        if cfg.modality == "audio":
            # tokens: [B, S, K] -> sum of per-codebook embeddings.
            parts = [
                jnp.take(params["embed"][k], tokens[..., k], axis=0)
                for k in range(cfg.num_codebooks)
            ]
            x = sum(parts)
        else:
            x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.modality == "vlm" and media_emb is not None:
            x = jnp.concatenate([media_emb.astype(x.dtype), x], axis=1)
        return shard(x, "batch", None, None)

    def _head(self, params: PyTree, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        logits = x @ params["lm_head"]
        if cfg.modality == "audio":
            logits = logits.reshape(x.shape[:-1] + (cfg.num_codebooks, cfg.vocab_size))
            return shard(logits, "batch", None, None, "vocab")
        return shard(logits, "batch", None, "vocab")

    # --------------------------------------------------------------- forward
    def _apply_layer(
        self, lp: PyTree, x: jax.Array, spec: LayerSpec, window: int
    ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        if spec.kind == "attn":
            h = attention_apply(lp["attn"], h, cfg, window=window)
        elif spec.kind == "rwkv6":
            h = ssm_mod.rwkv6_apply(lp["attn"], h, cfg)
        else:
            h = ssm_mod.mamba_apply(lp["attn"], h, cfg)
        x = x + h
        aux = jnp.zeros((), jnp.float32)
        if spec.mlp != "none":
            h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
            if spec.mlp == "moe":
                h2, aux = moe_apply(lp["mlp"], h2, cfg)
            else:
                h2 = mlp_apply(lp["mlp"], h2, cfg)
            x = x + h2
        return x, aux

    def forward(
        self,
        params: PyTree,
        tokens: jax.Array,
        media_emb: Optional[jax.Array] = None,
        window: int = 0,
        remat: bool = False,
    ) -> Tuple[jax.Array, jax.Array]:
        """Returns (hidden [B, S, d], moe aux loss)."""
        cfg = self.cfg
        x = self._embed(params, tokens, media_emb)
        aux = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(cfg.prologue):
            x, a = self._apply_layer(params[f"prologue_{i}"], x, spec, window)
            aux = aux + a

        # Per-layer remat nested inside per-block remat: backward recomputes
        # one layer at a time, so peak residuals ~ a single layer's
        # intermediates even for multi-layer patterns (Jamba's 8-layer block).
        layer_fns = []
        for i, spec in enumerate(self.pattern):
            fn = lambda lp, x, _spec=spec: self._apply_layer(lp, x, _spec, window)
            layer_fns.append(jax.checkpoint(fn) if remat else fn)

        def block_fn(carry, bp):
            x, aux = carry
            for i in range(len(self.pattern)):
                x, a = layer_fns[i](bp[str(i)], x)
                aux = aux + a
            if cfg.shard_residuals:
                # Residual/remat-carry activations sharded over 'model' so the
                # saved per-block activation is 1/model_axis per device.
                x = shard(x, "batch", None, "d_ff")
            return (x, aux), None

        if remat:
            block_fn = jax.checkpoint(block_fn)
        (x, aux), _ = jax.lax.scan(block_fn, (x, aux), params["blocks"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, aux

    # ------------------------------------------------------------------ loss
    def loss(
        self,
        params: PyTree,
        tokens: jax.Array,
        labels: jax.Array,
        media_emb: Optional[jax.Array] = None,
        remat: bool = True,
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Causal LM loss. labels < 0 are masked."""
        cfg = self.cfg
        x, aux = self.forward(params, tokens, media_emb, remat=remat)
        if cfg.modality == "vlm" and media_emb is not None:
            x = x[:, media_emb.shape[1] :]  # media positions carry no labels
        logits = self._head(params, x).astype(jnp.float32)
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return loss + aux, {"nll": loss, "aux": aux}

    # ------------------------------------------------------------- caching
    def _init_layer_cache(self, spec: LayerSpec, batch: int, window: int) -> PyTree:
        cfg = self.cfg
        if spec.kind == "attn":
            return init_attn_cache(cfg, batch, window)
        if spec.kind == "rwkv6":
            return ssm_mod.init_rwkv6_state(cfg, batch)
        return ssm_mod.init_mamba_state(cfg, batch)

    def init_cache(self, batch: int, window: int) -> PyTree:
        cache: Dict[str, Any] = {
            "pos": jnp.zeros((), jnp.int32),
        }
        for i, spec in enumerate(self.cfg.prologue):
            cache[f"prologue_{i}"] = self._init_layer_cache(spec, batch, window)

        def stack(leaf_fn):
            return jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(l, (self.num_blocks,) + l.shape), leaf_fn
            )

        cache["blocks"] = {
            str(i): stack(self._init_layer_cache(spec, batch, window))
            for i, spec in enumerate(self.pattern)
        }
        return cache

    def _decode_layer(
        self, lp: PyTree, x: jax.Array, spec: LayerSpec, lcache: PyTree, pos: jax.Array
    ) -> Tuple[jax.Array, PyTree]:
        cfg = self.cfg
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        if spec.kind == "attn":
            h, lcache = attention_decode(lp["attn"], h, lcache, pos, cfg)
        elif spec.kind == "rwkv6":
            h, lcache = ssm_mod.rwkv6_decode(lp["attn"], h, lcache, cfg)
        else:
            h, lcache = ssm_mod.mamba_decode(lp["attn"], h, lcache, cfg)
        x = x + h
        if spec.mlp != "none":
            h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
            if spec.mlp == "moe":
                h2, _ = moe_apply(lp["mlp"], h2, cfg)
            else:
                h2 = mlp_apply(lp["mlp"], h2, cfg)
            x = x + h2
        return x, lcache

    def decode_step(
        self, params: PyTree, cache: PyTree, tokens: jax.Array, with_hidden: bool = False
    ):
        """One token for every sequence. tokens: [B,1] (audio [B,1,K]).

        ``cache["pos"]`` may be a scalar (all sequences at the same depth) or
        a [B] vector of per-lane positions (ragged co-batched decode).
        Returns (logits, new_cache), plus the final-norm hidden [B,1,d] when
        ``with_hidden`` (for value heads riding the decode path).
        """
        cfg = self.cfg
        pos = cache["pos"]
        x = self._embed(params, tokens, None)
        new_cache: Dict[str, Any] = {"pos": pos + 1}
        for i, spec in enumerate(cfg.prologue):
            x, c = self._decode_layer(params[f"prologue_{i}"], x, spec, cache[f"prologue_{i}"], pos)
            new_cache[f"prologue_{i}"] = c

        def block_fn(x, xs):
            bp, bc = xs
            for i, spec in enumerate(self.pattern):
                x, c = self._decode_layer(bp[str(i)], x, spec, bc[str(i)], pos)
                bc = dict(bc, **{str(i): c})
            return x, bc

        x, new_blocks = jax.lax.scan(block_fn, x, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = new_blocks
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x)
        if with_hidden:
            return logits, new_cache, x
        return logits, new_cache

    # ------------------------------------------------------------- prefill
    def prefill(
        self,
        params: PyTree,
        tokens: jax.Array,
        media_emb: Optional[jax.Array] = None,
        window: int = 0,
        with_hidden: bool = False,
    ):
        """Forward over a prompt, returning (last-token logits, filled cache).

        The cache window equals the prompt length (or ``window`` if set).
        Implemented by running the sequence path and reconstructing per-layer
        cache state; attention caches are the (rope'd) K/V of the prompt.
        With ``with_hidden`` the full final-norm hidden [B,S,d] is appended
        to the return (callers with ragged prompts need logits at their own
        last position, not at S-1).
        """
        cfg = self.cfg
        B, S = tokens.shape[0], tokens.shape[1]
        if cfg.modality == "vlm" and media_emb is not None:
            S = S + media_emb.shape[1]
        W = window or S
        # Run the standard forward; capture caches layer by layer.
        x = self._embed(params, tokens, media_emb)
        positions = jnp.arange(S)
        cache: Dict[str, Any] = {"pos": jnp.asarray(S, jnp.int32)}

        def layer_with_cache(lp, x, spec):
            h = rms_norm(x, lp["norm1"], cfg.norm_eps)
            if spec.kind == "attn":
                c = _prefill_attn_cache(lp["attn"], h, cfg, W, positions)
                h = attention_apply(lp["attn"], h, cfg, window=window)
            elif spec.kind == "rwkv6":
                h, c = _prefill_rwkv6(lp["attn"], h, cfg)
            else:
                h, c = _prefill_mamba(lp["attn"], h, cfg)
            x = x + h
            if spec.mlp != "none":
                h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
                if spec.mlp == "moe":
                    h2, _ = moe_apply(lp["mlp"], h2, cfg)
                else:
                    h2 = mlp_apply(lp["mlp"], h2, cfg)
                x = x + h2
            return x, c

        for i, spec in enumerate(cfg.prologue):
            x, c = layer_with_cache(params[f"prologue_{i}"], x, spec)
            cache[f"prologue_{i}"] = c

        def block_fn(x, bp):
            cs = {}
            for i, spec in enumerate(self.pattern):
                x, c = layer_with_cache(bp[str(i)], x, spec)
                cs[str(i)] = c
            return x, cs

        x, blocks_cache = jax.lax.scan(block_fn, x, params["blocks"])
        cache["blocks"] = blocks_cache
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, x[:, -1:])
        if with_hidden:
            return logits, cache, x
        return logits, cache


# ------------------------------------------------- prefill cache builders
def _prefill_attn_cache(ap: PyTree, h: jax.Array, cfg: ModelConfig, W: int, positions: jax.Array):
    from repro.models.layers import _project_qkv

    B, S, _ = h.shape
    if cfg.mla is not None:
        m = cfg.mla
        ckv = h @ ap["w_dkv"]
        c, k_rope = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank :]
        from repro.models.layers import rope as _rope

        k_rope = _rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
        c = _fit_window(c, W)
        k_rope = _fit_window(k_rope, W)
        return {"c": c, "k_rope": k_rope}
    q, k, v = _project_qkv(ap, h, cfg, positions)
    if cfg.kv_cache_dtype == "int8":
        from repro.models.layers import _quantize_kv

        kq, ks = _quantize_kv(_fit_window(k, W))
        vq, vs = _quantize_kv(_fit_window(v, W))
        return {"k_q": kq, "k_s": ks, "v_q": vq, "v_s": vs}
    return {"k": _fit_window(k, W), "v": _fit_window(v, W)}


def _fit_window(x: jax.Array, W: int) -> jax.Array:
    """Fit [B, S, ...] sequence into a [B, W, ...] ring buffer (keep last W)."""
    S = x.shape[1]
    if S == W:
        return x
    if S > W:
        # Last W entries, rotated so ring slot (pos % W) lines up.
        tail = x[:, S - W :]
        shift = (S - W) % W
        return jnp.roll(tail, shift=shift, axis=1)
    pad = [(0, 0), (0, W - S)] + [(0, 0)] * (x.ndim - 2)
    return jnp.pad(x, pad)


def _prefill_rwkv6(ap: PyTree, h: jax.Array, cfg: ModelConfig):
    from repro.kernels import ops as kops
    from repro.models.ssm import _rwkv6_streams

    B, T, d = h.shape
    x_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, w = _rwkv6_streams(ap, h, x_prev, cfg)
    out, state = kops.rwkv6(r, k, v, w, ap["bonus_u"].astype(jnp.float32), chunk=cfg.ssm.chunk)
    out = out.reshape(B, T, d)
    out = rms_norm(out, ap["ln_out"], cfg.norm_eps) * g
    out = out @ ap["wo"]
    return out, {"wkv": state, "x_prev": h[:, -1]}


def _prefill_mamba(ap: PyTree, h: jax.Array, cfg: ModelConfig):
    from repro.models.ssm import _mamba_scan

    from repro.models.ssm import _causal_conv

    s = cfg.ssm
    B, T, d = h.shape
    d_in = s.expand * d
    xz = h @ ap["in_proj"]
    xc, z = xz[..., :d_in], xz[..., d_in:]
    xc = shard(xc, "batch", None, "d_ff")
    xc_act = jax.nn.silu(_causal_conv(xc, ap["conv_w"], ap["conv_b"]))
    h0 = jnp.zeros((B, d_in, s.d_state), jnp.float32)
    y, hN = _mamba_scan(ap, xc_act, h0, s)
    y = y * jax.nn.silu(z)
    out = y @ ap["out_proj"]
    conv_tail = pad[:, T : T + s.d_conv - 1] if False else xc[:, T - (s.d_conv - 1) :]
    return out, {"h": hN, "conv": conv_tail}


# ----------------------------------------------------------- step builders
def make_train_step(model: Model, optimizer) -> Callable:
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(
                p,
                batch["tokens"],
                batch["labels"],
                media_emb=batch.get("media_emb"),
                remat=True,
            )

        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state = optimizer.apply(params, grads, opt_state)
        metrics = {"loss": loss, **parts}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model, window: int = 0) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(
            params, batch["tokens"], media_emb=batch.get("media_emb"), window=window
        )

    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch["tokens"])

    return decode_step
