"""Stress tests: union(deterministic=False) / Concurrently under contention.

ISSUE 2 satellites: (a) no lost or duplicated items with 8+ producer
branches and randomized delays; (b) async-union driver threads are joined on
iterator teardown instead of leaking across tests.

ISSUE 3 deflake: every injected delay draws from the shared
``deterministic_clock`` fixture (seeded per test id), deadline polling goes
through ``clock.wait_until``, and each stress test carries a ``timeout``
marker so a wedged union fails fast instead of hanging CI.
"""

import threading

import pytest

import repro.core as c


def union_driver_threads():
    return [t for t in threading.enumerate() if t.name.startswith("union-drive")]


def delayed_branch(clock, branch_id, n_items, max_delay=0.002):
    """A branch emitting (branch_id, seq) with seeded per-item delays."""
    rng = clock.rng.__class__(clock.seed * 7919 + branch_id)

    def _delay(item):
        import time

        time.sleep(rng.random() * max_delay)
        return item

    return c.from_items([(branch_id, i) for i in range(n_items)]).for_each(_delay)


@pytest.mark.timeout(120)
@pytest.mark.parametrize("n_branches,n_items", [(8, 40), (12, 25)])
def test_union_async_no_lost_or_duplicated_items(deterministic_clock, n_branches, n_items):
    branches = [delayed_branch(deterministic_clock, b, n_items) for b in range(n_branches)]
    merged = branches[0].union(*branches[1:], deterministic=False)
    out = merged.take(n_branches * n_items)

    expected = {(b, i) for b in range(n_branches) for i in range(n_items)}
    assert len(out) == len(expected), "items lost"
    assert set(out) == expected, "items lost or duplicated"
    assert len(set(out)) == len(out), "duplicated items"
    # Per-branch FIFO survives contention.
    for b in range(n_branches):
        seq = [i for bb, i in out if bb == b]
        assert seq == list(range(n_items))
    merged.close()


@pytest.mark.timeout(120)
def test_concurrently_async_under_contention(deterministic_clock):
    n_branches, n_items = 9, 30
    ops = [delayed_branch(deterministic_clock, b, n_items) for b in range(n_branches)]
    merged = c.Concurrently(ops, mode="async")
    out = merged.take(n_branches * n_items)
    assert set(out) == {(b, i) for b in range(n_branches) for i in range(n_items)}
    assert len(out) == n_branches * n_items
    merged.close()


@pytest.mark.timeout(120)
def test_concurrently_round_robin_under_contention(deterministic_clock):
    n_branches, n_items = 8, 20
    ops = [
        delayed_branch(deterministic_clock, b, n_items, max_delay=0.001)
        for b in range(n_branches)
    ]
    merged = c.Concurrently(ops, mode="round_robin")
    out = merged.take(n_branches * n_items)
    assert set(out) == {(b, i) for b in range(n_branches) for i in range(n_items)}
    # Deterministic interleave: round r emits every alive branch in order.
    assert out[:n_branches] == [(b, 0) for b in range(n_branches)]
    merged.close()


@pytest.mark.timeout(60)
def test_union_async_driver_threads_joined_on_close(deterministic_clock):
    """Satellite: Concurrently/union async driver threads must not leak."""
    baseline = len(union_driver_threads())
    merged = c.Concurrently(
        [c.from_items([(b, i) for i in range(1000)]) for b in range(6)],
        mode="async",
    )
    merged.take(30)  # partial consumption: drivers still live/blocked
    assert len(union_driver_threads()) > baseline
    merged.close()
    assert deterministic_clock.wait_until(
        lambda: len(union_driver_threads()) <= baseline, timeout=5.0
    ), "driver threads leaked"


@pytest.mark.timeout(60)
def test_union_async_driver_threads_joined_on_exhaustion(deterministic_clock):
    baseline = len(union_driver_threads())
    merged = c.from_items([1, 2]).union(c.from_items([3, 4]), deterministic=False)
    assert sorted(merged.take(10)) == [1, 2, 3, 4]  # stream drains
    assert deterministic_clock.wait_until(
        lambda: len(union_driver_threads()) <= baseline, timeout=5.0
    )
    merged.close()


@pytest.mark.timeout(60)
def test_nested_union_close_propagates(deterministic_clock):
    baseline = len(union_driver_threads())
    inner = c.from_items(range(1000)).union(c.from_items(range(1000)))
    outer = inner.union(c.from_items(range(1000)))
    outer.take(10)
    outer.close()
    assert deterministic_clock.wait_until(
        lambda: len(union_driver_threads()) <= baseline, timeout=5.0
    ), "nested drivers leaked"


@pytest.mark.timeout(120)
def test_algorithm_stop_joins_flow_threads(deterministic_clock):
    """Flow-level teardown: Algorithm.stop() closes the compiled stream and
    joins its Concurrently drivers (plus learner threads, already covered)."""
    import chaos
    import repro.flow as flow
    from repro.core import WorkerSet
    from repro.flow.spec import FlowSpec

    baseline = len(union_driver_threads())
    ws = WorkerSet.create(chaos.make_stub_worker, 2)
    spec = FlowSpec("teardown")
    a = spec.rollouts(ws, mode="async").for_each(flow.pure(lambda b: b.count), label="count")
    bq = spec.rollouts(ws, mode="bulk_sync").for_each(flow.pure(lambda b: b.count), label="count2")
    spec.set_output(spec.concurrently([a, bq], mode="async"))
    algo = flow.Algorithm.from_plan(spec, ws)
    algo.iterate(5)
    assert len(union_driver_threads()) > baseline
    algo.stop()
    assert deterministic_clock.wait_until(
        lambda: len(union_driver_threads()) <= baseline, timeout=5.0
    ), "flow teardown leaked drivers"
