"""flowcheck: the static-analysis pass (ISSUE 6) and the shm-lease sanitizer.

One positive (rule fires, with node anchor + fix hint) and one negative
(clean graph stays clean) case per built-in rule; a property test that the
analyzer never crashes on arbitrary annotated specs; the regression gate
that all committed plan builders are error-clean; and unit tests for the
``TRANSPORT_SANITIZE=1`` lease sanitizer that the autouse conftest fixture
drives across the whole suite.
"""

import gc
import json
import os
import pickle
import random
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.transport import SANITIZER, ShmLeaseViolation, sanitize_enabled
from repro.flow.analysis import (
    RULES,
    Diagnostic,
    FlowAnalysisError,
    Severity,
    analyze,
    audit_plans,
)
from repro.flow.spec import FlowSpec, ResourceRef

REPO = Path(__file__).resolve().parents[1]

EXPECTED_RULES = {
    "graph-structure",
    "credit-deadlock",
    "unbounded-queue",
    "annotation-lowering",
    "cross-host-placement",
    "pickle-safety",
    "resource-oversubscription",
    "determinism-hazard",
}


# --------------------------------------------------------------- fakes
class FakeActor:
    def __init__(self, name, backend="thread"):
        self.name = name
        self.backend_name = backend


class FakeLocalWorker:
    def __init__(self, policy="policy"):
        self.policy = policy


class FakePool:
    """Duck-typed WorkerSet: just enough surface for GraphView introspection."""

    def __init__(self, n=2, backend="thread", local=None):
        self._actors = [FakeActor(f"rollout-{i + 1}", backend) for i in range(n)]
        self._local = local

    def remote_workers(self):
        return list(self._actors)

    def local_worker(self):
        return self._local


def _identity(x):
    return x


def _uses_stdlib_random(batch):
    return random.random()


# Built in an isolated namespace: the rule resolves the name `random`
# through the stage's __globals__, and this test module's own
# `import random` (for the stdlib case above) would otherwise shadow the
# np.random classification.
_NP_NS = {"np": np}
exec("def _uses_np_random(batch):\n    return np.random.rand(2)\n", _NP_NS)
_uses_np_random = _NP_NS["_uses_np_random"]


class _TrainStage:
    """A TrainOneStep-shaped stage: accepts the learner-group knobs."""

    num_learners = 1
    microbatch = 1

    def __call__(self, batch):
        return batch


def by_rule(diags, name):
    return [d for d in diags if d.rule == name]


# ---------------------------------------------------------- registry
def test_builtin_rule_registry():
    analyze(FlowSpec("touch"))  # import side effect registers the builtins
    assert EXPECTED_RULES <= set(RULES)
    for r in RULES.values():
        assert r.name and r.description


# ----------------------------------------------------- graph-structure
def test_graph_structure_flags_missing_output_and_double_consumption():
    spec = FlowSpec("broken")
    s = spec.from_items([1, 2, 3])
    s.for_each(_identity)
    s.for_each(_identity)  # second consumer of the same edge
    diags = by_rule(analyze(spec), "graph-structure")
    messages = [d.message for d in diags]
    assert any("no output set" in m for m in messages)
    dup = [d for d in diags if "consumed 2 times" in d.message]
    assert dup and dup[0].is_error
    assert dup[0].node == s.node_id and dup[0].edge == s.ref
    assert "duplicate" in dup[0].hint


def test_graph_structure_flags_resource_wiring():
    spec = FlowSpec("wiring")
    spec.learner_thread(FakePool(), name="idle")  # declared, never wired
    out = spec.from_items([1]).enqueue(ResourceRef(spec, "ghost"))  # undeclared
    spec.set_output(out)
    diags = by_rule(analyze(spec), "graph-structure")
    ghost = [d for d in diags if "'ghost'" in d.message]
    assert ghost and ghost[0].is_error and ghost[0].hint
    idle = [d for d in diags if "'idle'" in d.message]
    assert idle and idle[0].severity == Severity.WARN and "wire it" in idle[0].hint


def test_graph_structure_flags_dead_duplicate_port():
    spec = FlowSpec("dead-port")
    live, dead = spec.from_items([1]).duplicate(2)
    spec.set_output(live.for_each(_identity))
    diags = by_rule(analyze(spec), "graph-structure")
    [d] = [d for d in diags if "never consumed" in d.message]
    assert d.severity == Severity.WARN
    assert d.node == dead.node_id and d.edge == dead.ref and d.hint


def test_clean_spec_analyzes_clean():
    spec = FlowSpec("clean")
    spec.set_output(spec.from_items([1, 2]).for_each(_identity).report())
    assert analyze(spec) == []


# ----------------------------------------------------- credit-deadlock
def test_credit_deadlock_blocking_enqueue_without_dequeue():
    spec = FlowSpec("wedge")
    lt = spec.learner_thread(FakePool(), out_policy="block")
    enq = spec.from_items([1], repeat=True).enqueue(lt)  # block=True default
    spec.set_output(enq)
    [d] = by_rule(analyze(spec), "credit-deadlock")
    assert d.is_error and d.node == enq.node_id
    assert "no dequeue node drains" in d.message
    assert "spec.dequeue" in d.hint


def test_credit_deadlock_round_robin_union_owns_both_sides():
    spec = FlowSpec("rr-cycle")
    lt = spec.learner_thread(FakePool(), out_policy="block")
    enq = spec.from_items([1], repeat=True).enqueue(lt)
    deq = spec.dequeue(lt)
    union = spec.concurrently([enq, deq], mode="round_robin")
    spec.set_output(union)
    [d] = by_rule(analyze(spec), "credit-deadlock")
    assert d.is_error and d.node == union.node_id
    assert "round_robin union" in d.message and "concurrently(mode='async')" in d.hint


def test_credit_deadlock_warns_on_starved_credit_window():
    spec = FlowSpec("starved")
    s = spec.rollouts(FakePool(n=4), mode="async", credits=2)
    spec.set_output(s.for_each(_identity))
    [d] = by_rule(analyze(spec), "credit-deadlock")
    assert d.severity == Severity.WARN and d.node == s.node_id
    assert "credits=2 is below the 4-shard pool" in d.message
    assert ">= 4" in d.hint


def test_credit_deadlock_quiet_when_cycle_is_drainable():
    spec = FlowSpec("drains")
    lt = spec.learner_thread(FakePool())  # default out_policy drops, never wedges
    enq = spec.rollouts(FakePool(n=2), mode="async", credits=2).enqueue(lt)
    deq = spec.dequeue(lt)
    spec.set_output(spec.concurrently([enq, deq], mode="round_robin"))
    assert by_rule(analyze(spec), "credit-deadlock") == []


# ----------------------------------------------------- unbounded-queue
def test_unbounded_queue_flags_creditless_async_feed():
    spec = FlowSpec("unbounded")
    lt = spec.learner_thread(FakePool())
    enq = spec.rollouts(FakePool(), mode="async").enqueue(lt)
    spec.set_output(spec.concurrently([enq, spec.dequeue(lt)]))
    [d] = by_rule(analyze(spec), "unbounded-queue")
    assert d.severity == Severity.WARN and d.node == enq.node_id
    assert "no credit bound" in d.message and "credits=" in d.hint


def test_unbounded_queue_quiet_with_credit_bound_or_sync_feed():
    spec = FlowSpec("bounded")
    lt = spec.learner_thread(FakePool())
    enq = spec.rollouts(FakePool(n=2), mode="async", credits=4).enqueue(lt)
    sync_enq = spec.rollouts(FakePool(n=2)).enqueue(lt)  # bulk_sync: bounded
    spec.set_output(spec.concurrently([enq, sync_enq, spec.dequeue(lt)]))
    assert by_rule(analyze(spec), "unbounded-queue") == []


def test_unbounded_queue_flags_duplicate_into_async_union():
    spec = FlowSpec("dup-async")
    a, b = spec.from_items([1], repeat=True).duplicate(2)
    union = spec.concurrently([a.for_each(_identity), b], mode="async")
    spec.set_output(union)
    [d] = by_rule(analyze(spec), "unbounded-queue")
    assert d.severity == Severity.WARN
    assert d.node == a.node_id and "grows without bound" in d.message
    assert "round_robin" in d.hint


# ------------------------------------------------- annotation-lowering
def test_annotation_lowering_flags_misplaced_and_invalid_knobs():
    spec = FlowSpec("bad-annotations")
    s = spec.from_items([1]).for_each(_identity)
    s.annotate(overflow_policy="block", credits=4)  # neither lowers here
    out = s.enqueue(spec.learner_thread(FakePool()))
    out.annotate(overflow_policy="bogus")
    spec.set_output(out)
    diags = by_rule(analyze(spec), "annotation-lowering")
    assert all(d.is_error and d.hint for d in diags)
    anchored = {d.node for d in diags}
    assert {s.node_id, out.node_id} == anchored
    assert any("only enqueue nodes lower it" in d.message for d in diags)
    assert any("only gather_async/rollouts/replay" in d.message for d in diags)
    assert any("unknown overflow_policy 'bogus'" in d.message for d in diags)


def test_annotation_lowering_flags_failure_policy_misuse_and_conflict():
    pool = FakePool(n=2)
    spec = FlowSpec("fp")
    a = spec.rollouts(pool, failure_policy="restart")
    b = spec.rollouts(pool, failure_policy="drop_shard")  # same pool, conflicts
    mid = spec.from_items([1]).annotate(failure_policy="restart")  # not a source
    bad = spec.rollouts(FakePool()).annotate(failure_policy="explode")
    spec.set_output(spec.concurrently([a, b, mid, bad]))
    diags = by_rule(analyze(spec), "annotation-lowering")
    conflict = [d for d in diags if "conflicts with" in d.message]
    assert conflict and conflict[0].severity == Severity.WARN
    assert conflict[0].node == b.node_id and a.node_id in conflict[0].message
    assert any(d.node == mid.node_id and "source actors only" in d.message for d in diags)
    assert any(d.node == bad.node_id and "unknown failure_policy" in d.message for d in diags)


def test_annotation_lowering_learner_knobs():
    spec = FlowSpec("learners")
    incapable = spec.from_items([1]).for_each(_identity).learners(2)
    capable = spec.from_items([2]).for_each(_TrainStage()).learners(2).microbatch(2)
    spec.set_output(spec.concurrently([incapable, capable]))
    diags = by_rule(analyze(spec), "annotation-lowering")
    [d] = diags
    assert d.is_error and d.node == incapable.node_id
    assert "no stage of this node accepts" in d.message
    assert "TrainOneStep" in d.hint


def test_annotation_lowering_ctx_stage_is_info_not_error():
    spec = FlowSpec("ctx")
    s = spec.from_items([1]).for_each_ctx(lambda rt: _identity, "TrainCtx").learners(2)
    spec.set_output(s)
    [d] = by_rule(analyze(spec), "annotation-lowering")
    assert d.severity == Severity.INFO and d.node == s.node_id


def test_annotation_lowering_vector_knobs():
    spec = FlowSpec("vector")
    misplaced = spec.from_items([1]).annotate(vector=4)
    bad_mode = spec.rollouts(FakePool()).annotate(inference="remote")
    no_policy = spec.rollouts(
        FakePool(local=FakeLocalWorker(policy=None)), inference="server"
    )
    spec.set_output(spec.concurrently([misplaced, bad_mode, no_policy]))
    diags = by_rule(analyze(spec), "annotation-lowering")
    assert all(d.is_error for d in diags)
    assert any(d.node == misplaced.node_id and "rollouts/" in d.message for d in diags)
    assert any(d.node == bad_mode.node_id and "unknown inference mode" in d.message for d in diags)
    assert any(d.node == no_policy.node_id and "no .policy to" in d.message for d in diags)


# -------------------------------------------------------- pickle-safety
def test_pickle_safety_server_inference_on_process_workers():
    spec = FlowSpec("proc-server")
    s = spec.rollouts(
        FakePool(backend="process", local=FakeLocalWorker()), inference="server"
    )
    spec.set_output(s)
    [d] = by_rule(analyze(spec), "pickle-safety")
    assert d.severity == Severity.WARN and d.node == s.node_id
    assert "pickle" in d.message
    assert "thread-backend" in d.hint


def test_pickle_safety_unpicklable_parallel_stage_and_pull_fn():
    spec = FlowSpec("proc-stages")
    stage = (
        spec.rollouts(FakePool(backend="process"), mode="raw")
        .for_each(lambda b: b)  # lambdas do not pickle
        .gather_sync()
    )
    par = spec.par_source(FakePool(backend="process"), pull_fn=lambda a: a)
    spec.set_output(spec.concurrently([stage, par.gather_sync()]))
    diags = by_rule(analyze(spec), "pickle-safety")
    warn = [d for d in diags if d.severity == Severity.WARN]
    info = [d for d in diags if d.severity == Severity.INFO]
    assert warn and "cannot be cloned per shard" in warn[0].message and warn[0].hint
    assert info and info[0].node == par.node_id and "driver-side" in info[0].message


def test_pickle_safety_quiet_on_thread_backends():
    spec = FlowSpec("threads")
    s = (
        spec.rollouts(FakePool(local=FakeLocalWorker()), mode="raw")
        .for_each(lambda b: b)
        .gather_sync()
    )
    spec.set_output(s)
    assert by_rule(analyze(spec), "pickle-safety") == []


# --------------------------------------- resource-oversubscription
def test_oversubscription_flags_learners_beyond_devices():
    spec = FlowSpec("too-many-learners")
    s = spec.from_items([1]).for_each(_TrainStage()).learners(999)
    spec.learner_thread(FakePool(), name="lt", num_learners=999)
    spec.set_output(s.enqueue(ResourceRef(spec, "lt")))
    diags = by_rule(analyze(spec), "resource-oversubscription")
    assert len(diags) == 2 and all(d.is_error for d in diags)
    assert any(d.node == s.node_id for d in diags)
    assert all("XLA_FLAGS" in d.hint for d in diags)


def test_oversubscription_warns_on_cpu_demand():
    ncpu = os.cpu_count()
    spec = FlowSpec("cpu-hungry")
    s = spec.rollouts(FakePool(n=4), resources={"num_cpus": ncpu})
    spec.set_output(s)
    [d] = by_rule(analyze(spec), "resource-oversubscription")
    assert d.severity == Severity.WARN and d.node == s.node_id
    assert d.details == {"declared": 4 * ncpu, "available": ncpu}


def test_oversubscription_quiet_within_budget():
    spec = FlowSpec("fits")
    s = spec.from_items([1]).for_each(_TrainStage()).learners(1)
    spec.set_output(s)
    assert by_rule(analyze(spec), "resource-oversubscription") == []


# ------------------------------------------------- determinism-hazard
def test_determinism_hazard_flags_ambient_rng():
    spec = FlowSpec("rng")
    a = spec.from_items([1]).for_each(_uses_stdlib_random)
    b = spec.from_items([2]).filter(_uses_np_random)
    spec.set_output(spec.concurrently([a, b]))
    diags = by_rule(analyze(spec), "determinism-hazard")
    assert {d.node for d in diags} == {a.node_id, b.node_id}
    assert all(d.severity == Severity.WARN and "seeded" in d.hint for d in diags)
    assert any("stdlib `random`" in d.message for d in diags)
    assert any("np.random" in d.message for d in diags)


def test_determinism_hazard_quiet_on_seeded_stages():
    # The idiom the hint recommends: thread an explicit Generator through
    # the stage (here via closure) so its body never names `random` at all.
    rng = np.random.default_rng(0)

    def seeded(batch):
        return rng.integers(0, 2)

    spec = FlowSpec("seeded")
    spec.set_output(spec.from_items([1]).for_each(seeded))
    assert by_rule(analyze(spec), "determinism-hazard") == []


# ------------------------------------------------------ engine plumbing
# ------------------------------------------------- cross-host-placement
def test_cross_host_flags_undeclared_and_non_source_placement():
    spec = FlowSpec("bad-hosts")
    spec.declare_host("box")
    out = (
        spec.rollouts(FakePool(), host="ghost")  # never declared
        .for_each(_identity)
        .host("box")  # placement on a for_each: lowering never reads it
    )
    spec.set_output(out)
    diags = by_rule(analyze(spec), "cross-host-placement")
    ghost = [d for d in diags if "'ghost'" in d.message and "not declared" in d.message]
    assert ghost and ghost[0].is_error and "declare_host" in ghost[0].hint
    nonsrc = [d for d in diags if "for_each" in d.message]
    assert nonsrc and nonsrc[0].is_error and "source node" in nonsrc[0].hint


def test_cross_host_flags_shm_edge_spanning_fragments():
    """ISSUE 7 acceptance: an shm edge may not span fragments — a
    process(shm)-backed pool placed on a remote host is a static error."""
    spec = FlowSpec("shm-span")
    spec.declare_host("box")
    spec.set_output(spec.rollouts(FakePool(backend="process"), host="box"))
    diags = by_rule(analyze(spec), "cross-host-placement")
    span = [d for d in diags if "process-backed" in d.message]
    assert span and span[0].is_error
    assert "cannot span the host boundary" in span[0].message
    assert "thread backend" in span[0].hint


def test_cross_host_flags_server_inference_on_remote_fragment():
    spec = FlowSpec("srv-remote")
    spec.declare_host("box")
    pool = FakePool(local=FakeLocalWorker())
    spec.set_output(spec.rollouts(pool, host="box", inference="server"))
    diags = by_rule(analyze(spec), "cross-host-placement")
    srv = [d for d in diags if "inference='server'" in d.message]
    assert srv and srv[0].is_error and "driver fragment" in srv[0].message


def test_cross_host_warns_on_conflicting_and_dead_placement():
    spec = FlowSpec("host-conflict")
    spec.declare_host("box-a")
    spec.declare_host("box-b")
    spec.declare_host("idle")  # declared, never placed on
    pool = FakePool()
    a = spec.rollouts(pool, host="box-a")
    b = spec.rollouts(pool, host="box-b")  # same pool, different host
    spec.set_output(spec.concurrently([a.for_each(_identity), b.for_each(_identity)]))
    diags = by_rule(analyze(spec), "cross-host-placement")
    conflict = [d for d in diags if "conflicts with" in d.message]
    assert conflict and conflict[0].severity == Severity.WARN
    dead = [d for d in diags if "'idle'" in d.message]
    assert dead and dead[0].severity == Severity.WARN and "dead" in dead[0].message


def test_cross_host_quiet_on_clean_two_fragment_plan():
    spec = FlowSpec("clean-hosts")
    spec.declare_host("box")
    spec.set_output(spec.rollouts(FakePool(), host="box").for_each(_identity))
    assert not by_rule(analyze(spec), "cross-host-placement")


def test_crashing_rule_surfaces_as_analyzer_internal():
    from repro.flow.analysis import rule

    @rule("crashing-rule", "always explodes (test)")
    def _crash(view):
        raise RuntimeError("boom")

    try:
        spec = FlowSpec("crash")
        spec.set_output(spec.from_items([1]))
        [d] = analyze(spec, rules=["crashing-rule"])
        assert d.rule == "analyzer-internal" and d.is_error
        assert "'crashing-rule' crashed" in d.message
    finally:
        del RULES["crashing-rule"]


def test_spec_check_matches_analyze_and_orders_by_severity():
    spec = FlowSpec("ordering")
    s = spec.from_items([1]).for_each(_uses_stdlib_random)
    s.annotate(credits="nope")
    spec.set_output(s)
    diags = spec.check()
    assert diags == analyze(spec)
    ranks = [Severity.rank(d.severity) for d in diags]
    assert ranks == sorted(ranks) and ranks[0] == Severity.rank(Severity.ERROR)


def test_diagnostic_format_and_json_roundtrip():
    d = Diagnostic(
        "credit-deadlock", Severity.ERROR, "msg", node="n1_enqueue",
        edge=("n0_rollouts", 0), hint="fix it", details={"k": 1},
    )
    text = d.format()
    assert "error[credit-deadlock]" in text and "n1_enqueue" in text
    assert "hint: fix it" in text
    js = d.to_json()
    assert js["rule"] == "credit-deadlock" and js["edge"] == ["n0_rollouts", 0]
    assert json.loads(json.dumps(js)) == js


# ------------------------------------- property: the analyzer never crashes
def test_analyzer_never_crashes_on_arbitrary_annotations():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    WEIRD = [
        {}, {"credits": -1}, {"credits": "many"}, {"overflow_policy": "bogus"},
        {"num_learners": 0}, {"microbatch": "k"}, {"failure_policy": "explode"},
        {"vector": "wide"}, {"inference": 17}, {"inference_credits": 0},
        {"resources": {"num_cpus": 10**6}},
    ]

    @hypothesis.given(st.data())
    @hypothesis.settings(max_examples=30, deadline=None)
    def run(data):
        spec = FlowSpec("prop")
        s = spec.from_items(list(range(1 + data.draw(st.integers(0, 2)))))
        for _ in range(data.draw(st.integers(0, 3))):
            op = data.draw(st.sampled_from(["for_each", "filter", "annotate"]))
            if op == "for_each":
                s = s.for_each(_identity)
            elif op == "filter":
                s = s.filter(_identity)
            else:
                s.annotate(**data.draw(st.sampled_from(WEIRD)))
        if data.draw(st.booleans()):
            spec.set_output(s)
        diags = analyze(spec)
        assert all(isinstance(d, Diagnostic) for d in diags)
        assert not [d for d in diags if d.rule == "analyzer-internal"]

    run()


# ---------------------------------------------- the committed plans gate
@pytest.mark.timeout(300)
def test_all_committed_plans_are_error_clean():
    """The regression behind ``scripts/flowcheck.py --all-plans`` in CI."""
    from repro.flow.plans import PLAN_BUILDERS

    results = audit_plans()
    assert set(results) == set(PLAN_BUILDERS)
    errors = {
        name: [d.format() for d in ds if d.is_error]
        for name, ds in results.items()
        if any(d.is_error for d in ds)
    }
    assert errors == {}
    # The three known warns are real findings (blocking learner feeds with
    # credit-unbounded async windows) and double as the docs' example output;
    # pin them so the rule keeps firing on real plans.
    for plan in ("apex", "appo", "impala"):
        assert [d.rule for d in results[plan]] == ["unbounded-queue"], plan


def test_flowcheck_cli_json_output():
    proc = subprocess.run(
        [sys.executable, "scripts/flowcheck.py", "--plan", "a2c", "--json"],
        cwd=REPO, capture_output=True, text=True, timeout=240,
        env={**os.environ, "PYTHONPATH": str(REPO / "src")},
    )
    assert proc.returncode == 0, proc.stderr
    doc = json.loads(proc.stdout)
    assert set(doc["plans"]) == {"a2c"} and doc["failing"] == 0
    assert doc["floor"] == Severity.ERROR


# --------------------------------------------- strict compile + promotion
@pytest.fixture(scope="module")
def pg_workers():
    from repro.core.workers import WorkerSet
    from repro.rl import ActorCriticPolicy, CartPole, RolloutWorker

    def mk(i):
        return RolloutWorker(
            CartPole(), ActorCriticPolicy(4, 2), algo="pg",
            num_envs=2, rollout_len=8, seed=0, worker_index=i,
        )

    ws = WorkerSet.create(mk, 2)
    yield ws
    ws.stop()


def test_strict_compile_rejects_error_diagnostics(pg_workers):
    spec = FlowSpec("strict-static")
    s = spec.rollouts(pg_workers).for_each(_identity)
    s.annotate(credits=3)  # cannot lower on a for_each: error severity
    spec.set_output(s)
    with pytest.raises(FlowAnalysisError) as ei:
        spec.compile(strict=True)
    assert any(d.rule == "annotation-lowering" for d in ei.value.diagnostics)


def test_lowering_fallbacks_promote_to_diagnostics(pg_workers):
    """Satellite: the warn-once compile fallbacks are now Diagnostic objects."""
    spec = FlowSpec("promoted")
    spec.set_output(spec.rollouts(pg_workers).for_each(_identity).learners(2))
    compiled = spec.compile()  # non-strict: lowers, records the degradation
    try:
        fallbacks = by_rule(compiled.diagnostics, "lowering-fallback")
        assert fallbacks and fallbacks[0].is_error
        assert "learner" in fallbacks[0].message
    finally:
        compiled.stop()
    with pytest.raises(FlowAnalysisError):
        spec.compile(strict=True)


def test_algorithm_check_merges_static_and_lowering(pg_workers):
    from repro.flow.algorithm import Algorithm

    spec = FlowSpec("algo-check")
    spec.set_output(spec.rollouts(pg_workers).for_each(_identity).learners(2))
    with Algorithm.from_plan(spec, pg_workers, own_workers=False) as algo:
        rules = {d.rule for d in algo.check()}
    assert {"annotation-lowering", "lowering-fallback"} <= rules


# ------------------------------------------------- shm-lease sanitizer
def _sanitizer_endpoints(prefix):
    from repro.core.transport import ShmReader, ShmWriter

    return ShmWriter(prefix, threshold=1024), ShmReader(prefix)


def _roundtrip(writer, reader):
    from repro.rl.sample_batch import SampleBatch

    batch = SampleBatch({"obs": np.arange(4096, dtype=np.float64)})
    return reader.decode(pickle.loads(pickle.dumps(writer.encode(batch))))


def test_sanitize_enabled_reads_environment(monkeypatch):
    monkeypatch.delenv("TRANSPORT_SANITIZE", raising=False)
    assert not sanitize_enabled()
    for val in ("1", "true", "on"):
        monkeypatch.setenv("TRANSPORT_SANITIZE", val)
        assert sanitize_enabled()
    monkeypatch.setenv("TRANSPORT_SANITIZE", "0")
    assert not sanitize_enabled()


def test_sanitizer_clean_epoch_passes():
    writer, reader = _sanitizer_endpoints("t6clean")
    SANITIZER.begin_epoch("unit:clean")
    try:
        out = _roundtrip(writer, reader)
        np.testing.assert_array_equal(out["obs"], np.arange(4096, dtype=np.float64))
        del out
        gc.collect()
        writer.reclaim(reader.drain_releases())
    finally:
        reader.close()
        writer.close()
    SANITIZER.end_epoch()  # no violations: must not raise


def test_sanitizer_catches_double_release():
    writer, reader = _sanitizer_endpoints("t6dbl")
    SANITIZER.begin_epoch("unit:double-release")
    try:
        out = _roundtrip(writer, reader)
        del out
        gc.collect()
        releases = reader.drain_releases()
        assert releases
        writer.reclaim(releases)
        writer.reclaim(releases)  # the bug reclaim() used to swallow silently
        with pytest.raises(ShmLeaseViolation) as ei:
            SANITIZER.end_epoch()
        assert "released below zero" in str(ei.value)
    finally:
        reader.close()
        writer.close()


def test_sanitizer_catches_unmatched_lease_drop():
    SANITIZER.begin_epoch("unit:unmatched-drop")
    SANITIZER.lease_dropped(object(), "t6ghosts0")
    with pytest.raises(ShmLeaseViolation) as ei:
        SANITIZER.end_epoch()
    assert "no live lease outstanding" in str(ei.value)


def test_sanitizer_catches_leaked_lease():
    writer, reader = _sanitizer_endpoints("t6leak")
    SANITIZER.begin_epoch("unit:leak")
    out = _roundtrip(writer, reader)
    try:
        with pytest.raises(ShmLeaseViolation) as ei:
            SANITIZER.end_epoch()  # the held batch still leases its segment
        assert "leaked lease" in str(ei.value)
    finally:
        del out
        gc.collect()
        writer.reclaim(reader.drain_releases())
        reader.close()
        writer.close()


def test_sanitizer_catches_and_sweeps_leftover_segments():
    from repro.core.transport import _open_shm, list_segments

    shm = _open_shm("t6lefts0", create=True, size=4096)
    shm.buf[:4] = b"dead"
    SANITIZER.begin_epoch("unit:leftover")
    with pytest.raises(ShmLeaseViolation) as ei:
        SANITIZER.end_epoch(prefix="t6left")
    assert "leaked /dev/shm segment: t6lefts0" in str(ei.value)
    # One leak must not cascade into every later test: the epoch swept it.
    assert list_segments("t6left") == []
