"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles, in
interpret mode (CPU container; same code compiles on TPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gmm import moe_gmm_pallas
from repro.kernels.ref import (
    chunked_attention,
    decode_attention_ref,
    moe_gmm_ref,
    naive_attention,
    rwkv6_ref,
)
from repro.kernels.rwkv6 import rwkv6_pallas


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("S,H,KV,D", [(128, 4, 4, 64), (256, 4, 2, 64), (128, 8, 1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(S, H, KV, D, dtype, causal, window):
    B = 2
    q = _rand(0, (B, S, H, D), dtype)
    k = _rand(1, (B, S, KV, D), dtype)
    v = _rand(2, (B, S, KV, D), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=64, block_k=64, interpret=True)
    ref = naive_attention(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("W,H,KV,D", [(256, 8, 2, 64), (512, 4, 4, 128), (128, 8, 8, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(W, H, KV, D, dtype):
    B = 2
    q = _rand(0, (B, 1, H, D), dtype)
    kc = _rand(1, (B, W, KV, D), dtype)
    vc = _rand(2, (B, W, KV, D), dtype)
    valid = jnp.arange(W) < (W * 3) // 4
    out = decode_attention_pallas(q, kc, vc, valid, block_w=64, interpret=True)
    ref = decode_attention_ref(q, kc, vc, valid)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_decode_attention_all_invalid_returns_zeros():
    """Regression: an all-False valid mask used to yield garbage (the online
    softmax saw uniform exp(0) mass over masked slots); empty rows must
    produce exactly zero output in both the oracle and the kernel."""
    B, W, H, KV, D = 2, 128, 4, 2, 64
    q = _rand(0, (B, 1, H, D), jnp.float32)
    kc = _rand(1, (B, W, KV, D), jnp.float32)
    vc = _rand(2, (B, W, KV, D), jnp.float32)
    valid = jnp.zeros((W,), bool)
    ref = decode_attention_ref(q, kc, vc, valid)
    out = decode_attention_pallas(q, kc, vc, valid, block_w=64, interpret=True)
    assert np.asarray(ref).shape == (B, 1, H, D)
    np.testing.assert_array_equal(np.asarray(ref), 0.0)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


@pytest.mark.parametrize("W,H,KV,D", [(128, 4, 2, 64), (256, 8, 8, 64)])
def test_decode_attention_per_sequence_valid(W, H, KV, D):
    """[B, W] ragged masks: each sequence attends its own prefix; one row is
    fully masked (mid-reset lane) and must come back as zeros."""
    B = 4
    q = _rand(0, (B, 1, H, D), jnp.float32)
    kc = _rand(1, (B, W, KV, D), jnp.float32)
    vc = _rand(2, (B, W, KV, D), jnp.float32)
    lengths = jnp.array([W // 4, W, 1, 0])
    valid = jnp.arange(W)[None, :] < lengths[:, None]
    out = decode_attention_pallas(q, kc, vc, valid, block_w=64, interpret=True)
    ref = decode_attention_ref(q, kc, vc, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)
    np.testing.assert_array_equal(np.asarray(out[3]), 0.0)
    # Per-row parity against a single-sequence call with a [W] mask.
    for b in range(B - 1):
        solo = decode_attention_pallas(
            q[b : b + 1], kc[b : b + 1], vc[b : b + 1], valid[b],
            block_w=64, interpret=True,
        )
        np.testing.assert_allclose(
            np.asarray(out[b]), np.asarray(solo[0]), atol=2e-5, rtol=2e-5
        )


def test_decode_attention_shared_valid_broadcasts():
    """A [W] mask must mean the same thing as the equivalent [B, W] mask."""
    B, W, H, KV, D = 3, 128, 4, 4, 64
    q = _rand(0, (B, 1, H, D), jnp.float32)
    kc = _rand(1, (B, W, KV, D), jnp.float32)
    vc = _rand(2, (B, W, KV, D), jnp.float32)
    valid1 = jnp.arange(W) < 77
    valid2 = jnp.broadcast_to(valid1[None], (B, W))
    for fn in (decode_attention_ref, lambda *a: decode_attention_pallas(
            *a, block_w=64, interpret=True)):
        a = np.asarray(fn(q, kc, vc, valid1))
        b = np.asarray(fn(q, kc, vc, valid2))
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("T,H,N,chunk", [(64, 2, 32, 16), (128, 4, 64, 64), (96, 1, 16, 32)])
def test_rwkv6_kernel_sweep(T, H, N, chunk):
    B = 2
    r = _rand(0, (B, T, H, N), jnp.float32) * 0.5
    k = _rand(1, (B, T, H, N), jnp.float32) * 0.5
    v = _rand(2, (B, T, H, N), jnp.float32) * 0.5
    w = jax.nn.sigmoid(_rand(3, (B, T, H, N), jnp.float32)) * 0.5 + 0.5
    u = _rand(4, (H, N), jnp.float32) * 0.1
    out, st = rwkv6_pallas(r, k, v, w, u, chunk=chunk, interpret=True)
    ref_out, ref_st = rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(ref_st), atol=1e-4, rtol=1e-4)


def test_rwkv6_chunked_ref_matches_plain():
    B, T, H, N = 1, 128, 2, 16
    r = _rand(0, (B, T, H, N), jnp.float32)
    k = _rand(1, (B, T, H, N), jnp.float32)
    v = _rand(2, (B, T, H, N), jnp.float32)
    w = jax.nn.sigmoid(_rand(3, (B, T, H, N), jnp.float32))
    u = _rand(4, (H, N), jnp.float32)
    o1, s1 = rwkv6_ref(r, k, v, w, u, chunk=0)
    o2, s2 = rwkv6_ref(r, k, v, w, u, chunk=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)


@pytest.mark.parametrize(
    "sizes,D,F", [([64, 128, 64], 32, 64), ([128, 0, 128, 64], 64, 128)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_sweep(sizes, D, F, dtype):
    E = len(sizes)
    T = sum(sizes)
    x = _rand(0, (T, D), dtype)
    w = _rand(1, (E, D, F), dtype)
    gs = jnp.array(sizes)
    out = moe_gmm_pallas(x, w, gs, block_m=64, block_n=64, interpret=True)
    ref = moe_gmm_ref(x, w, gs)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_chunked_attention_matches_naive():
    B, S, H, KV, D = 1, 160, 4, 2, 32
    q = _rand(0, (B, S, H, D), jnp.float32)
    k = _rand(1, (B, S, KV, D), jnp.float32)
    v = _rand(2, (B, S, KV, D), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, chunk=64)  # non-divisible S
    ref = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)
