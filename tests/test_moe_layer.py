"""MoE layer invariants: routing conservation, capacity drops, aux loss."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig
from repro.models.moe import moe_apply, moe_init


def _cfg(E=4, k=2, cf=8.0, d=64, dff=128):
    return ModelConfig(
        name="t",
        arch_type="moe",
        num_layers=1,
        d_model=d,
        num_heads=2,
        num_kv_heads=2,
        d_ff=dff,
        vocab_size=64,
        block_pattern=(LayerSpec(kind="attn", mlp="moe"),),
        moe=MoEConfig(num_experts=E, top_k=k, d_ff=dff, capacity_factor=cf),
    )


def test_moe_output_shape_and_finite():
    cfg = _cfg()
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), jnp.float32)
    out, aux = moe_apply(params, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0.0


def test_moe_matches_dense_expert_computation():
    """With capacity ample and k=E (all experts selected), the MoE output
    equals the explicitly-computed weighted sum of every expert's FFN."""
    E = 2
    cfg = _cfg(E=E, k=E, cf=float(E) * 2)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model), jnp.float32)
    out, _ = moe_apply(params, x, cfg)

    logits = x @ params["router"]
    w = jax.nn.softmax(logits, axis=-1)  # renormalized top-E == softmax
    expected = jnp.zeros_like(x)
    for e in range(E):
        h = x @ params["up"][e]
        h = jax.nn.silu(x @ params["gate"][e]) * h
        y = h @ params["down"][e]
        expected = expected + w[..., e : e + 1] * y
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), atol=1e-4, rtol=1e-3)


def test_moe_capacity_drops_tokens():
    """With capacity 1 and many tokens per row, most contributions drop —
    output magnitude shrinks but stays finite."""
    cfg = _cfg(E=2, k=1, cf=0.01)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model), jnp.float32)
    out, _ = moe_apply(params, x, cfg)
    assert np.isfinite(np.asarray(out)).all()
    # at most E*C = 2 tokens can have nonzero output
    nonzero_rows = np.abs(np.asarray(out[0])).sum(-1) > 1e-6
    assert nonzero_rows.sum() <= 2


def test_moe_shared_experts_always_active():
    cfg = _cfg(E=4, k=1, cf=0.01)
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, num_shared=1))
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model), jnp.float32)
    out, _ = moe_apply(params, x, cfg)
    # Shared expert path gives every token nonzero output despite drops.
    nonzero_rows = np.abs(np.asarray(out[0])).sum(-1) > 1e-6
    assert nonzero_rows.all()


@given(st.integers(min_value=1, max_value=4), st.integers(min_value=4, max_value=24))
@settings(max_examples=10, deadline=None)
def test_moe_gradients_finite(k, S):
    cfg = _cfg(E=4, k=k, cf=4.0)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, cfg.d_model), jnp.float32)

    def loss(p):
        out, aux = moe_apply(p, x, cfg)
        return jnp.sum(out**2) + aux

    grads = jax.grad(loss)(params)
    for g in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(g, np.float32)).all()
