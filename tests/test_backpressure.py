"""Credit-based backpressure + data-plane instrumentation (ISSUE 3).

Covers the fix satellite — ``gather_async`` and the learner feed are now
credit-bounded instead of open-loop — and the observability contract: credit
stalls, drops, bytes moved, queue occupancy, and sample->learn latency all
reach ``Algorithm.train()`` results and ``to_dot()`` edge labels.
"""

import queue
import threading
import time

import numpy as np
import pytest

import chaos
import repro.flow as flow
from repro.core import CreditPool, Enqueue, WorkerSet
from repro.core.concurrency import OverflowPolicy
from repro.core.iterators import from_iterators
from repro.core.metrics import (
    CREDIT_STALL_TIME,
    NUM_BYTES_MOVED,
    NUM_CREDIT_STALLS,
    NUM_SAMPLES_DROPPED,
    MetricsContext,
    set_metrics_for_thread,
)
from repro.flow.spec import FlowSpec
from repro.rl.replay import ReplayBuffer
from repro.rl.sample_batch import SampleBatch


# --------------------------------------------------------------- CreditPool
def test_credit_pool_bounds_and_resizes():
    pool = CreditPool(2)
    assert pool.try_acquire() and pool.try_acquire()
    assert not pool.try_acquire()
    pool.release()
    assert pool.try_acquire()
    pool.resize(None)  # unbounded
    assert all(pool.try_acquire() for _ in range(64))
    with pytest.raises(ValueError):
        CreditPool(0)


def test_overflow_policy_validation():
    for p in ("block", "drop_newest", "drop_oldest"):
        assert OverflowPolicy.validate(p) == p
    with pytest.raises(ValueError, match="unknown overflow policy"):
        OverflowPolicy.validate("explode")


# ------------------------------------------------------------- gather_async
@pytest.mark.timeout(60)
def test_gather_async_credits_cap_inflight():
    """With credits=1 over two shards, at most one item is dispatched at a
    time and both shards still make progress (FIFO backfill fairness)."""
    par = from_iterators([iter(range(0, 100)), iter(range(100, 200))])
    it = par.gather_async(num_async=2, credits=1, metrics_key="g")
    got = it.take(40)
    assert len(got) == 40
    assert {x // 100 for x in got} == {0, 1}, "a starved shard never ran"
    # The credit window stalled dispatches and said so.
    assert it.metrics.counters[NUM_CREDIT_STALLS] > 0
    # Per-shard FIFO order survives credit arbitration.
    for branch in (0, 1):
        seq = [x for x in got if x // 100 == branch]
        assert seq == sorted(seq)


@pytest.mark.timeout(60)
def test_gather_async_default_credits_match_legacy_window():
    """Default credits (num_async * shards) must not change the stream."""
    par = from_iterators([iter(range(10)), iter(range(10, 20))])
    got = par.gather_async(num_async=2).take(20)
    assert sorted(got) == list(range(20))


@pytest.mark.timeout(60)
def test_gather_async_credit_stall_time_accrues():
    """A slow consumer against a tight window accrues credit_stall_time."""
    par = from_iterators([iter(range(50)), iter(range(100, 150))])
    it = par.gather_async(num_async=1, credits=1)
    out = []
    for x in iter(it):
        time.sleep(0.002)  # slow consumer
        out.append(x)
        if len(out) >= 20:
            break
    assert it.metrics.counters.get(CREDIT_STALL_TIME, 0) > 0


# ----------------------------------------------------------------- Enqueue
def _ctx():
    m = MetricsContext()
    set_metrics_for_thread(m)
    return m


def test_enqueue_drop_newest_counts_drops():
    m = _ctx()
    q = queue.Queue(maxsize=2)
    enq = Enqueue(q, policy="drop_newest", metrics_key="k")
    for i in range(5):
        assert enq(i) == i
    assert q.qsize() == 2
    assert enq.num_dropped == 3
    assert m.counters[NUM_SAMPLES_DROPPED] == 3
    assert [q.get(), q.get()] == [0, 1]


def test_enqueue_drop_oldest_keeps_freshest():
    m = _ctx()
    q = queue.Queue(maxsize=2)
    enq = Enqueue(q, policy="drop_oldest")
    for i in range(5):
        enq(i)
    assert [q.get(), q.get()] == [3, 4]
    assert enq.num_dropped == 3
    assert m.counters[NUM_SAMPLES_DROPPED] == 3


@pytest.mark.timeout(60)
def test_enqueue_block_stalls_and_records():
    m = _ctx()
    q = queue.Queue(maxsize=1)
    enq = Enqueue(q, policy="block", check=lambda: True)
    enq(0)

    drained = []

    def _drain():
        time.sleep(0.05)
        drained.append(q.get())
        drained.append(q.get())

    t = threading.Thread(target=_drain)
    t.start()
    enq(1)  # must block until the consumer frees a slot
    t.join()
    assert drained == [0, 1]
    assert m.counters[NUM_CREDIT_STALLS] >= 1
    assert m.counters.get(CREDIT_STALL_TIME, 0) > 0
    assert enq.num_dropped == 0


def test_enqueue_legacy_block_flag_still_works():
    q = queue.Queue(maxsize=4)
    assert Enqueue(q, block=False).policy == "drop_newest"
    assert Enqueue(q, block=True).policy == "block"
    with pytest.raises(ValueError, match="not both"):
        Enqueue(q, block=True, policy="drop_oldest")


def test_enqueue_records_bytes_and_occupancy():
    m = _ctx()
    q = queue.Queue(maxsize=8)
    enq = Enqueue(q, policy="drop_newest", metrics_key="feed")
    batch = SampleBatch({"obs": np.zeros(1024, np.float64)})
    enq(batch)
    assert m.counters["bytes_moved/feed"] == batch.size_bytes()
    assert m.gauges["queue_occupancy/feed"] == 1


def test_enqueue_stamps_queue_wait():
    _ctx()
    q = queue.Queue(maxsize=8)
    batch = SampleBatch({"obs": np.zeros(8, np.float64)})
    Enqueue(q, policy="drop_newest")((batch, None))
    assert isinstance(batch._enqueued_at, float)


# ----------------------------------------------- flow-level integration
def stub_ws(n=2):
    return WorkerSet.create(chaos.make_stub_worker, n)


def replay_pool(n=1):
    from repro.core.actor import ActorPool

    return ActorPool.from_targets(
        [ReplayBuffer(capacity=4096, sample_batch_size=16, learning_starts=16, seed=i)
         for i in range(n)],
        name="replay",
    )


@pytest.mark.timeout(120)
def test_apex_drop_counts_reach_train_results():
    """Fix satellite acceptance: the lossy Ape-X feed (drop_newest) surfaces
    ``num_samples_dropped`` in Algorithm.train() results, and the learner
    latency stream (sample_to_learn p50/p99) is populated."""
    ws = stub_ws(2)
    replay = replay_pool(1)
    algo = flow.Algorithm.from_plan(
        "apex", ws, replay,
        target_update_freq=10_000,
        block_on_enqueue=False,
    )
    # Shrink the learner in-queue so drops actually happen.
    algo.resources["learner"].inqueue.maxsize = 1
    deadline = time.time() + 60
    result = algo.train()
    while time.time() < deadline:
        result = algo.train()
        if (
            result["counters"].get(NUM_SAMPLES_DROPPED, 0) > 0
            and result["latencies"].get("sample_to_learn_s", {}).get("count", 0) > 0
        ):
            break
    assert result["counters"][NUM_SAMPLES_DROPPED] > 0
    lat = result["latencies"]["sample_to_learn_s"]
    assert lat["count"] > 0
    assert 0 <= lat["p50"] <= lat["p99"]
    assert result["counters"][NUM_BYTES_MOVED] > 0
    algo.stop()


@pytest.mark.timeout(120)
def test_enqueue_policy_annotation_lowered():
    """An ``overflow_policy`` annotation on the enqueue node wins at
    lowering time (FlowSpec -> compile -> Enqueue policy)."""
    ws = stub_ws(1)
    spec = FlowSpec("annotated")
    learner = spec.learner_thread(ws)
    feed = (
        spec.rollouts(ws, mode="async", num_async=1)
        .enqueue(learner, block=True)
        .annotate(overflow_policy="drop_oldest")
    )
    out = spec.dequeue(learner).for_each(flow.pure(lambda item: item[1].count), label="count")
    spec.set_output(spec.concurrently([feed, out], mode="async", output_indexes=[1]))
    compiled = spec.compile()
    enq_nodes = [n for n in compiled.spec.nodes.values() if n.kind == "enqueue"]
    assert enq_nodes and enq_nodes[0].annotations["overflow_policy"] == "drop_oldest"
    algo = flow.Algorithm(compiled, ws)
    assert algo.train() == 8  # StubWorker batch size
    algo.stop()


@pytest.mark.timeout(120)
def test_credits_annotation_lowered_and_visible():
    """credits= on spec.rollouts caps the async gather; train still works
    and credit telemetry appears in results."""
    ws = stub_ws(2)
    spec = FlowSpec("credited")
    out = spec.rollouts(ws, mode="async", num_async=2, credits=1).for_each(
        flow.pure(lambda b: b.count), label="count"
    )
    spec.set_output(out)
    algo = flow.Algorithm.from_plan(spec, ws)
    results = algo.iterate(12)
    assert all(r == 8 for r in results)
    assert algo.compiled.iterator().metrics.counters[NUM_CREDIT_STALLS] > 0
    algo.stop()


@pytest.mark.timeout(120)
def test_to_dot_edge_labels_carry_bytes():
    """to_dot(with_metrics=True) labels data-plane edges with bytes moved."""
    ws = stub_ws(2)
    spec = FlowSpec("dotted")
    out = spec.rollouts(ws, mode="async", num_async=1).for_each(
        flow.pure(lambda b: b.count), label="count"
    )
    spec.set_output(out.report(ws))
    algo = flow.Algorithm.from_plan(spec, ws)
    bare = algo.to_dot()
    assert "KB" not in bare and "MB" not in bare
    algo.iterate(6)
    dot = algo.to_dot(with_metrics=True)
    assert any(unit in dot for unit in ("KB", "MB", "B\"")), dot
    algo.stop()


@pytest.mark.timeout(120)
def test_train_results_include_gauges_and_latencies_sections():
    ws = stub_ws(2)
    algo = flow.Algorithm.from_plan("a3c", ws)
    result = algo.train()
    assert "gauges" in result and "latencies" in result
    algo.stop()


@pytest.mark.timeout(120)
def test_learner_out_queue_drop_oldest_policy():
    """The learner out-queue honors drop_oldest: metrics stream stays fresh
    instead of stale-first."""
    from repro.core.learner_thread import LearnerThread

    lt = LearnerThread(chaos.StubWorker(0), out_queue_size=2, out_policy="drop_oldest")
    for i in range(5):
        lt._put_out((None, None, i))
    assert lt.outqueue.qsize() == 2
    assert lt.outqueue.get()[2] == 3
    assert lt.outqueue.get()[2] == 4
    assert lt.num_out_dropped == 3
