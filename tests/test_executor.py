"""Executor runtime: the rollout-worker matrix under every backend.

The same deterministic rollout-worker protocol suite (sampling in every
gather mode, weight sync, gradient paths, supervision, elasticity) runs
under ``ThreadBackend`` and ``ProcessBackend`` via a parametrized fixture
and must produce *identical* results (ISSUE 2 acceptance)."""

import time

import numpy as np
import pytest

import chaos
from conftest import BACKEND_MATRIX, make_backend
from repro.core import (
    ActorDiedError,
    FailurePolicy,
    ProcessBackend,
    ThreadBackend,
    VirtualActor,
    WorkerSet,
    resolve_backend,
)
from repro.core.metrics import (
    NUM_SAMPLES_DROPPED,
    NUM_SHARDS_DROPPED,
    NUM_WORKER_FAILURES,
    MetricsContext,
    set_metrics_for_thread,
)
from repro.core.operators import ParallelRollouts, par_compute_gradients

# thread / process+pickle / process+shm: the protocol suite must be
# transport-independent (ISSUE 3).
BACKENDS = BACKEND_MATRIX


@pytest.fixture(params=BACKENDS)
def backend(request):
    return make_backend(request.param)


def make_ws(backend, n=2, **supervision):
    return WorkerSet.create(chaos.make_stub_worker, n, backend=backend, **supervision)


def obs_bases(batches):
    """Map each StubWorker batch back to (worker_index, nth_sample)."""
    out = []
    for b in batches:
        first = int(np.asarray(b["obs"])[0])
        out.append((first // 10_000_000, (first % 10_000_000) // 100))
    return out


# ---------------------------------------------------------------- the matrix
def test_backend_resolution():
    assert isinstance(resolve_backend(None), ThreadBackend)
    assert isinstance(resolve_backend("process"), ProcessBackend)
    b = ProcessBackend()
    assert resolve_backend(b) is b
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("gpu")


@pytest.mark.parametrize("mode", ["bulk_sync", "async", "raw_sync", "raw_batch"])
def test_rollout_matrix_identical_across_backends(mode):
    """Acceptance: every rollout mode yields the same stream under both
    backends (async mode modulo completion order)."""

    def run(backend):
        ws = make_ws(backend, n=2)
        try:
            if mode == "raw_sync":
                it = ParallelRollouts(ws, mode="raw").gather_sync()
                return [obs_bases([b])[0] for b in it.take(6)]
            if mode == "raw_batch":
                it = ParallelRollouts(ws, mode="raw").batch_across_shards()
                return [obs_bases(bs) for bs in it.take(3)]
            if mode == "bulk_sync":
                it = ParallelRollouts(ws, mode="bulk_sync")
                # Concatenated across shards per round: totals are exact.
                return [int(np.asarray(b["obs"]).sum()) for b in it.take(3)]
            it = ParallelRollouts(ws, mode="async", num_async=1)
            return obs_bases(it.take(6))
        finally:
            ws.stop()

    outs = [run(make_backend(p)) for p in BACKENDS]
    thread_out = outs[0]
    if mode != "async":
        for other in outs[1:]:
            assert thread_out == other
    else:
        # Async completion order is scheduling-dependent; the invariant
        # (identical under every backend/transport) is per-shard FIFO over
        # the same worker set with nothing lost or duplicated.
        for got in outs:
            assert len(got) == 6 and {w for w, _ in got} <= {1, 2}
            for w in (1, 2):
                seq = [k for wi, k in got if wi == w]
                assert seq == list(range(1, len(seq) + 1))


def test_rollout_matrix_expected_values(backend):
    """The stream is the *correct* deterministic stream, not just consistent:
    barrier gather round r yields workers 1..N each on their rth sample."""
    ws = make_ws(backend, n=2)
    it = ParallelRollouts(ws, mode="raw").gather_sync()
    assert obs_bases(it.take(6)) == [(1, 1), (2, 1), (1, 2), (2, 2), (1, 3), (2, 3)]
    ws.stop()


def test_weight_sync_roundtrip(backend):
    ws = make_ws(backend, n=2)
    ws.local_worker().set_weights(np.array([3.0, 4.0], np.float32))
    ws.sync_weights()
    for a in ws.remote_workers():
        np.testing.assert_array_equal(
            a.sync("get_weights"), np.array([3.0, 4.0], np.float32)
        )
    ws.stop()


def test_gradient_path(backend):
    """A2C-shaped path: per-worker grads -> barrier -> apply on local."""
    ws = make_ws(backend, n=2)
    rounds = par_compute_gradients(ws).batch_across_shards().take(2)
    for grads_infos in rounds:
        assert len(grads_infos) == 2
        for grads, info in grads_infos:
            ws.local_worker().apply_gradients(grads)
            assert info["batch_count"] == 8
    assert not np.array_equal(ws.local_worker().get_weights(), np.zeros(2))
    ws.stop()


def test_learn_on_batch_path(backend):
    ws = make_ws(backend, n=1)
    batch = ws.remote_workers()[0].sync("sample")
    info = ws.local_worker().learn_on_batch(batch)
    assert info["trained"] == 8
    ws.stop()


# ------------------------------------------------------------- supervision
def test_restart_policy_keeps_shard(backend):
    """A worker failing once under max_restarts keeps its shard: the item is
    lost, the stream continues, and the failure is counted."""
    factory = chaos.ChaosFactory(
        chaos.make_stub_worker, {1: [chaos.RaiseOnNth("sample", n=2)]}
    )
    # A restart rebuilds the injector (fresh counts), so every incarnation
    # fails on its 2nd sample; a large budget keeps the shard alive forever.
    ws = WorkerSet.create(
        factory, 2, backend=backend,
        max_restarts=100, backoff_base=0.0, failure_policy="restart",
    )
    metrics = MetricsContext()
    set_metrics_for_thread(metrics)
    it = ParallelRollouts(ws, mode="async", num_async=1)
    it.metrics = metrics
    got = obs_bases(it.take(8))
    # Both workers (re)join the stream after the injected failures; keep
    # pulling past spawn/restart latency until both have contributed and
    # worker 1's 2nd-call fault has actually fired.
    deadline = time.time() + 20
    while (
        {w for w, _ in got} != {1, 2} or metrics.counters[NUM_WORKER_FAILURES] == 0
    ) and time.time() < deadline:
        got += obs_bases(it.take(1))
    assert {w for w, _ in got} == {1, 2}
    assert metrics.counters[NUM_WORKER_FAILURES] >= 1
    assert metrics.counters[NUM_SHARDS_DROPPED] == 0
    [a1] = [a for a in ws.remote_workers() if a.name == "rollout-1"]
    assert a1.num_restarts >= 1 and a1.alive
    ws.stop()


def test_drop_shard_policy_shrinks_stream(backend):
    """A sticky failure under drop_shard removes the shard; survivors keep
    producing and the drop is recorded."""
    factory = chaos.ChaosFactory(
        chaos.make_stub_worker, {1: [chaos.RaiseOnNth("sample", n=3, sticky=True)]}
    )
    ws = WorkerSet.create(factory, 2, backend=backend, failure_policy="drop_shard")
    metrics = MetricsContext()
    set_metrics_for_thread(metrics)
    it = ParallelRollouts(ws, mode="async", num_async=1)
    it.metrics = metrics
    got = obs_bases(it.take(12))
    # Keep pulling past process-spawn latency until the sticky fault fires
    # and the shard is dropped.
    deadline = time.time() + 20
    while metrics.counters[NUM_SHARDS_DROPPED] == 0 and time.time() < deadline:
        got += obs_bases(it.take(1))
    assert metrics.counters[NUM_SHARDS_DROPPED] == 1
    assert metrics.counters[NUM_WORKER_FAILURES] >= 1
    # Worker 1 contributed at most its pre-fault samples; the tail is all
    # worker 2 (shard 1 gone for good).
    got += obs_bases(it.take(4))
    assert [w for w, _ in got].count(1) <= 2
    assert [w for w, _ in got][-4:] == [2, 2, 2, 2]
    ws.stop()


def test_raise_policy_propagates(backend):
    factory = chaos.ChaosFactory(
        chaos.make_stub_worker, {1: [chaos.RaiseOnNth("sample", n=1, exc=ValueError)]}
    )
    ws = WorkerSet.create(factory, 1, backend=backend)  # default: raise
    it = ParallelRollouts(ws, mode="async")
    with pytest.raises(ValueError, match="chaos"):
        it.take(2)
    ws.stop()


def test_restart_budget_exhaustion_drops_shard(backend):
    """Sticky fault + restart policy: the supervisor burns its budget, the
    actor dies, and the gather loop degrades to dropping the shard."""
    factory = chaos.ChaosFactory(
        chaos.make_stub_worker, {1: [chaos.RaiseOnNth("sample", n=1, sticky=True)]}
    )
    ws = WorkerSet.create(
        factory, 2, backend=backend,
        max_restarts=2, backoff_base=0.0, failure_policy="restart",
    )
    metrics = MetricsContext()
    set_metrics_for_thread(metrics)
    it = ParallelRollouts(ws, mode="async", num_async=1)
    it.metrics = metrics
    got = obs_bases(it.take(8))
    assert {w for w, _ in got} == {2}
    # Process restarts take real time: keep pulling until the supervisor
    # exhausts the budget and the gather loop drops the shard.
    deadline = time.time() + 20
    while metrics.counters[NUM_SHARDS_DROPPED] == 0 and time.time() < deadline:
        got += obs_bases(it.take(1))
    assert metrics.counters[NUM_SHARDS_DROPPED] == 1
    [a1] = [a for a in ws.remote_workers() if a.name == "rollout-1"]
    assert not a1.alive and a1.num_restarts == 2
    assert ws.num_healthy_workers() == 1
    ws.stop()


def test_recover_heals_dead_worker(backend):
    factory = chaos.ChaosFactory(
        chaos.make_stub_worker, {1: [chaos.RaiseOnNth("sample", n=1, sticky=True)]}
    )
    ws = WorkerSet.create(
        factory, 2, backend=backend,
        max_restarts=1, backoff_base=0.0, failure_policy="restart",
    )
    it = ParallelRollouts(ws, mode="async", num_async=1)
    it.take(6)
    deadline = time.time() + 20
    while ws.num_healthy_workers() == 2 and time.time() < deadline:
        it.take(1)
    assert ws.num_healthy_workers() == 1
    report = ws.recover()
    assert report["restarted"] or report["replaced"]
    assert ws.num_healthy_workers() == 2
    # The healed worker REJOINS the already-running stream (its "dead" drop
    # is pruned): it fails again on its fresh injector's 2nd call, burns the
    # budget again, dies again — proving it was actually re-dispatched.
    [a1] = [a for a in ws.remote_workers() if a.name == "rollout-1"]
    deadline = time.time() + 20
    while a1.alive and time.time() < deadline:
        it.take(1)
    assert not a1.alive, "recovered worker never rejoined the live stream"
    ws.stop()


def test_kill_and_dead_futures(backend):
    ws = make_ws(backend, n=2)
    victim = ws.remote_workers()[0]
    victim.kill()
    assert not victim.alive
    with pytest.raises(ActorDiedError):
        victim.call("sample").result(timeout=5)
    assert ws.num_healthy_workers() == 1
    # sync_weights skips the corpse instead of raising.
    ws.sync_weights()
    ws.stop()


# --------------------------------------------------------------- elasticity
def test_elastic_add_workers_mid_stream(backend):
    ws = make_ws(backend, n=2)
    it = ParallelRollouts(ws, mode="async", num_async=1)
    first = obs_bases(it.take(4))
    assert {w for w, _ in first} <= {1, 2}
    ws.add_workers(1)
    later = []
    deadline = time.time() + 20
    while 3 not in {w for w, _ in later} and time.time() < deadline:
        later += obs_bases(it.take(1))
    assert 3 in {w for w, _ in later}, "new worker never joined the stream"
    ws.stop()


def test_elastic_remove_workers_mid_stream(backend):
    ws = make_ws(backend, n=3)
    it = ParallelRollouts(ws, mode="async", num_async=1)
    it.take(6)
    removed = ws.remove_workers(1)
    assert removed == ["rollout-3"]
    tail = obs_bases(it.take(10))
    # Removed worker contributes at most its already-in-flight item.
    assert [w for w, _ in tail].count(3) <= 1
    assert {1, 2} <= {w for w, _ in tail}
    ws.stop()


def test_remove_workers_keeps_at_least_one(backend):
    ws = make_ws(backend, n=1)
    with pytest.raises(ValueError, match="at least one"):
        ws.remove_workers(1)
    ws.stop()


# ------------------------------------------------------------ misc plumbing
def test_enqueue_drop_counts_surface_in_metrics():
    """Satellite: Enqueue drops land in the shared metrics context."""
    import queue

    from repro.core import Enqueue

    metrics = MetricsContext()
    set_metrics_for_thread(metrics)
    q = queue.Queue(maxsize=1)
    enq = Enqueue(q, block=False)
    for i in range(3):
        assert enq(i) == i
    assert enq.num_dropped == 2
    assert metrics.counters[NUM_SAMPLES_DROPPED] == 2
    set_metrics_for_thread(None)


def test_virtual_actor_argument_validation():
    with pytest.raises(ValueError, match="exactly one"):
        VirtualActor()
    with pytest.raises(ValueError, match="exactly one"):
        VirtualActor(object(), factory=object)
    with pytest.raises(ValueError, match="factory"):
        VirtualActor(object(), max_restarts=1)
    with pytest.raises(ValueError, match="unknown failure policy"):
        VirtualActor(object(), failure_policy="retry")


def test_process_backend_requires_picklable_factory():
    with pytest.raises(Exception):
        VirtualActor(factory=lambda: object(), backend="process")


def test_failure_policy_validation():
    assert FailurePolicy.validate("drop_shard") == "drop_shard"
    with pytest.raises(ValueError):
        FailurePolicy.validate("explode")
