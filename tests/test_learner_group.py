"""Sharded SPMD learner group (ISSUE 4): microbatch accumulation parity,
batch sharding at the transport boundary, FlowSpec annotation lowering, and
the 4-device simulated-mesh loss-parity acceptance gate (subprocess)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as c
from repro.core.learner_thread import LearnerThread
from repro.core.operators import TrainOneStep
from repro.flow import Algorithm, FlowSpec, build_ppo
from repro.rl import (
    ActorCriticPolicy,
    CartPole,
    DQNPolicy,
    RolloutWorker,
    SampleBatch,
    ShardedLearnerGroup,
)

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def make_worker(algo="ppo", seed=7):
    policy = (
        DQNPolicy(4, 2) if algo == "dqn"
        else ActorCriticPolicy(4, 2, loss_kind=algo)
    )
    return RolloutWorker(
        CartPole(), policy, algo=algo, num_envs=4, rollout_len=32,
        seed=seed, worker_index=0,
    )


def max_param_diff(a, b):
    return max(
        float(jnp.max(jnp.abs(x - y)))
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


# ---------------------------------------------------------- microbatch parity
def test_microbatch_accumulation_matches_full_batch():
    """Mean-gradient accumulation over k slices == one full-batch update."""
    batch = make_worker().sample()
    w_plain = make_worker()
    info_plain = w_plain.learn_on_batch(batch)

    w_micro = make_worker()
    group = ShardedLearnerGroup(w_micro, num_learners=1, microbatch=4)
    info_micro = group.learn_on_batch(batch)

    assert abs(info_plain["loss"] - info_micro["loss"]) < 1e-4
    assert max_param_diff(w_plain.params, w_micro.params) < 1e-4
    assert info_micro["microbatch"] == 4
    assert group.num_steps == 1


def test_dqn_td_error_survives_microbatching():
    """Per-row aux columns must flatten back out, not average away."""
    w = make_worker("dqn")
    batch = w.sample()
    group = ShardedLearnerGroup(make_worker("dqn"), num_learners=1, microbatch=2)
    info = group.learn_on_batch(batch)
    assert info["td_error"].shape == (batch.count,)


def test_group_keeps_worker_canonical():
    """After a sharded step the worker's own weights are the fresh ones."""
    w = make_worker()
    group = ShardedLearnerGroup(w, num_learners=1, microbatch=2)
    before = jax.tree_util.tree_map(jnp.array, w.params)
    group.learn_on_batch(w.sample())
    assert max_param_diff(before, w.params) > 0
    # set_weights re-replicates onto the mesh and the next step still runs.
    group.set_weights(before)
    group.learn_on_batch(w.sample())


def test_shard_batch_trims_ragged_rows():
    w = make_worker()
    group = ShardedLearnerGroup(w, num_learners=1, microbatch=4)
    ragged = SampleBatch({"obs": np.zeros((130, 4), np.float32)})
    cols, usable = group.shard_batch(ragged)
    assert usable == 128
    assert group.num_rows_trimmed == 2
    assert cols["obs"].shape == (4, 32, 4)  # [k, rows/k, ...]
    with pytest.raises(ValueError):
        group.shard_batch(SampleBatch({"obs": np.zeros((3, 4), np.float32)}))


def test_sample_batch_shard_views():
    b = SampleBatch({"obs": np.arange(12).reshape(6, 2)})
    shards = b.shard(3)
    assert [s.count for s in shards] == [2, 2, 2]
    np.testing.assert_array_equal(shards[1]["obs"], [[4, 5], [6, 7]])
    with pytest.raises(ValueError):
        b.shard(5)
    with pytest.raises(ValueError):
        b.shard(0)


def test_vtrace_trace_aligned_tiling():
    """Trace-structured losses: microbatch slices must hold whole length-T
    traces, and tail-trimming must not cut mid-trace."""
    def mk_vtrace():
        return RolloutWorker(
            CartPole(), ActorCriticPolicy(4, 2, loss_kind="vtrace", rollout_len=16),
            algo="vtrace", num_envs=4, rollout_len=16, seed=9, worker_index=0,
        )

    w = mk_vtrace()
    group = ShardedLearnerGroup(w, num_learners=1, microbatch=2)
    assert group.trace_len == 16
    batch = w.sample()  # 64 rows = 4 contiguous traces of 16
    info = group.learn_on_batch(batch)  # 32-row microbatches: 2 whole traces
    assert np.isfinite(info["loss"])
    # Ragged rows trim in whole-trace units: tile = k * lcm(n, T) = 32.
    ragged = SampleBatch({"obs": np.zeros((70, 4), np.float32)})
    _, usable = group.shard_batch(ragged)
    assert usable == 64


def test_sac_polyak_target_tracks_in_sharded_path():
    from repro.rl import Pendulum, SACPolicy

    def mk_sac():
        return RolloutWorker(
            Pendulum(), SACPolicy(3, 1), algo="sac", num_envs=2, rollout_len=8,
            seed=5, worker_index=0, target_polyak=0.05,
        )

    w = mk_sac()
    group = ShardedLearnerGroup(w, num_learners=1, microbatch=2)
    target_before = jax.tree_util.tree_map(jnp.array, w.target_params)
    group.learn_on_batch(w.sample())
    assert max_param_diff(target_before, w.target_params) > 0


def test_td_error_padded_to_full_batch_after_trim():
    """Consumers zip td_error with the full batch (UpdateReplayPriorities
    against batch_indices): trimmed rows must be padded back, neutrally."""
    w = make_worker("dqn")
    group = ShardedLearnerGroup(make_worker("dqn"), num_learners=1, microbatch=4)
    full = w.sample()
    ragged = full.slice(0, 126)  # tile=4 -> 124 usable, 2 trimmed
    info = group.learn_on_batch(ragged)
    assert info["td_error"].shape == (126,)
    trained = np.abs(info["td_error"][:124])
    np.testing.assert_allclose(info["td_error"][124:], np.mean(trained))


# ------------------------------------------------------- annotation lowering
class FakeTrain:
    """Stand-in train operator exposing the learner-group knobs."""

    flow_pure = True
    share_across_shards = True

    def __init__(self):
        self.num_learners = 0
        self.microbatch = 0

    def __call__(self, item):
        return (self.num_learners, self.microbatch)


def test_learners_annotation_lowered_onto_train_stage():
    spec = FlowSpec("t")
    out = spec.from_items([1, 2]).for_each(FakeTrain()).learners(3).microbatch(2)
    spec.set_output(out)
    compiled = spec.compile()
    assert compiled.take(1) == [(3, 2)]
    # The builder-side operator instance is untouched (compile deep-copies).
    assert spec.nodes[out.node_id].annotations == {"num_learners": 3, "microbatch": 2}


def test_learners_annotation_survives_fusion():
    spec = FlowSpec("t")
    out = (
        spec.from_items([1, 2])
        .for_each(lambda x: x, label="id")
        .for_each(FakeTrain())
        .learners(2)
    )
    spec.set_output(out)
    assert spec.compile(fuse=True).take(1) == [(2, 0)]


def test_learners_annotation_warns_without_capable_stage(caplog):
    spec = FlowSpec("t")
    out = spec.from_items([1]).for_each(lambda x: x, label="id").learners(2)
    spec.set_output(out)
    with caplog.at_level("WARNING"):
        spec.compile(fuse=False).take(1)
    assert any("learners/microbatch" in r.message for r in caplog.records)


def test_learners_annotation_on_parallel_node_warns(caplog):
    """learners()/microbatch() only lower onto *local* train stages; a
    parallel for_each carrying them must say so instead of silently
    training single-device."""
    def mk(i):
        return make_worker(seed=13)

    ws = c.WorkerSet.create(mk, 1)
    try:
        spec = FlowSpec("t")
        out = (
            spec.rollouts(ws, mode="raw")
            .for_each(FakeTrain())
            .learners(4)
            .gather_sync()
        )
        spec.set_output(out)
        with caplog.at_level("WARNING"):
            spec.compile(fuse=False)
        assert any("parallel" in r.message for r in caplog.records)
    finally:
        ws.stop()


def test_learners_annotation_validates():
    spec = FlowSpec("t")
    s = spec.from_items([1]).for_each(lambda x: x)
    with pytest.raises(ValueError):
        s.learners(0)
    with pytest.raises(ValueError):
        s.microbatch(0)


def test_train_one_step_direct_kwargs():
    def mk(i):
        return make_worker(seed=11)

    ws = c.WorkerSet.create(mk, 1)
    step = TrainOneStep(ws, microbatch=2)
    batch, info = step(ws.local_worker().sample())
    assert info["microbatch"] == 2
    assert info["num_learners"] == 1
    ws.stop()


def test_learner_thread_builds_group():
    lt = LearnerThread(make_worker(), num_learners=1, microbatch=2)
    assert lt.learner_group is not None
    assert lt.learner_group.microbatch == 2
    lt_plain = LearnerThread(make_worker())
    assert lt_plain.learner_group is None


# ------------------------------------------------------------ end-to-end flow
@pytest.mark.timeout(120)
def test_ppo_plan_with_sharded_learner_end_to_end():
    def mk(i):
        return RolloutWorker(
            CartPole(), ActorCriticPolicy(4, 2, loss_kind="ppo"), algo="ppo",
            num_envs=2, rollout_len=16, seed=3, worker_index=i,
        )

    ws = c.WorkerSet.create(mk, 2)
    with Algorithm.from_plan(
        build_ppo(
            ws, train_batch_size=64, num_sgd_iter=1, sgd_minibatch_size=0,
            microbatch=2,
        ),
        ws,
    ) as algo:
        # Multiple iterations on purpose: iteration N+1 samples on remote
        # workers holding weight refs broadcast after iteration N, which
        # regresses the donated-params aliasing crash (thread-backend
        # sync_weights shares param buffers by reference).
        for _ in range(3):
            result = algo.train()
    info = result["info"]
    assert info["microbatch"] == 2
    assert np.isfinite(info["loss"])


# ------------------------------------------- 4-device parity acceptance gate
@pytest.mark.timeout(300)
def test_four_device_mesh_loss_parity():
    """ISSUE 4 acceptance: 4-device simulated-mesh learner reaches loss and
    parameter parity (atol 1e-4) with the single-device path at equal global
    batch, with and without microbatch accumulation."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "sharded_parity_child.py")],
        env=env, capture_output=True, text=True, timeout=280,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    row = json.loads(proc.stdout.strip().splitlines()[-1])
    assert row["devices"] == 4
    assert row["num_learners"] == 4
    assert row["batch_shard_count"] == 4
    assert abs(row["loss_single"] - row["loss_sharded"]) < 1e-4
    assert abs(row["loss_single"] - row["loss_micro"]) < 1e-4
    assert row["param_diff_sharded"] < 1e-4
    assert row["param_diff_micro"] < 1e-4
