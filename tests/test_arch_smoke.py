"""Per-architecture smoke tests: reduced variants (2 layers, d_model<=512,
<=4 experts) run one forward/train step on CPU, asserting shapes + no NaNs;
decode paths run one serve step against a prefilled cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config, reduced_config
from repro.models import Model, make_train_step
from repro.optim import adam

ARCHS = sorted(ARCHITECTURES)


def _batch(cfg, B=2, S=32, key=None):
    key = key or jax.random.PRNGKey(0)
    if cfg.modality == "audio":
        tokens = jax.random.randint(key, (B, S, cfg.num_codebooks), 0, cfg.vocab_size)
        return {"tokens": tokens, "labels": tokens}
    if cfg.modality == "vlm":
        M = cfg.num_media_tokens
        tokens = jax.random.randint(key, (B, S - M), 0, cfg.vocab_size)
        media = jax.random.normal(key, (B, M, cfg.d_model), jnp.float32)
        return {"tokens": tokens, "labels": tokens, "media_emb": media}
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"tokens": tokens, "labels": tokens}


def test_reduced_configs_respect_limits():
    for a in ARCHS:
        r = reduced_config(a)
        assert r.num_layers <= 2
        assert r.d_model <= 512
        if r.moe:
            assert r.moe.num_experts <= 4


def test_full_configs_match_assignment():
    spec = {
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 102400),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 65536),
        "rwkv6-7b": (32, 4096, 0, 0, 65536),
        "qwen1.5-4b": (40, 2560, 20, 20, 151936),
        "llava-next-34b": (60, 7168, 56, 8, 64000),
        "qwen1.5-32b": (64, 5120, 40, 40, 152064),
        "musicgen-large": (48, 2048, 32, 32, 2048),
        "nemotron-4-15b": (32, 6144, 48, 8, 256000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 32064),
        "qwen3-14b": (40, 5120, 40, 8, 151936),
    }
    for name, (L, d, H, KV, V) in spec.items():
        cfg = get_config(name)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.vocab_size) == (
            L, d, H, KV, V
        ), name


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced_config(arch)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    batch = _batch(cfg)
    opt = adam(1e-4)
    step = jax.jit(make_train_step(model, opt))
    p2, o2, metrics = step(params, opt.init(params), batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss)
    # params actually changed (bf16 norm scales may round to unchanged; any
    # leaf moving is sufficient)
    changed = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(p2))
    )
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = reduced_config(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = _batch(cfg)
    x, aux = model.forward(params, batch["tokens"], batch.get("media_emb"))
    B = batch["tokens"].shape[0]
    S = 32  # total seq incl media for vlm
    assert x.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(x, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen3-14b", "deepseek-v2-lite-16b", "rwkv6-7b", "jamba-v0.1-52b", "musicgen-large"])
def test_decode_matches_forward(arch):
    import dataclasses

    cfg = reduced_config(arch)
    # float32 so reordered-but-equal math (MLA absorption, MoE dispatch)
    # compares tightly; bf16 is exercised by the train smoke tests.
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe is not None:
        # Ample capacity: compare the math, not the (intentional) capacity
        # drop policy, whose drop pattern differs between seq lengths.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    model = Model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init_params(key)
    B, S = 2, 16
    shape = (B, S, cfg.num_codebooks) if cfg.modality == "audio" else (B, S)
    tokens = jax.random.randint(key, shape, 0, cfg.vocab_size)
    x, _ = model.forward(params, tokens)
    full = model._head(params, x)
    _, cache = model.prefill(params, tokens[:, : S - 1], window=S)
    dec, _ = model.decode_step(params, cache, tokens[:, S - 1 : S])
    a = np.asarray(full[:, -1], np.float32)
    b = np.asarray(dec[:, 0], np.float32)
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert rel < 2e-3, rel
