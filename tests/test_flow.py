"""Flow IR: build/compile round-trips, deferred resources, fusion, DOT."""

import re
import threading

import numpy as np
import pytest

import repro.flow as flow
from repro.core.actor import ActorPool
from repro.core.iterators import NextValueNotReady
from repro.core.workers import WorkerSet
from repro.rl import ActorCriticPolicy, CartPole, DQNPolicy, ReplayBuffer, RolloutWorker


def pg_ws(algo="pg", n=2, rollout_len=8):
    def mk(i):
        return RolloutWorker(
            CartPole(),
            ActorCriticPolicy(4, 2, loss_kind=algo if algo != "pg" else "pg", rollout_len=rollout_len),
            algo=algo, num_envs=2, rollout_len=rollout_len, seed=3, worker_index=i,
        )

    return WorkerSet.create(mk, n)


def dqn_ws(n=2):
    def mk(i):
        return RolloutWorker(
            CartPole(), DQNPolicy(4, 2), algo="dqn", num_envs=2, rollout_len=8,
            seed=4, worker_index=i, epsilon=0.3,
        )

    return WorkerSet.create(mk, n)


def replay(n=1, batch=32, starts=64):
    return ActorPool.from_targets(
        [ReplayBuffer(capacity=4096, sample_batch_size=batch, learning_starts=starts)
         for _ in range(n)]
    )


def spec_for(name):
    """(spec, workers, replay_pool-or-None) for every registered plan."""
    if name in flow.REPLAY_PLANS:
        ws, rp = dqn_ws(n=1), replay()
        return flow.PLAN_BUILDERS[name](ws, rp), ws, rp
    ws = pg_ws(n=1)
    return flow.PLAN_BUILDERS[name](ws), ws, None


# --------------------------------------------------------------- round-trip
@pytest.mark.parametrize("name", sorted(flow.PLAN_BUILDERS))
def test_build_compile_roundtrip(name):
    """Every Table 2 plan builds a valid graph and lowers without running."""
    spec, ws, rp = spec_for(name)
    spec.validate()
    assert spec.output is not None and spec.nodes

    compiled = spec.compile()
    # Compilation is side-effect free: resources exist but are not started.
    for res in compiled.runtime.resources.values():
        assert not res.is_alive()
    dot = compiled.to_dot()
    assert dot.startswith("digraph") and dot.rstrip().endswith("}")

    compiled.stop()
    ws.stop()
    if rp is not None:
        rp.stop()


def _assert_valid_dot(dot):
    assert dot.startswith('digraph "')
    assert dot.count("{") == dot.count("}") == 1
    declared = set(re.findall(r'^\s*"([^"]+)"\s*\[', dot, re.M))
    for src, dst in re.findall(r'^\s*"([^"]+)"\s*->\s*"([^"]+)"', dot, re.M):
        assert src in declared, f"edge source {src} undeclared"
        assert dst in declared, f"edge target {dst} undeclared"


@pytest.mark.parametrize("name", ["apex", "multi_agent_ppo_dqn"])
def test_to_dot_is_valid(name):
    """Acceptance: valid DOT for the paper's Fig 9-12 style graphs."""
    spec, ws, rp = spec_for(name)
    _assert_valid_dot(spec.to_dot())
    # Fused view stays valid too.
    _assert_valid_dot(flow.fuse_for_each(spec).to_dot())
    ws.stop()
    if rp is not None:
        rp.stop()


# ---------------------------------------------------------------- Algorithm
def test_algorithm_ppo_trains_and_reports():
    ws = pg_ws(algo="ppo")
    with flow.Algorithm.from_plan(
        "ppo", ws, train_batch_size=64, num_sgd_iter=2, sgd_minibatch_size=32
    ) as algo:
        res = algo.iterate(2)
        assert res[-1]["counters"]["num_steps_trained"] > 0


def test_algorithm_deferred_learner_lifecycle():
    """The tentpole guarantee: no side effects at build/compile time, and
    no live learner threads after Algorithm.stop()."""
    ws = dqn_ws()
    rp = replay(n=2)
    algo = flow.Algorithm.from_plan("apex", ws, rp, target_update_freq=256)
    learner = algo.resources["learner"]
    assert not learner.is_alive(), "learner must not start at compile time"

    res = algo.iterate(3)
    assert learner.is_alive(), "first pull starts the learner"
    assert res[-1]["counters"]["num_steps_trained"] > 0

    algo.stop()
    assert not learner.is_alive()
    assert not [t for t in threading.enumerate() if t.name == "learner"]


def test_algorithm_rejects_missing_replay():
    ws = pg_ws(n=1)
    with pytest.raises(ValueError, match="replay_actors"):
        flow.Algorithm.from_plan("apex", ws)
    with pytest.raises(ValueError, match="unknown plan"):
        flow.Algorithm.from_plan("nope", ws)
    with pytest.raises(ValueError, match="no effect"):
        flow.Algorithm.from_plan(flow.build_a3c(ws), ws, num_async=2)
    ws.stop()


def test_algorithm_guards_use_after_stop():
    ws = pg_ws(n=1)
    algo = flow.Algorithm.from_plan("a3c", ws)
    algo.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        algo.train()
    with pytest.raises(RuntimeError, match="stopped"):
        algo.iterate(1)
    with pytest.raises(RuntimeError, match="stopped"):
        iter(algo)


def test_algorithm_save_restore_roundtrip(tmp_path):
    ws = pg_ws(algo="ppo")
    algo = flow.Algorithm.from_plan(
        "ppo", ws, train_batch_size=64, num_sgd_iter=1, sgd_minibatch_size=0
    )
    algo.train()
    path = str(tmp_path / "ck.npz")
    algo.save(path)
    import jax

    saved = jax.tree_util.tree_map(np.asarray, ws.local_worker().get_weights())
    algo.train()  # weights move on
    algo.restore(path)
    restored = ws.local_worker().get_weights()
    for a, b in zip(jax.tree_util.tree_leaves(saved), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    # Remote workers got the restored weights too (sync_weights broadcast).
    remote = ws.remote_workers()[0].sync("get_weights")
    np.testing.assert_allclose(
        np.asarray(jax.tree_util.tree_leaves(remote)[0]),
        np.asarray(jax.tree_util.tree_leaves(restored)[0]),
        rtol=1e-6,
    )
    algo.stop()


# ------------------------------------------------------------ stage fusion
def _chain_spec():
    """Mixed pure/impure chain: fusion must preserve sentinel semantics."""
    spec = flow.FlowSpec("chain")
    s = spec.from_items(list(range(20)))

    def batcher():
        buf = []

        def _batch(x):  # impure: emits NextValueNotReady until 2 buffered
            buf.append(x)
            if len(buf) < 2:
                return NextValueNotReady()
            out, buf[:] = list(buf), []
            return out

        return _batch

    s = s.for_each(flow.pure(lambda x: x + 1), label="inc")
    s = s.for_each(batcher(), label="pair")
    s = s.for_each(flow.pure(lambda p: p[0] * 100 + p[1]), label="encode")
    spec.set_output(s)
    return spec


def test_fusion_equivalence():
    """Acceptance: fused and unfused compiles produce identical outputs."""
    fused = list(_chain_spec().compile(fuse=True))
    unfused = list(_chain_spec().compile(fuse=False))
    expected = [(2 * i + 1) * 100 + (2 * i + 2) for i in range(10)]
    assert fused == unfused == expected


def test_fusion_merges_adjacent_local_stages():
    spec = _chain_spec()
    assert sum(n.kind == "for_each" for n in spec.nodes.values()) == 3
    opt = flow.fuse_for_each(spec)
    fe = [n for n in opt.nodes.values() if n.kind == "for_each"]
    assert len(fe) == 1
    assert len(fe[0].params["stages"]) == 3


def test_fusion_respects_stream_splits():
    """A duplicated (multi-consumer) stage chain must not fuse across the
    split point."""
    spec = flow.FlowSpec("split")
    s = spec.from_items([1, 2, 3]).for_each(flow.pure(lambda x: x + 1))
    a, b = s.duplicate(2)
    a = a.for_each(flow.pure(lambda x: x * 2))
    b = b.for_each(flow.pure(lambda x: x * 3))
    spec.set_output(spec.concurrently([a, b], mode="round_robin"))
    opt = flow.fuse_for_each(spec)
    assert sum(n.kind == "for_each" for n in opt.nodes.values()) == 3


def test_compose_stages_skips_checks_after_pure():
    inc = flow.pure(lambda x: x + 1)
    fused = flow.compose_stages([inc, inc, inc])
    assert fused(0) == 3
    assert getattr(fused, "flow_pure", False)


# ------------------------------------------------------------- builder API
def test_stream_typing_errors():
    ws = pg_ws(n=1)
    spec = flow.FlowSpec("t")
    par = spec.par_gradients(ws)
    with pytest.raises(TypeError):
        par.zip_with_source_actor()  # parallel stream: must sequence first
    local = par.gather_async()
    with pytest.raises(TypeError):
        local.gather_async()  # already local
    ws.stop()


def test_validate_rejects_double_consumption():
    spec = flow.FlowSpec("t")
    s = spec.from_items([1])
    s.for_each(flow.pure(lambda x: x))
    spec.set_output(s.for_each(flow.pure(lambda x: x)))
    with pytest.raises(ValueError, match="consumed"):
        spec.validate()


def test_compat_shims_still_return_plan_iterators():
    """Legacy surface: plans.py functions return iterators with .learner_thread."""
    import repro.core as c

    ws = pg_ws(algo="vtrace")
    plan = c.impala_plan(ws, train_batch_size=32)
    assert hasattr(plan, "learner_thread") and not plan.learner_thread.is_alive()
    res = plan.take(2)
    assert res[-1]["counters"]["num_steps_trained"] > 0
    plan.flow.stop()
    assert not plan.learner_thread.is_alive()
    ws.stop()
