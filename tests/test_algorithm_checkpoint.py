"""Algorithm.save()/restore() round-trip mid-stream (ISSUE 2 satellite).

A checkpoint taken mid-training must restore into a fresh Algorithm with
identical metrics counters and replay state (contents, cursors, RNG), and
training must resume from there.

ISSUE 5 extends the contract to the vectorized rollout engine: a worker's
``VectorEnv`` auto-reset state (env pytree mid-episode, episode returns/
lengths/counters) and its per-lane RNG key chains ride the ``.state.pkl``
sidecar, so a restored Algorithm's next rollout is *bit-identical* to what
the original would have sampled."""

import numpy as np

import repro.flow as flow
from repro.core.actor import ActorPool
from repro.core.workers import WorkerSet
from repro.rl import (
    CartPole,
    DQNPolicy,
    DummyPolicy,
    ReplayBuffer,
    RolloutWorker,
    StubEnv,
    VectorizedRolloutWorker,
)


def dqn_ws(n=1):
    def mk(i):
        return RolloutWorker(
            CartPole(), DQNPolicy(4, 2), algo="dqn", num_envs=2, rollout_len=8,
            seed=11, worker_index=i, epsilon=0.3,
        )

    return WorkerSet.create(mk, n)


def replay_pool(n=2):
    return ActorPool.from_targets(
        [ReplayBuffer(capacity=2048, sample_batch_size=32, learning_starts=64, seed=5)
         for _ in range(n)]
    )


def make_algo():
    ws, rp = dqn_ws(), replay_pool()
    algo = flow.Algorithm.from_plan("dqn", ws, rp, target_update_freq=128)
    return algo, ws, rp


def test_save_restore_mid_stream_resumes_identically(tmp_path):
    algo, ws, rp = make_algo()
    for _ in range(4):
        result = algo.train()
    path = str(tmp_path / "mid.npz")
    algo.save(path)
    saved_counters = dict(result["counters"])
    saved_replay_stats = [a.sync("stats") for a in rp]

    # Training moves on after the checkpoint: live state diverges from it.
    algo.train()
    assert algo._it.metrics.counters != saved_counters

    # Restore into a *fresh* setup (new workers, empty buffers).
    algo2, ws2, rp2 = make_algo()
    algo2.restore(path)

    # Identical metrics counters...
    for k, v in saved_counters.items():
        assert algo2._it.metrics.counters[k] == v, k
    # ... identical replay state (sizes, cursors)...
    for a2, stats in zip(rp2, saved_replay_stats):
        assert a2.sync("stats") == stats
    # ... including the sampling RNG: both buffers draw the same indices next.
    # Compare against the checkpointed state (the original moved on since).
    import pickle

    with open(path + ".state.pkl", "rb") as f:
        sidecar = pickle.load(f)
    for ckpt_state, a2 in zip(sidecar["replay"], rp2):
        ref = ReplayBuffer(capacity=2048, sample_batch_size=32, learning_starts=64)
        ref.set_state(ckpt_state)
        b_ref, b2 = ref.replay(), a2.sync("replay")
        if b_ref is None:
            assert b2 is None
        else:
            np.testing.assert_array_equal(b_ref["batch_indices"], b2["batch_indices"])

    # ... identical weights on local AND remote workers.
    import jax

    algo.restore(path)  # rewind the original too, for an apples-to-apples check
    w1 = jax.tree_util.tree_leaves(ws.local_worker().get_weights())
    w2 = jax.tree_util.tree_leaves(ws2.local_worker().get_weights())
    wr = jax.tree_util.tree_leaves(ws2.remote_workers()[0].sync("get_weights"))
    for a, b, r in zip(w1, w2, wr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-6)

    # ... and training RESUMES: counters strictly grow from the restored point.
    res = algo2.train()
    assert res["counters"]["num_steps_sampled"] > saved_counters["num_steps_sampled"]

    algo.stop()
    algo2.stop()


def test_restore_without_sidecar_is_weights_only(tmp_path):
    """Backward compat: a bare .npz (no .state.pkl) restores weights only."""
    import os

    algo, ws, rp = make_algo()
    algo.train()
    path = str(tmp_path / "bare.npz")
    algo.save(path)
    os.remove(path + ".state.pkl")
    counters_before = dict(algo._it.metrics.counters)
    algo.restore(path)
    assert dict(algo._it.metrics.counters) == counters_before  # untouched
    algo.stop()


def make_vec_ckpt_worker(i):
    # rollout_len=7 vs horizon 6: after any whole number of samples the
    # lanes sit mid-episode, so checkpoints capture nontrivial reset state.
    return VectorizedRolloutWorker(
        StubEnv(max_steps=6), DummyPolicy(4, 2), algo="pg",
        num_envs=3, rollout_len=7, seed=31, worker_index=i,
    )


def make_vec_algo():
    ws = WorkerSet.create(make_vec_ckpt_worker, 2)
    algo = flow.Algorithm.from_plan(
        "ppo", ws, train_batch_size=42, num_sgd_iter=1, own_workers=True
    )
    return algo, ws


def test_vector_env_state_and_lane_rng_survive_checkpoint(tmp_path):
    """ISSUE 5 satellite: VectorEnv auto-reset state + per-lane RNG keys
    survive Algorithm.save()/restore() — the restored workers' next sample
    is bit-identical to the original's, mid-episode lanes included."""
    algo, ws = make_vec_algo()
    for _ in range(3):
        algo.train()
    path = str(tmp_path / "vec.npz")
    algo.save(path)

    # The sidecar actually carries the rollout state for local + remotes.
    import pickle

    with open(path + ".state.pkl", "rb") as f:
        sidecar = pickle.load(f)
    assert "local_worker" in sidecar
    assert set(sidecar["remote_workers"]) == {"rollout-1", "rollout-2"}
    saved = sidecar["remote_workers"]["rollout-1"]
    # Mid-stream: some lane is mid-episode (nonzero length) and lanes have
    # completed episodes — the state is genuinely nontrivial.
    assert np.any(np.asarray(saved["vstate"].ep_len) > 0)
    assert np.any(np.asarray(saved["vstate"].eps_count) > 0)

    # Reference stream the original would produce from the checkpoint.
    ref = [ws.remote_workers()[0].sync("sample") for _ in range(2)]

    # Restore into a FRESH topology (new workers, fresh RNG) and compare.
    algo2, ws2 = make_vec_algo()
    fresh = ws2.remote_workers()[0].sync("sample")  # diverged before restore
    algo2.restore(path)
    got = [ws2.remote_workers()[0].sync("sample") for _ in range(2)]
    assert not all(
        np.array_equal(fresh[k], ref[0][k]) for k in ref[0]
    ), "fresh worker already matched; restore proves nothing"
    for i, (a, b) in enumerate(zip(ref, got)):
        assert set(a.keys()) == set(b.keys())
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=f"round {i}: {k}")
    # Episode counters continue from the checkpoint, never restart at 0.
    from repro.rl.rollout_worker import EPS_STRIDE

    restored_counts = got[0]["eps_id"] % EPS_STRIDE
    assert restored_counts.min() >= np.asarray(saved["vstate"].eps_count).min()

    algo.stop()
    algo2.stop()


def test_vector_worker_state_roundtrip_unit():
    w = make_vec_ckpt_worker(1)
    w.sample()
    state = w.get_state()
    nxt = w.sample()
    w2 = make_vec_ckpt_worker(1)
    w2.set_state(state)
    nxt2 = w2.sample()
    for k in nxt:
        np.testing.assert_array_equal(nxt[k], nxt2[k], err_msg=k)
    # Per-lane RNG keys and auto-reset state restored exactly.
    np.testing.assert_array_equal(np.asarray(w.act_rng), np.asarray(w2.act_rng))
    np.testing.assert_array_equal(
        np.asarray(w.vstate.rng), np.asarray(w2.vstate.rng)
    )


def test_replay_state_roundtrip_unit():
    buf = ReplayBuffer(capacity=256, sample_batch_size=16, learning_starts=16, seed=3)
    from repro.rl.sample_batch import SampleBatch

    for i in range(4):
        buf.add_batch(SampleBatch({"obs": np.arange(16.0) + i, "rewards": np.ones(16)}))
    state = buf.get_state()

    buf2 = ReplayBuffer(capacity=256, sample_batch_size=16, learning_starts=16, seed=99)
    buf2.set_state(state)
    assert buf2.stats() == buf.stats()
    b1, b2 = buf.replay(), buf2.replay()
    np.testing.assert_array_equal(b1["batch_indices"], b2["batch_indices"])
    np.testing.assert_array_equal(b1["obs"], b2["obs"])
    np.testing.assert_array_equal(b1["weights"], b2["weights"])
