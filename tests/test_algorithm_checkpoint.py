"""Algorithm.save()/restore() round-trip mid-stream (ISSUE 2 satellite).

A checkpoint taken mid-training must restore into a fresh Algorithm with
identical metrics counters and replay state (contents, cursors, RNG), and
training must resume from there."""

import numpy as np
import pytest

import repro.flow as flow
from repro.core.actor import ActorPool
from repro.core.workers import WorkerSet
from repro.rl import CartPole, DQNPolicy, ReplayBuffer, RolloutWorker


def dqn_ws(n=1):
    def mk(i):
        return RolloutWorker(
            CartPole(), DQNPolicy(4, 2), algo="dqn", num_envs=2, rollout_len=8,
            seed=11, worker_index=i, epsilon=0.3,
        )

    return WorkerSet.create(mk, n)


def replay_pool(n=2):
    return ActorPool.from_targets(
        [ReplayBuffer(capacity=2048, sample_batch_size=32, learning_starts=64, seed=5)
         for _ in range(n)]
    )


def make_algo():
    ws, rp = dqn_ws(), replay_pool()
    algo = flow.Algorithm.from_plan("dqn", ws, rp, target_update_freq=128)
    return algo, ws, rp


def test_save_restore_mid_stream_resumes_identically(tmp_path):
    algo, ws, rp = make_algo()
    for _ in range(4):
        result = algo.train()
    path = str(tmp_path / "mid.npz")
    algo.save(path)
    saved_counters = dict(result["counters"])
    saved_replay_stats = [a.sync("stats") for a in rp]

    # Training moves on after the checkpoint: live state diverges from it.
    algo.train()
    assert algo._it.metrics.counters != saved_counters

    # Restore into a *fresh* setup (new workers, empty buffers).
    algo2, ws2, rp2 = make_algo()
    algo2.restore(path)

    # Identical metrics counters...
    for k, v in saved_counters.items():
        assert algo2._it.metrics.counters[k] == v, k
    # ... identical replay state (sizes, cursors)...
    for a2, stats in zip(rp2, saved_replay_stats):
        assert a2.sync("stats") == stats
    # ... including the sampling RNG: both buffers draw the same indices next.
    # Compare against the checkpointed state (the original moved on since).
    import pickle

    with open(path + ".state.pkl", "rb") as f:
        sidecar = pickle.load(f)
    for ckpt_state, a2 in zip(sidecar["replay"], rp2):
        ref = ReplayBuffer(capacity=2048, sample_batch_size=32, learning_starts=64)
        ref.set_state(ckpt_state)
        b_ref, b2 = ref.replay(), a2.sync("replay")
        if b_ref is None:
            assert b2 is None
        else:
            np.testing.assert_array_equal(b_ref["batch_indices"], b2["batch_indices"])

    # ... identical weights on local AND remote workers.
    import jax

    w_saved = jax.tree_util.tree_leaves(ws.local_worker().get_weights())
    algo.restore(path)  # rewind the original too, for an apples-to-apples check
    w1 = jax.tree_util.tree_leaves(ws.local_worker().get_weights())
    w2 = jax.tree_util.tree_leaves(ws2.local_worker().get_weights())
    wr = jax.tree_util.tree_leaves(ws2.remote_workers()[0].sync("get_weights"))
    for a, b, r in zip(w1, w2, wr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-6)

    # ... and training RESUMES: counters strictly grow from the restored point.
    res = algo2.train()
    assert res["counters"]["num_steps_sampled"] > saved_counters["num_steps_sampled"]

    algo.stop()
    algo2.stop()


def test_restore_without_sidecar_is_weights_only(tmp_path):
    """Backward compat: a bare .npz (no .state.pkl) restores weights only."""
    import os

    algo, ws, rp = make_algo()
    algo.train()
    path = str(tmp_path / "bare.npz")
    algo.save(path)
    os.remove(path + ".state.pkl")
    counters_before = dict(algo._it.metrics.counters)
    algo.restore(path)
    assert dict(algo._it.metrics.counters) == counters_before  # untouched
    algo.stop()


def test_replay_state_roundtrip_unit():
    buf = ReplayBuffer(capacity=256, sample_batch_size=16, learning_starts=16, seed=3)
    from repro.rl.sample_batch import SampleBatch

    for i in range(4):
        buf.add_batch(SampleBatch({"obs": np.arange(16.0) + i, "rewards": np.ones(16)}))
    state = buf.get_state()

    buf2 = ReplayBuffer(capacity=256, sample_batch_size=16, learning_starts=16, seed=99)
    buf2.set_state(state)
    assert buf2.stats() == buf.stats()
    b1, b2 = buf.replay(), buf2.replay()
    np.testing.assert_array_equal(b1["batch_indices"], b2["batch_indices"])
    np.testing.assert_array_equal(b1["obs"], b2["obs"])
    np.testing.assert_array_equal(b1["weights"], b2["weights"])
