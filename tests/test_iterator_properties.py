"""Property-based tests of the dataflow model's core guarantees (paper §4)."""

import pytest

pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st

import repro.core as c

shard_lists = st.lists(
    st.lists(st.integers(min_value=0, max_value=1000), min_size=1, max_size=8),
    min_size=1,
    max_size=4,
)


@given(shard_lists)
@settings(max_examples=25, deadline=None)
def test_gather_sync_is_round_interleaved(shards):
    """Barrier gather emits one item per shard per round, in shard order,
    for as many full rounds as the shortest shard provides."""
    n_rounds = min(len(s) for s in shards)
    expected = [s[r] for r in range(n_rounds) for s in shards]
    out = c.from_iterators(shards).gather_sync().take(len(expected))
    assert out == expected


@given(shard_lists, st.integers(min_value=1, max_value=4))
@settings(max_examples=25, deadline=None)
def test_gather_async_yields_exact_multiset(shards, num_async):
    total = sum(len(s) for s in shards)
    out = c.from_iterators(shards).gather_async(num_async=num_async).take(total)
    assert sorted(out) == sorted(x for s in shards for x in s)


@given(shard_lists)
@settings(max_examples=25, deadline=None)
def test_gather_async_preserves_per_shard_order(shards):
    # Tag items with shard id so we can check relative order per shard.
    tagged = [[(i, x) for x in s] for i, s in enumerate(shards)]
    total = sum(len(s) for s in shards)
    out = c.from_iterators(tagged).gather_async().take(total)
    for i, s in enumerate(tagged):
        seen = [item for item in out if item[0] == i]
        assert seen == s  # per-shard FIFO even under async completion order


@given(
    st.lists(st.integers(min_value=0, max_value=9), min_size=2, max_size=30),
    st.integers(min_value=1, max_value=3),
    st.integers(min_value=1, max_value=3),
)
@settings(max_examples=25, deadline=None)
def test_round_robin_weight_ratio(items, w1, w2):
    """Weighted round-robin pulls w1:w2 items per turn while both alive."""
    a = c.from_items([("a", x) for x in items])
    b = c.from_items([("b", x) for x in items])
    u = a.union(b, deterministic=True, round_robin_weights=[w1, w2])
    take_n = min(len(items) // max(w1, w2), 2) * (w1 + w2)
    if take_n == 0:
        return
    out = u.take(take_n)
    # First full cycle: w1 'a's then w2 'b's.
    assert [t for t, _ in out[: w1 + w2]] == ["a"] * w1 + ["b"] * w2


@given(shard_lists)
@settings(max_examples=15, deadline=None)
def test_union_async_exact_multiset(shards):
    locals_ = [c.from_items(s) for s in shards]
    total = sum(len(s) for s in shards)
    out = locals_[0].union(*locals_[1:]).take(total)
    assert sorted(out) == sorted(x for s in shards for x in s)


@given(
    st.lists(st.integers(), min_size=1, max_size=20),
    st.integers(min_value=2, max_value=4),
)
@settings(max_examples=25, deadline=None)
def test_duplicate_fanout_identical(items, n):
    dups = c.from_items(items).duplicate(n)
    for d in dups:
        assert d.take(len(items)) == items


@given(st.lists(st.integers(), min_size=0, max_size=30), st.integers(min_value=1, max_value=7))
@settings(max_examples=25, deadline=None)
def test_batch_partitions_stream(items, n):
    batches = c.from_items(items).batch(n).take(len(items))
    flat = [x for b in batches for x in b]
    assert flat == items[: (len(items) // n) * n]
    assert all(len(b) == n for b in batches)
