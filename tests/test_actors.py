"""Virtual actor model: serialized execution, futures, wait, messaging."""

import threading
import time

import pytest

from repro.core.actor import ActorPool, VirtualActor, create_colocated, get, wait


class Counter:
    def __init__(self):
        self.n = 0
        self.thread_ids = set()

    def incr(self, k=1):
        self.thread_ids.add(threading.get_ident())
        self.n += k
        return self.n

    def slow(self):
        time.sleep(0.05)
        return "slow"

    def fast(self):
        return "fast"

    def boom(self):
        raise ValueError("boom")


def test_serialized_execution_single_thread():
    a = VirtualActor(Counter())
    futs = [a.call("incr") for _ in range(50)]
    assert [f.result() for f in futs] == list(range(1, 51))
    assert len(a.target.thread_ids) == 1  # mailbox thread only
    a.stop()


def test_fifo_ordering_per_actor():
    a = VirtualActor(Counter())
    f1 = a.call("slow")
    f2 = a.call("fast")
    # FIFO: fast cannot complete before slow.
    assert f1.result() == "slow"
    assert f2.done()
    a.stop()


def test_exceptions_propagate():
    a = VirtualActor(Counter())
    with pytest.raises(ValueError):
        a.call("boom").result()
    a.stop()


def test_wait_num_returns():
    a = VirtualActor(Counter())
    b = VirtualActor(Counter())
    futs = [a.call("slow"), b.call("fast")]
    ready, pending = wait(futs, num_returns=1)
    assert len(ready) >= 1
    a.stop(); b.stop()


def test_apply_sees_target():
    a = VirtualActor(Counter())
    assert a.apply(lambda t: t.incr(5)).result() == 5
    a.stop()


def test_pool_broadcast():
    pool = ActorPool.from_targets([Counter(), Counter()])
    assert pool.broadcast_sync("incr") == [1, 1]
    pool.stop()


def test_create_colocated():
    pool = create_colocated(Counter, 3)
    assert len(pool) == 3
    pool.stop()


def test_get_helper():
    a = VirtualActor(Counter())
    assert get([a.call("incr"), a.call("incr")]) == [1, 2]
    assert get(42) == 42
    a.stop()
