"""SampleBatch / MultiAgentBatch invariants (property-based)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property-based tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.rl.sample_batch import MultiAgentBatch, SampleBatch


def make_batch(n, seed=0):
    rng = np.random.default_rng(seed)
    return SampleBatch(
        obs=rng.standard_normal((n, 4)),
        actions=rng.integers(0, 2, n),
        rewards=rng.standard_normal(n),
    )


@given(st.lists(st.integers(min_value=0, max_value=20), min_size=1, max_size=6))
@settings(max_examples=30, deadline=None)
def test_concat_count_additive(sizes):
    batches = [make_batch(n, i) for i, n in enumerate(sizes)]
    out = SampleBatch.concat_samples(batches)
    assert out.count == sum(sizes)


@given(
    st.integers(min_value=1, max_value=50),
    st.integers(min_value=0, max_value=49),
    st.integers(min_value=1, max_value=50),
)
@settings(max_examples=30, deadline=None)
def test_slice_bounds(n, start, length):
    b = make_batch(n)
    end = min(start + length, n)
    s = b.slice(min(start, n), end)
    assert s.count == max(0, end - min(start, n))


@given(st.integers(min_value=1, max_value=64), st.integers(min_value=1, max_value=16))
@settings(max_examples=30, deadline=None)
def test_minibatches_partition(n, mb):
    b = make_batch(n)
    rows = sum(m.count for m in b.minibatches(mb))
    assert rows == (n // mb) * mb  # full minibatches only
    for m in b.minibatches(mb):
        assert m.count == mb


def test_ragged_rejected():
    with pytest.raises(ValueError):
        SampleBatch(a=np.zeros(3), b=np.zeros(4))


def test_shuffle_preserves_rows():
    b = make_batch(16)
    s = b.shuffle(np.random.default_rng(0))
    assert sorted(s["rewards"].tolist()) == sorted(b["rewards"].tolist())
    # rows stay aligned across columns
    for i in range(16):
        j = np.where(b["rewards"] == s["rewards"][i])[0][0]
        assert np.allclose(b["obs"][j], s["obs"][i])


def test_split_by_episode():
    b = SampleBatch(obs=np.zeros((6, 2)), eps_id=np.array([1, 1, 2, 2, 2, 3]))
    eps = b.split_by_episode()
    assert [e.count for e in eps] == [2, 3, 1]


def test_multi_agent_select_concat():
    ma = MultiAgentBatch({"p1": make_batch(4), "p2": make_batch(6)})
    assert ma.count == 10
    sel = ma.select(["p1"])
    assert list(sel.policy_batches) == ["p1"]
    merged = MultiAgentBatch.concat_samples([ma, ma])
    assert merged.count == 20
