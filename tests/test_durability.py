"""Durability model (paper §3): weak consistency — restart from checkpoint,
regenerate identical data, continue training deterministically.

Operator state (iterator buffers, replay contents) is deliberately
discardable; the only durable state is (params, opt_state, step), matching
the paper's argument that RL tolerates message/data loss and restarts
cheaply from the last checkpoint.
"""

import os

import numpy as np

from repro.checkpoint import restore_pytree, save_pytree
from repro.configs import reduced_config
from repro.configs.base import InputShape
from repro.core.spmd import SPMDLearnerWorker, SPMDTrainContext
from repro.data import make_batch
from repro.launch.mesh import make_local_mesh
from repro.optim import adamw


def _learner():
    cfg = reduced_config("qwen3-14b")
    ctx = SPMDTrainContext(cfg, adamw(1e-3), make_local_mesh())
    return cfg, SPMDLearnerWorker(ctx, seed=0)


def test_checkpoint_restart_is_deterministic(tmp_path):
    cfg, lw = _learner()
    shape = InputShape("t", 32, 2, "train")

    # Train 2 steps, checkpoint, train 2 more: record losses 3-4.
    for s in range(2):
        lw.learn_on_batch(make_batch(cfg, shape, seed=0, step=s))
    ck = os.path.join(tmp_path, "ck.npz")
    save_pytree(ck, {"params": lw.params, "opt": lw.opt_state})
    ref = [
        lw.learn_on_batch(make_batch(cfg, shape, seed=0, step=s))["loss"]
        for s in (2, 3)
    ]

    # Fresh process-equivalent: new learner, restore, regenerate same data.
    cfg2, lw2 = _learner()
    state = restore_pytree(ck, {"params": lw2.params, "opt": lw2.opt_state})
    lw2.params, lw2.opt_state = state["params"], state["opt"]
    out = [
        lw2.learn_on_batch(make_batch(cfg2, shape, seed=0, step=s))["loss"]
        for s in (2, 3)
    ]
    np.testing.assert_allclose(out, ref, rtol=1e-5)


def test_replay_state_is_discardable():
    """Rebuilding replay from scratch after 'failure' still trains (the
    paper's point: buffer loss degrades sample reuse, not correctness)."""
    from repro.core.actor import ActorPool
    from repro.rl import CartPole, DQNPolicy, ReplayBuffer, RolloutWorker
    import repro.core as c

    def mk(i):
        return RolloutWorker(CartPole(), DQNPolicy(4, 2), algo="dqn",
                             num_envs=2, rollout_len=8, seed=9, worker_index=i)

    ws = c.WorkerSet.create(mk, 1)
    rp = ActorPool.from_targets([ReplayBuffer(capacity=1024, sample_batch_size=16, learning_starts=32)])
    c.dqn_plan(ws, rp, target_update_freq=64).take(3)
    rp.stop()
    # "failure": fresh replay actors, same workers/params
    rp2 = ActorPool.from_targets([ReplayBuffer(capacity=1024, sample_batch_size=16, learning_starts=32)])
    res = c.dqn_plan(ws, rp2, target_update_freq=64).take(3)
    assert res[-1]["counters"]["num_steps_trained"] > 0
    ws.stop(); rp2.stop()
