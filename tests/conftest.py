"""Shared test infrastructure (ISSUE 3 deflake satellites).

* ``timeout`` marker — ``@pytest.mark.timeout(seconds)`` fails a wedged test
  fast (SIGALRM) instead of hanging CI: a transport bug that deadlocks a
  pipe/queue surfaces as a clean failure with a traceback pointing at the
  blocked call.  Defers to the real pytest-timeout plugin when installed.

* ``deterministic_clock`` fixture — one seeded randomness + polling helper
  for every time-dependent test (union stress, chaos hang/slow injectors).
  The seed derives from the test id, so each test's delay schedule is stable
  run-to-run but distinct across tests, and deadline polling goes through
  ``wait_until`` instead of hand-rolled ``time.time()`` loops.

* ``backend_matrix`` params — the executor/chaos/transport suites share one
  backend axis: thread, process+pickle-pipe, process+shared-memory.

* shm lease sanitizer — with ``TRANSPORT_SANITIZE=1`` in the environment,
  every test runs inside a sanitizer epoch: the transport's lease
  acquire/release ledger starts clean, and teardown fails the test on any
  double-released lease, lease still live after GC, or ``/dev/shm`` segment
  the test left behind (see ``repro.core.transport.SANITIZER``).
"""

from __future__ import annotations

import random
import signal
import time
import zlib

import pytest

try:  # the plugin owns the marker when present
    import pytest_timeout  # noqa: F401

    _HAVE_PYTEST_TIMEOUT = True
except ImportError:
    _HAVE_PYTEST_TIMEOUT = False


# --------------------------------------------------------------- timeout
@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("timeout")
    if (
        marker is None
        or _HAVE_PYTEST_TIMEOUT
        or not hasattr(signal, "SIGALRM")
    ):
        yield
        return
    seconds = float(marker.args[0]) if marker.args else 60.0

    def _alarm(signum, frame):
        pytest.fail(
            f"test exceeded its {seconds:.0f}s timeout marker "
            "(wedged transport/queue?)", pytrace=True
        )

    old_handler = signal.signal(signal.SIGALRM, _alarm)
    signal.setitimer(signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, old_handler)


# ----------------------------------------------------- deterministic clock
class DeterministicClock:
    """Seeded delays + deadline polling for time-dependent tests.

    ``rng`` drives every injected delay (stable per test id); ``jitter``
    sleeps a seeded fraction of ``max_delay``; ``wait_until`` polls a
    predicate against a bounded deadline and reports success instead of
    letting the test spin forever.
    """

    def __init__(self, seed: int):
        self.seed = seed
        self.rng = random.Random(seed)

    def jitter(self, max_delay: float) -> float:
        dt = self.rng.random() * max_delay
        time.sleep(dt)
        return dt

    @staticmethod
    def wait_until(predicate, timeout: float = 5.0, interval: float = 0.01) -> bool:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if predicate():
                return True
            time.sleep(interval)
        return bool(predicate())


@pytest.fixture
def deterministic_clock(request) -> DeterministicClock:
    return DeterministicClock(seed=zlib.crc32(request.node.nodeid.encode()) & 0xFFFF)


# ------------------------------------------------------- lease sanitizer
@pytest.fixture(autouse=True)
def _shm_lease_sanitizer(request):
    """Per-test lease-sanitizer epoch, active under TRANSPORT_SANITIZE=1."""
    from repro.core.transport import SANITIZER, sanitize_enabled

    if not sanitize_enabled():
        yield
        return
    SANITIZER.begin_epoch(request.node.nodeid)
    yield
    SANITIZER.end_epoch()


# ------------------------------------------------------- backend matrix
# One axis for every suite exercising the executor runtime: the two process
# rows differ only in the data plane, which is exactly what the transport
# matrix tests assert equality across.
BACKEND_MATRIX = ["thread", "process-pickle", "process-shm"]


def make_backend(param: str):
    """Map a matrix param to a WorkerSet.create backend argument."""
    if param == "thread":
        return "thread"
    from repro.core import ProcessBackend

    _, transport = param.split("-", 1)
    return ProcessBackend(transport=transport)
