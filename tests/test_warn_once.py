"""Warn-once fallback latches are per-compile, not per-process (ISSUE 8).

``TrainOneStep._warned_fallback`` suppressed its sharded-fallback warning
forever once set: a deepcopy at compile time copied the latched flag along,
and operators that can't be deep-copied (live WorkerSet) are *shared*
across every compile of the spec — so one Algorithm's fallback silenced
the warning in every later Algorithm and across test runs in one process.
``CompiledFlow._instantiate`` now re-arms the latch via the
``reset_warnings()`` protocol."""

import logging
import threading

from repro.core.operators import TrainOneStep
from repro.flow.compile import CompiledFlow
from repro.flow.spec import StageSpec


class _SharedWorkers:
    """Stub WorkerSet whose lock makes deepcopy fail -> shared instance."""

    def __init__(self):
        self._lock = threading.Lock()

    def local_worker(self):
        return object()

    def sync_weights(self):
        pass


class _CopyableWorkers:
    def local_worker(self):
        return object()

    def sync_weights(self):
        pass


def _warn_count(caplog):
    return sum(
        "falling back" in r.getMessage() for r in caplog.records
    )


def test_warn_fallback_is_once_per_instance(caplog):
    op = TrainOneStep(_SharedWorkers(), num_learners=2)
    lw = object()  # no _loss_for -> sharded path warns
    with caplog.at_level(logging.WARNING, logger="repro.core.operators"):
        op._warn_fallback(lw, "no pure loss")
        op._warn_fallback(lw, "no pure loss")
    assert _warn_count(caplog) == 1


def test_warn_fallback_reemits_after_recompile_shared_instance(caplog):
    """The deepcopy-failed path: _instantiate falls back to the SAME
    instance, so without reset_warnings() a second compile would inherit
    the latched flag and never warn again."""
    op = TrainOneStep(_SharedWorkers(), num_learners=2)
    lw = object()
    with caplog.at_level(logging.WARNING, logger="repro.core.operators"):
        op._warn_fallback(lw, "first compile")
    assert _warn_count(caplog) == 1

    fn = CompiledFlow._instantiate(None, StageSpec(fn=op, label="train"))
    assert fn is op  # lock killed the deepcopy -> shared instance

    caplog.clear()
    with caplog.at_level(logging.WARNING, logger="repro.core.operators"):
        fn._warn_fallback(lw, "second compile")
        fn._warn_fallback(lw, "second compile")
    assert _warn_count(caplog) == 1  # re-armed: warns once again


def test_warn_fallback_fresh_in_deepcopied_instance(caplog):
    """The deepcopy-survived path: the copy must start with the latch
    re-armed even when the original already warned."""
    op = TrainOneStep(_CopyableWorkers(), num_learners=2)
    op._warned_fallback = True  # original already latched
    fn = CompiledFlow._instantiate(None, StageSpec(fn=op, label="train"))
    assert fn is not op
    assert fn._warned_fallback is False
    with caplog.at_level(logging.WARNING, logger="repro.core.operators"):
        fn._warn_fallback(object(), "fresh compile")
    assert _warn_count(caplog) == 1
    # ... and the original's latch is untouched by the copy's reset.
    assert op._warned_fallback is True


def test_reset_warnings_protocol():
    op = TrainOneStep(_SharedWorkers(), num_learners=2)
    op._warned_fallback = True
    op.reset_warnings()
    assert op._warned_fallback is False
