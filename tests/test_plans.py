"""Algorithm execution plans end-to-end on CartPole (paper Table 2 suite)."""

import numpy as np
import pytest

import repro.core as c
from conftest import BACKEND_MATRIX
from repro.core.actor import ActorPool
from repro.rl import (
    ActorCriticPolicy,
    CartPole,
    DQNPolicy,
    MultiAgentCartPole,
    MultiAgentRolloutWorker,
    Pendulum,
    ReplayBuffer,
    RolloutWorker,
    SACPolicy,
)


def pg_ws(algo="pg", n=2, rollout_len=16):
    def mk(i):
        return RolloutWorker(
            CartPole(),
            ActorCriticPolicy(4, 2, loss_kind=algo if algo != "pg" else "pg", rollout_len=rollout_len),
            algo=algo,
            num_envs=2,
            rollout_len=rollout_len,
            seed=3,
            worker_index=i,
        )

    return c.WorkerSet.create(mk, n)


def dqn_ws(n=2):
    def mk(i):
        return RolloutWorker(
            CartPole(), DQNPolicy(4, 2), algo="dqn", num_envs=2, rollout_len=8,
            seed=4, worker_index=i, epsilon=0.3,
        )

    return c.WorkerSet.create(mk, n)


def replay(n=1, batch=32, starts=64):
    return ActorPool.from_targets(
        [ReplayBuffer(capacity=4096, sample_batch_size=batch, learning_starts=starts)
         for _ in range(n)]
    )


def test_a3c_plan_trains():
    ws = pg_ws()
    res = c.a3c_plan(ws).take(4)
    assert res[-1]["counters"]["num_steps_trained"] > 0
    ws.stop()


def test_a2c_plan_trains():
    ws = pg_ws()
    res = c.a2c_plan(ws).take(3)
    assert res[-1]["counters"]["num_steps_trained"] > 0
    ws.stop()


def test_ppo_plan_trains():
    ws = pg_ws(algo="ppo")
    res = c.ppo_plan(ws, train_batch_size=64, num_sgd_iter=2, sgd_minibatch_size=32).take(3)
    assert res[-1]["counters"]["num_steps_trained"] > 0
    assert res[-1]["episodes"]["episodes"] >= 0
    ws.stop()


def test_dqn_plan_trains_and_updates_target():
    ws = dqn_ws()
    rp = replay()
    res = c.dqn_plan(ws, rp, target_update_freq=64).take(5)
    assert res[-1]["counters"]["num_steps_trained"] > 0
    assert res[-1]["counters"]["num_target_updates"] >= 1
    ws.stop(); rp.stop()


def test_apex_plan_concurrent_subflows():
    ws = dqn_ws()
    rp = replay(n=2)
    plan = c.apex_plan(ws, rp, target_update_freq=256)
    res = plan.take(4)
    plan.learner_thread.stop()
    assert res[-1]["counters"]["num_steps_sampled"] > 0
    assert res[-1]["counters"]["num_steps_trained"] > 0
    ws.stop(); rp.stop()


def test_impala_plan_vtrace():
    ws = pg_ws(algo="vtrace")
    plan = c.impala_plan(ws, train_batch_size=64)
    res = plan.take(4)
    plan.learner_thread.stop()
    assert res[-1]["counters"]["num_steps_trained"] > 0
    ws.stop()


def test_sac_plan_continuous():
    def mk(i):
        return RolloutWorker(
            Pendulum(), SACPolicy(3, 1), algo="sac", num_envs=2, rollout_len=8,
            seed=5, worker_index=i, target_polyak=0.01,
        )

    ws = c.WorkerSet.create(mk, 2)
    rp = replay(batch=16, starts=32)
    res = c.sac_plan(ws, rp).take(4)
    assert res[-1]["counters"]["num_steps_trained"] > 0
    ws.stop(); rp.stop()


def test_maml_plan_nested_loops():
    ws = pg_ws()
    res = c.maml_plan(ws, inner_steps=1).take(2)
    assert res[-1]["counters"]["num_steps_trained"] > 0
    ws.stop()


def test_multi_agent_composition():
    mapping = {0: "ppo_policy", 1: "ppo_policy", 2: "dqn_policy", 3: "dqn_policy"}
    specs = {
        "ppo_policy": {"policy": ActorCriticPolicy(4, 2, loss_kind="ppo"), "algo": "ppo"},
        "dqn_policy": {"policy": DQNPolicy(4, 2), "algo": "dqn"},
    }

    def mk(i):
        return MultiAgentRolloutWorker(
            MultiAgentCartPole(4, mapping), specs, mapping, rollout_len=8,
            seed=6, worker_index=i,
        )

    ws = c.WorkerSet.create(mk, 2)
    rp = replay(batch=16, starts=32)
    res = c.multi_agent_ppo_dqn_plan(ws, rp, ppo_batch_size=64, dqn_target_update_freq=128).take(6)
    counters = res[-1]["counters"]
    assert counters["num_steps_trained"] > 0
    stats = rp[0].sync("stats")
    assert stats["added"] > 0  # DQN branch stored experience
    ws.stop(); rp.stop()


# Module-level so the process backends can pickle it into worker children
# (spawn start method: the child re-imports this module and builds the
# JAX worker from scratch — fork would inherit the driver's initialized
# JAX/XLA threads, which is unsafe for jitted targets).
MA_MAPPING = {0: "ppo_policy", 1: "ppo_policy", 2: "dqn_policy", 3: "dqn_policy"}


def make_multi_agent_worker(i):
    specs = {
        "ppo_policy": {"policy": ActorCriticPolicy(4, 2, loss_kind="ppo"), "algo": "ppo"},
        "dqn_policy": {"policy": DQNPolicy(4, 2), "algo": "dqn"},
    }
    return MultiAgentRolloutWorker(
        MultiAgentCartPole(4, MA_MAPPING), specs, MA_MAPPING, rollout_len=8,
        seed=6, worker_index=i,
    )


@pytest.mark.timeout(300)
@pytest.mark.parametrize("backend_param", BACKEND_MATRIX)
def test_multi_agent_composition_backend_matrix(backend_param):
    """ISSUE 4 satellite: the PPO+DQN composition must behave identically
    under thread, process+pickle, and process+shm backends — both training
    branches make the same progress regardless of how rollout batches
    cross the worker boundary."""
    if backend_param == "thread":
        backend = "thread"
    else:
        _, transport = backend_param.split("-", 1)
        backend = c.ProcessBackend(transport=transport, start_method="spawn")

    ws = c.WorkerSet.create(make_multi_agent_worker, 2, backend=backend)
    rp = replay(batch=16, starts=32)
    try:
        res = c.multi_agent_ppo_dqn_plan(
            ws, rp, ppo_batch_size=64, dqn_target_update_freq=128
        ).take(6)
        counters = res[-1]["counters"]
        # Bulk-sync rollouts + round-robin union are deterministic: every
        # backend must sample/train the exact same number of steps (fixed
        # expectations, so each parametrized row is checked independently —
        # no cross-test state that -k / xdist selection could hollow out).
        assert counters["num_steps_sampled"] == 256
        assert counters["num_steps_trained"] == 192
        assert rp[0].sync("stats")["added"] > 0  # DQN branch stored experience
        # The reported learner info is per policy id (paper §5.3).
        infos = [r["info"] for r in res if isinstance(r.get("info"), dict)]
        assert any("ppo_policy" in i or "dqn_policy" in i for i in infos)
        for r in res:
            assert np.isfinite(r["time_total_s"])
    finally:
        ws.stop()
        rp.stop()


def test_lowlevel_a3c_equivalent_progress():
    from repro.rl.lowlevel import a3c_lowlevel

    ws = pg_ws()
    it = a3c_lowlevel(ws)
    res = None
    for _ in range(4):
        res = next(it)
    assert res["counters"]["num_steps_trained"] > 0
    ws.stop()


def test_mbpo_model_based_plan():
    """Paper §2.2: model-based training = one more concurrent sub-flow."""
    from repro.rl.model_based import ModelBasedWorker

    def mk(i):
        return ModelBasedWorker(
            CartPole(), ActorCriticPolicy(4, 2, loss_kind="pg"), algo="pg",
            num_envs=2, rollout_len=16, seed=21, worker_index=i,
        )

    ws = c.WorkerSet.create(mk, 2)
    rp = replay(batch=64, starts=64)
    res = c.mbpo_plan(ws, rp).take(6)
    lw = ws.local_worker()
    assert res[-1]["counters"]["num_steps_trained"] > 0
    assert lw.dyn_losses, "dynamics model never trained"
    # dynamics loss should be finite and improving-ish over the run
    import numpy as np
    assert all(np.isfinite(l) for l in lw.dyn_losses)
    ws.stop(); rp.stop()


def test_appo_plan_async_ppo():
    ws = pg_ws(algo="ppo")
    plan = c.appo_plan(ws, train_batch_size=64)
    res = plan.take(4)
    plan.learner_thread.stop()
    assert res[-1]["counters"]["num_steps_trained"] > 0
    ws.stop()


def test_transformer_policy_in_ppo_plan():
    """Model-zoo attention stack as the RL policy trunk (zoo <-> RL link)."""
    from repro.rl import TransformerPolicy

    def mk(i):
        return RolloutWorker(
            CartPole(), TransformerPolicy(4, 2, d_model=32, n_layers=2),
            algo="ppo", num_envs=2, rollout_len=16, seed=31, worker_index=i,
        )

    ws = c.WorkerSet.create(mk, 2)
    res = c.ppo_plan(ws, train_batch_size=64, num_sgd_iter=1, sgd_minibatch_size=64).take(3)
    assert res[-1]["counters"]["num_steps_trained"] > 0
    ws.stop()
