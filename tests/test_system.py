"""End-to-end behaviour tests for the paper's system.

The headline check mirrors the paper's own evaluation setting: PPO on
CartPole, expressed as an RLlib Flow plan, must actually LEARN (reward
improves substantially over training) — proving the dataflow executor drives
correct end-to-end training, not just data movement.
"""

import numpy as np

import repro.core as c
from repro.rl import ActorCriticPolicy, CartPole, RolloutWorker


def test_ppo_cartpole_learns():
    def mk(i):
        return RolloutWorker(
            CartPole(),
            ActorCriticPolicy(4, 2, hidden=(64, 64), loss_kind="ppo", ent_coef=0.0),
            algo="ppo",
            num_envs=8,
            rollout_len=64,
            seed=0,
            worker_index=i,
        )

    ws = c.WorkerSet.create(mk, num_workers=2)
    plan = c.ppo_plan(ws, train_batch_size=1024, num_sgd_iter=4, sgd_minibatch_size=256)
    it = iter(plan)
    first = next(it)
    early = first["episodes"]["episode_reward_mean"]
    last = first
    for _ in range(25):
        last = next(it)
    final = last["episodes"]["episode_reward_mean"]
    ws.stop()
    # Untrained CartPole ~ 20; a learning run exceeds 60 well within budget.
    assert np.isfinite(final)
    assert final > 60.0, f"reward did not improve: {early} -> {final}"
    assert final > early


def test_end_to_end_counters_consistent():
    def mk(i):
        return RolloutWorker(
            CartPole(), ActorCriticPolicy(4, 2, loss_kind="ppo"), algo="ppo",
            num_envs=2, rollout_len=16, seed=1, worker_index=i,
        )

    ws = c.WorkerSet.create(mk, 2)
    res = c.ppo_plan(ws, train_batch_size=64, num_sgd_iter=1, sgd_minibatch_size=64).take(3)
    counters = res[-1]["counters"]
    # Every sampled step was trained on exactly once (synchronous PPO).
    assert counters["num_steps_trained"] == counters["num_steps_sampled"]
    ws.stop()
